#!/usr/bin/env python
"""Markdown link checker for the docs suite (no network, no deps).

Scans the repo's tracked markdown (README.md, docs/, ROADMAP.md, ...)
for inline links/images ``[text](target)`` and verifies that every
*relative* target resolves to an existing file or directory, including
the file half of ``path#anchor`` targets.  External schemes
(https/mailto) and bare in-page anchors are skipped — this guard is
about the docs suite never pointing at moved/renamed repo files, which
is the failure mode that actually happens here.

    python scripts/check_links.py          # exits 1 on any broken link
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: the *maintained* documentation set.  PAPER.md / PAPERS.md /
#: SNIPPETS.md are retrieval artifacts (they carry dangling figure refs
#: from the source material) and are deliberately out of scope.
MD_GLOBS = ("README.md", "ROADMAP.md", "CHANGES.md", "ISSUE.md",
            "docs/*.md")

#: inline markdown link or image: [text](target) — stops at the first
#: unescaped ')', which is fine for the plain paths used in this repo
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_links(md_path: Path):
    for n, line in enumerate(md_path.read_text().splitlines(), 1):
        for m in _LINK.finditer(line):
            yield n, m.group(1)


def check(md_path: Path) -> list:
    broken = []
    for n, target in iter_links(md_path):
        if target.startswith(_SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md_path.parent / rel).exists():
            broken.append((md_path.relative_to(ROOT), n, target))
    return broken


def main() -> int:
    files = sorted({p for g in MD_GLOBS for p in ROOT.glob(g)})
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    broken = [b for f in files for b in check(f)]
    for path, line, target in broken:
        print(f"BROKEN LINK {path}:{line}: ({target})", file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(broken)} broken relative links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
