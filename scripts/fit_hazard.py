#!/usr/bin/env python
"""Fit a piecewise-constant hazard from a failure/repair event log.

Turns a timestamped CSV/JSONL event log (or an explicit duration
column) into the segment ``edges``/``rates`` JSON that
``Params(failure_distribution="empirical",
distribution_kwargs=fit.distribution_kwargs)`` consumes on the CTMC
fast path — see docs/distributions.md for the log format and
:mod:`repro.core.empirical` for the estimators.

    python scripts/fit_hazard.py failures.csv                # fit -> stdout
    python scripts/fit_hazard.py failures.csv -o fit.json    # fit -> file
    python scripts/fit_hazard.py log.jsonl --event failure --bins 6
    python scripts/fit_hazard.py --selftest                  # CI round trip

``--selftest`` generates a synthetic log, fits it, round-trips the fit
through JSON, runs a short CTMC study from the fitted hazard, and exits
non-zero on any mismatch — the one-line smoke scripts/ci.sh runs.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Params, resolve_engine, run_replications  # noqa: E402
from repro.core.empirical import (PiecewiseFit, fit_piecewise_hazard,  # noqa: E402
                                  from_log)


def _fit_from_args(args: argparse.Namespace) -> PiecewiseFit:
    durations = from_log(args.log, event=args.event,
                         time_field=args.time_field,
                         duration_field=args.duration_field,
                         entity_field=args.entity)
    return fit_piecewise_hazard(durations, n_bins=args.bins,
                                method=args.method)


def selftest() -> int:
    """Synthetic log -> fit -> JSON round trip -> short CTMC run."""
    import numpy as np

    rng = np.random.default_rng(7)
    # two-regime synthetic fleet: early failures at 1/40 min, then 1/400
    rows = []
    for server in range(40):
        t = 0.0
        for k in range(6):
            t += float(rng.exponential(40.0 if k < 2 else 400.0))
            rows.append((t, server))
    with tempfile.TemporaryDirectory() as td:
        log = Path(td) / "failures.csv"
        with log.open("w") as fh:
            fh.write("time,server\n")
            for t, server in sorted(rows):
                fh.write(f"{t:.4f},{server}\n")
        fit = fit_piecewise_hazard(from_log(log), n_bins=4)
    blob = json.loads(json.dumps(fit.to_json()))   # the full disk round trip
    rt = PiecewiseFit.from_json(blob)
    assert rt.edges == fit.edges and rt.rates == fit.rates, \
        "JSON round trip changed the fit"
    assert 0 < fit.mean < 1e6 and fit.rate > 0, f"bad fit mean {fit.mean}"

    p = Params(job_size=16, working_pool_size=24, spare_pool_size=4,
               warm_standbys=2, job_length=600.0,
               random_failure_rate=fit.rate,
               systematic_failure_rate=2.0 * fit.rate,
               failure_distribution="empirical",
               distribution_kwargs=fit.distribution_kwargs,
               histogram=None)
    engine = resolve_engine(p, "auto")
    assert engine == "ctmc", f"fitted hazard routed to {engine}, not ctmc"
    rep = run_replications(p, 64, engine="ctmc")
    tt = rep.stats["total_time"].mean
    assert tt > p.job_length, f"implausible total_time {tt}"
    print(f"fit_hazard selftest OK: {len(fit.rates)} segments, "
          f"mean={fit.mean:.1f} min, ctmc total_time={tt:.1f} min")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("log", nargs="?", help="CSV/JSONL event log")
    ap.add_argument("-o", "--out", help="write fit JSON here (default stdout)")
    ap.add_argument("--event", default=None,
                    help="keep only rows whose event/kind field equals this")
    ap.add_argument("--time-field", default="time")
    ap.add_argument("--duration-field", default="duration")
    ap.add_argument("--entity", default=None,
                    help="per-entity column for interarrival extraction "
                         "(auto-detected among server/host/node/entity/id)")
    ap.add_argument("--bins", type=int, default=8,
                    help="number of hazard segments to fit (default 8)")
    ap.add_argument("--method", default="nelson-aalen",
                    choices=("nelson-aalen", "binned"))
    ap.add_argument("--selftest", action="store_true",
                    help="run the synthetic round-trip smoke and exit")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()
    if not args.log:
        ap.error("an event log is required (or --selftest)")
    fit = _fit_from_args(args)
    blob = json.dumps(fit.to_json(), indent=2)
    if args.out:
        Path(args.out).write_text(blob + "\n")
        print(f"wrote {args.out}: {len(fit.rates)} segments, "
              f"mean={fit.mean:.2f}, rate={fit.rate:.6g}", file=sys.stderr)
    else:
        print(blob)
    return 0


if __name__ == "__main__":
    sys.exit(main())
