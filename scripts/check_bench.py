#!/usr/bin/env python
"""Bench regression gate: keep BENCH_sweep.json an enforced contract.

Two modes:

* ``--quick`` (default; what plain ``scripts/ci.sh`` and hosted CI run):
  re-measures a scaled-down warm-speedup A/B for the exponential
  baseline sweep and the repair-distribution sweep, then checks them
  against the *committed* BENCH_sweep.json with a generous tolerance
  band (small grids amortize fixed overhead worse and CI runners are
  noisy, so the quick gate catches collapses — a fast path silently
  falling back to the event engine — not percent-level drift).

* ``--fresh PATH`` (what ``scripts/ci.sh --bench`` runs after
  regenerating the artifact): compares a freshly measured full artifact
  against a baseline copy saved before the run, enforcing relative
  bands, the absolute speedup floors (the repair_dist entry's >= 5x
  acceptance criterion among them), exact compile-count invariants, and
  cross-engine agreement sanity.  ``--append-history`` then appends a
  timestamped one-line JSON record to BENCH_history.jsonl so the perf
  trajectory is machine-readable across PRs.

Exit status is nonzero on any violated gate; every gate prints a
PASS/FAIL line so the CI log reads as a checklist.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from datetime import datetime, timezone

#: absolute warm-speedup floors for the full artifact — the claims the
#: README/BENCH entries make, enforced rather than aspirational
FULL_SPEEDUP_FLOORS = {
    "speedup_x": 3.0,            # exponential baseline sweep
    "nonexp.speedup_x": 5.0,     # weibull failure grid
    "repair_dist.speedup_x": 5.0,   # repair-policy grid (acceptance)
    "empirical.speedup_x": 5.0,     # trace-driven hazard grid (acceptance)
    "correlated.speedup_x": 5.0,    # fault-domain scenario grid (acceptance)
    "multijob.speedup_x": 4.0,      # shared-pool capacity grid (acceptance)
    "checkpoint.speedup_x": 3.0,    # rollback interval grid (acceptance)
}

#: non-speedup numeric floors — the replica-sharding section reports
#: throughput *retention* ratios (forced host devices share physical
#: cores on CI, so weak-scaling efficiency ~1 is ideal and real speedup
#: needs real devices; docs/scaling.md); floors catch the sharded path
#: collapsing, not parallel hardware appearing
FULL_VALUE_FLOORS = {
    # sharded throughput per replica at D devices vs the 1-device mesh
    "sharded.min_weak_scaling_efficiency": 0.3,
    # 1-device sharded dispatch vs the unsharded engine (shard_map tax)
    "sharded.retention_1dev": 0.6,
}

#: exact compile-count invariants of the full artifact
FULL_COMPILE_GATES = {
    "structural.padded_compiles": 1,
    "bucketing.bucketed_compiles": 1,
    # segment count is the only static key: one program per fitted grid
    "empirical.sweep_compiles": 1,
    # the scenario's rates/times are traced: one program per shock grid
    "correlated.sweep_compiles": 1,
    # J is the only static key: one program per mixed-size capacity grid
    "multijob.sweep_compiles": 1,
    # interval and cost are traced columns: one program per interval grid
    "checkpoint.sweep_compiles": 1,
    # mesh is a static key: one sharded program per weak-scaling child
    "sharded.sweep_compiles": 1,
}

_FAILURES = []


def _gate(name: str, ok: bool, detail: str) -> None:
    print(f"[{'PASS' if ok else 'FAIL'}] {name}: {detail}")
    if not ok:
        _FAILURES.append(name)


def _lookup(doc: dict, dotted: str):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            stderr=subprocess.DEVNULL).decode().strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


# ---------------------------------------------------------------------------
# quick mode
# ---------------------------------------------------------------------------

def _quick_ab(base, parameter, values, n_replicas):
    """Warm CTMC wall vs event wall on a small grid (compile excluded)."""
    from repro.core import OneWaySweep

    kw = dict(n_replications=n_replicas, base_params=base, base_seed=0)
    ct = OneWaySweep("quick", parameter, values, engine="ctmc", **kw)
    ct.run()                                     # compile
    t0 = time.perf_counter()
    ct.run()
    ctmc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    OneWaySweep("quick", parameter, values, engine="event", **kw).run()
    event_s = time.perf_counter() - t0
    return event_s / max(ctmc_s, 1e-9)


def run_quick(baseline: dict, tolerance: float) -> None:
    import os
    # `python scripts/check_bench.py` puts scripts/ (not the repo root)
    # first on sys.path; the benchmarks package lives at the root
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.engine_perf import repair_bench_params, sweep_bench_params
    from repro.core import MINUTES_PER_DAY

    # exponential baseline sweep, quick scale (distinct max_run_records
    # keeps the jit cache entries from colliding with test runs)
    base = sweep_bench_params().replace(job_length=0.5 * MINUTES_PER_DAY,
                                        max_run_records=61)
    q_exp = _quick_ab(base, "recovery_time", [5.0, 15.0, 25.0, 35.0], 64)
    b_exp = baseline.get("speedup_x")
    # a missing baseline key must fail loudly: `>= tolerance * 0` would
    # otherwise pass unconditionally — exactly the silent-collapse
    # regression this gate exists to catch
    _gate("quick.exponential_speedup",
          b_exp is not None and q_exp >= tolerance * b_exp,
          f"measured {q_exp:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_exp is None else f'{b_exp:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the exact scenario the committed repair_dist entry measures
    # (shared factory — the gate and the baseline cannot drift apart),
    # shrunk to quick scale
    rbase = repair_bench_params().replace(
        job_length=0.5 * MINUTES_PER_DAY, max_run_records=62)
    q_rep = _quick_ab(rbase, "auto_repair_time", [30.0, 90.0, 150.0, 210.0],
                      64)
    b_rep = _lookup(baseline, "repair_dist.speedup_x")
    _gate("quick.repair_dist_speedup",
          b_rep is not None and q_rep >= tolerance * b_rep,
          f"measured {q_rep:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_rep is None else f'{b_rep:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the trace-driven empirical scenario (shared factory): a fitted-
    # style 3-segment hazard through the piecewise-constant sampler —
    # the gate that catches a log-fitted study silently collapsing back
    # onto the O(cluster)-per-restart event engine
    from benchmarks.engine_perf import empirical_bench_params

    ebase = empirical_bench_params().replace(
        job_length=0.5 * MINUTES_PER_DAY, max_run_records=65)
    q_emp = _quick_ab(ebase, "recovery_time", [5.0, 15.0, 25.0, 35.0], 64)
    b_emp = _lookup(baseline, "empirical.speedup_x")
    _gate("quick.empirical_speedup",
          b_emp is not None and q_emp >= tolerance * b_emp,
          f"measured {q_emp:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_emp is None else f'{b_emp:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the correlated-failure scenario (shared factory again): domain
    # shocks + a scripted kill + a maintenance window, swept over the
    # rack shock rate — the gate that catches the scenario race lanes
    # silently knocking the grid off the single-program fast path
    from benchmarks.engine_perf import correlated_bench_params

    cbase = correlated_bench_params(
        job_length=0.5 * MINUTES_PER_DAY).replace(max_run_records=63)
    q_cor = _quick_ab(cbase, "rack_shock_rate",
                      [5e-5, 1e-4, 1.5e-4, 2e-4], 64)
    b_cor = _lookup(baseline, "correlated.speedup_x")
    _gate("quick.correlated_speedup",
          b_cor is not None and q_cor >= tolerance * b_cor,
          f"measured {q_cor:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_cor is None else f'{b_cor:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the multi-job shared-pool scenario (shared factory, half job
    # length): a capacity grid through the compartment engine vs the
    # event-loop MultiJobSimulation — catches the multi-job path
    # silently recompiling per point or collapsing to the event oracle
    from benchmarks.engine_perf import multijob_bench_params

    q_mj = _quick_multijob_ab(*multijob_bench_params(job_length_scale=0.5),
                              n_replicas=64)
    b_mj = _lookup(baseline, "multijob.speedup_x")
    _gate("quick.multijob_speedup",
          b_mj is not None and q_mj >= tolerance * b_mj,
          f"measured {q_mj:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_mj is None else f'{b_mj:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the checkpoint-rollback scenario (shared factory, half job
    # length): an interval grid through the rollback lanes vs the event
    # engine's segment loop — catches the traced interval/cost axes
    # silently knocking the grid back onto the event fallback
    from benchmarks.engine_perf import checkpoint_bench_params

    kbase = checkpoint_bench_params().replace(
        job_length=0.5 * MINUTES_PER_DAY, max_run_records=66)
    q_ck = _quick_ab(kbase, "checkpoint_interval",
                     [15.0, 45.0, 80.0, 120.0], 64)
    b_ck = _lookup(baseline, "checkpoint.speedup_x")
    _gate("quick.checkpoint_speedup",
          b_ck is not None and q_ck >= tolerance * b_ck,
          f"measured {q_ck:.2f}x warm (4x64 grid) vs committed "
          f"{'MISSING' if b_ck is None else f'{b_ck:.2f}x'} (8x256); "
          f"floor {tolerance:.2f}x of committed")

    # the replica-sharded dispatch at mesh size 1: bit-identity is exact
    # (the contract, not a tolerance) and the shard_map tax must not
    # collapse throughput
    _quick_sharded(baseline, tolerance)


def _quick_sharded(baseline: dict, tolerance: float) -> None:
    """1-device-mesh retention + bit-identity, in-process (quick CI has
    one visible device; the multi-device curve is full-mode only)."""
    import numpy as np

    import repro.core.vectorized as vz
    from benchmarks.engine_perf import sweep_bench_params
    from repro.core import MINUTES_PER_DAY
    from repro.core.vectorized import default_max_steps

    base = sweep_bench_params().replace(job_length=0.5 * MINUTES_PER_DAY,
                                        max_run_records=67)
    pts = [base.replace(recovery_time=v)
           for v in (5.0, 15.0, 25.0, 35.0)]
    steps = max(default_max_steps(p) for p in pts)

    def run(shards):
        return vz.simulate_ctmc_sweep(pts, n_replicas=64, seed=0,
                                      max_steps=steps, shards=shards)

    sh = run(1)                                   # compile
    t0 = time.perf_counter()
    sh = run(1)
    sharded_s = time.perf_counter() - t0
    un = run(0)                                   # compile
    t0 = time.perf_counter()
    un = run(0)
    unsharded_s = time.perf_counter() - t0

    ident = all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                for a, b in zip(sh, un) for k in a)
    _gate("quick.sharded_mesh1_bitident", ident,
          "1-device mesh output identical to unsharded engine")
    q_ret = unsharded_s / max(sharded_s, 1e-9)
    b_ret = _lookup(baseline, "sharded.retention_1dev")
    _gate("quick.sharded_retention",
          b_ret is not None and q_ret >= tolerance * b_ret,
          f"measured {q_ret:.2f} retention (4x64 grid) vs committed "
          f"{'MISSING' if b_ret is None else f'{b_ret:.2f}'}; "
          f"floor {tolerance:.2f}x of committed")


def _quick_multijob_ab(cluster, jobs, n_replicas):
    """Warm multi-job CTMC wall vs the event oracle on a 4-point grid."""
    from benchmarks.engine_perf import multijob_capacity_grid
    from repro.core import run_multijob_batch

    grid = multijob_capacity_grid(
        cluster.replace(max_run_records=64),   # quick-unique jit shapes
        jobs, spares=(7, 9), shops=(3, 4))
    run_multijob_batch(grid, n_replicas, engine="ctmc", base_seed=0)
    t0 = time.perf_counter()
    run_multijob_batch(grid, n_replicas, engine="ctmc", base_seed=0)
    ctmc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_multijob_batch(grid, n_replicas, engine="event", base_seed=0)
    event_s = time.perf_counter() - t0
    return event_s / max(ctmc_s, 1e-9)


# ---------------------------------------------------------------------------
# full mode
# ---------------------------------------------------------------------------

def run_full(fresh: dict, baseline: dict, rel_tolerance: float) -> None:
    for key, floor in FULL_SPEEDUP_FLOORS.items():
        val = _lookup(fresh, key)
        _gate(f"full.{key}.floor", val is not None and val >= floor,
              f"{val if val is None else round(val, 2)}x >= {floor}x")
        base = _lookup(baseline, key)
        if base:
            ok = val is not None and val >= (1.0 - rel_tolerance) * base
            _gate(f"full.{key}.band", ok,
                  f"{val if val is None else round(val, 2)}x within "
                  f"{rel_tolerance:.0%} of baseline {round(base, 2)}x")
    for key, floor in FULL_VALUE_FLOORS.items():
        val = _lookup(fresh, key)
        _gate(f"full.{key}.floor", val is not None and val >= floor,
              f"{val if val is None else round(val, 3)} >= {floor}")
    val = _lookup(fresh, "sharded.mesh1_bitident")
    _gate("full.sharded.mesh1_bitident", val is True,
          f"1-device mesh bit-identical to unsharded engine: {val}")
    val = _lookup(fresh, "sharded.max_devices")
    _gate("full.sharded.max_devices", val is not None and val >= 4,
          f"weak-scaling curve reaches {val} forced host devices (>= 4)")
    for key, want in FULL_COMPILE_GATES.items():
        val = _lookup(fresh, key)
        # None = jit-cache introspection unavailable on this jax: the
        # count cannot be measured, which is not a regression
        _gate(f"full.{key}", val is None or val == want,
              f"{val} == {want} (None = unmeasurable, tolerated)")
    for sec in ("", "structural.", "nonexp.", "repair_dist.",
                "empirical.", "correlated.", "multijob.", "checkpoint."):
        key = f"{sec}max_abs_z"
        val = _lookup(fresh, key)
        _gate(f"full.{key}", val is not None and val < 4.0,
              f"cross-engine agreement |z| {val and round(val, 2)} < 4.0")


def append_history(fresh: dict, path: str) -> None:
    record = {
        "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "git": _git_sha(),
        "speedup_x": fresh.get("speedup_x"),
        "structural_warm_x": _lookup(
            fresh, "structural.padded_vs_per_structure_warm_x"),
        "structural_padded_compiles": _lookup(
            fresh, "structural.padded_compiles"),
        "bucketing_resize_x": _lookup(fresh, "bucketing.resize_speedup_x"),
        "bucketing_compiles": _lookup(fresh, "bucketing.bucketed_compiles"),
        "nonexp_speedup_x": _lookup(fresh, "nonexp.speedup_x"),
        "repair_dist_speedup_x": _lookup(fresh, "repair_dist.speedup_x"),
        "empirical_speedup_x": _lookup(fresh, "empirical.speedup_x"),
        "empirical_compiles": _lookup(fresh, "empirical.sweep_compiles"),
        "correlated_speedup_x": _lookup(fresh, "correlated.speedup_x"),
        "correlated_compiles": _lookup(fresh, "correlated.sweep_compiles"),
        "multijob_speedup_x": _lookup(fresh, "multijob.speedup_x"),
        "multijob_compiles": _lookup(fresh, "multijob.sweep_compiles"),
        "checkpoint_speedup_x": _lookup(fresh, "checkpoint.speedup_x"),
        "checkpoint_compiles": _lookup(fresh, "checkpoint.sweep_compiles"),
        "sharded_speedup_x": _lookup(fresh, "sharded.sharded_speedup_x"),
        "sharded_devices": _lookup(fresh, "sharded.max_devices"),
        "sharded_efficiency": _lookup(
            fresh, "sharded.min_weak_scaling_efficiency"),
        "sharded_compiles": _lookup(fresh, "sharded.sweep_compiles"),
    }
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")
    print(f"appended perf record to {path}: {record}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_sweep.json",
                    help="committed/saved baseline artifact")
    ap.add_argument("--fresh", default=None,
                    help="freshly measured artifact to gate (full mode)")
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down re-measurement vs the baseline "
                         "(default when --fresh is absent)")
    ap.add_argument("--quick-sharded", action="store_true",
                    help="only the replica-sharding quick gates "
                         "(mesh-1 bit-identity + retention) — what the "
                         "multi-device CI job runs")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="quick mode: fraction of the committed speedup "
                         "the small-grid measurement must reach")
    ap.add_argument("--rel-tolerance", type=float, default=0.5,
                    help="full mode: allowed relative drop vs baseline")
    ap.add_argument("--append-history", nargs="?", const="BENCH_history.jsonl",
                    default=None, help="append a timestamped record "
                    "(full mode, after the gates pass)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)

    if args.fresh:
        with open(args.fresh) as f:
            fresh = json.load(f)
        run_full(fresh, baseline, args.rel_tolerance)
        if not _FAILURES and args.append_history:
            append_history(fresh, args.append_history)
    elif args.quick_sharded:
        import os
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        _quick_sharded(baseline, args.tolerance)
    else:
        run_quick(baseline, args.tolerance)

    if _FAILURES:
        print(f"\nbench gate FAILED: {_FAILURES}", file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
