#!/usr/bin/env bash
# Tier-1 verification in one command (what the roadmap calls "tier-1
# verify"), plus the machine-readable sweep-performance artifact.
#
#   scripts/ci.sh           # tests + compile smokes + quick bench gate
#   scripts/ci.sh --bench   # also: full sweep benchmarks -> BENCH_sweep.json,
#                           #       gated against the committed baseline and
#                           #       appended to BENCH_history.jsonl
#
# Environment knobs (the hosted workflow sets these):
#   CI_ARTIFACTS_DIR  if set, write pytest junit XML + the smoke/bench
#                     output there for upload as CI artifacts
set -euo pipefail
cd "$(dirname "$0")/.." || exit 1

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# fail fast with a readable message when the pinned toolchain is broken
# (otherwise a bad jax install surfaces as a wall of pytest collection
# errors with the real cause buried)
if ! python - <<'EOF'
import sys
try:
    import jax, jaxlib, numpy, scipy  # noqa: F401
except Exception as exc:  # pragma: no cover - the readable-failure path
    print(f"TOOLCHAIN BROKEN: cannot import the pinned stack: {exc!r}",
          file=sys.stderr)
    sys.exit(1)
print(f"toolchain: python {sys.version.split()[0]}, jax {jax.__version__}, "
      f"jaxlib {jaxlib.__version__}, numpy {numpy.__version__}, "
      f"scipy {scipy.__version__}")
EOF
then
    echo "scripts/ci.sh: aborting before pytest — fix the environment" \
         "(see the import error above; the pins live in" \
         ".github/workflows/ci.yml)" >&2
    exit 1
fi

JUNIT_ARGS=()
if [[ -n "${CI_ARTIFACTS_DIR:-}" ]]; then
    mkdir -p "${CI_ARTIFACTS_DIR}"
    JUNIT_ARGS=(--junitxml="${CI_ARTIFACTS_DIR}/junit.xml")
fi

# -p no:randomly pins collection order if pytest-randomly is ever
# installed, so the tier-1 pass is reproducible run to run.
# (the ${arr[@]+...} guard keeps the empty-array expansion legal under
# `set -u` on bash <= 4.3 — stock macOS bash 3.2 included)
python -m pytest -x -q -p no:randomly ${JUNIT_ARGS[@]+"${JUNIT_ARGS[@]}"}

# public-API doctests: the runnable examples in the core docstrings
# (Params, HistogramSpec, run_replications_batch, the sweep classes,
# vectorized.supports) are executable documentation — they fail here
# the moment the API drifts from what docs/ promises
python -m pytest -q -p no:randomly -p no:cacheprovider --doctest-modules \
    src/repro/core/params.py src/repro/core/histograms.py \
    src/repro/core/backend.py src/repro/core/sweeps.py \
    src/repro/core/vectorized.py src/repro/core/hazards.py \
    src/repro/core/faultdomains.py src/repro/core/empirical.py \
    src/repro/parallel/sharding.py src/repro/kernels/ops.py

# docs suite link check: every relative markdown link in README/docs
# must resolve to a real file (no network; scheme links are skipped)
python scripts/check_links.py

# ordering-independence check (--lf-safe): the distribution/bucketing/
# non-exponential/multi-job/checkpoint suites must pass rerun standalone
# with a cold pytest cache — exactly what a `pytest --lf` retry after a
# failure would run
python -m pytest -q -p no:randomly -p no:cacheprovider \
    tests/test_histograms.py tests/test_bucketing.py tests/test_nonexp.py \
    tests/test_repair_dist.py tests/test_faultdomains.py \
    tests/test_multijob_parity.py tests/test_empirical.py \
    tests/test_checkpoint_opt.py

# replica-sharding parity on a forced 4-device CPU mesh: the per-shard
# independence contract, exact histogram/ring-buffer merges, and the
# sharded compile invariant all need >= 4 visible devices, which must
# be forced via XLA_FLAGS *before* jax imports — hence a fresh
# interpreter rather than a pytest lane of the tier-1 run above
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -q -p no:randomly -p no:cacheprovider \
    tests/test_replica_sharding.py

# trace-driven fitting smoke: synthetic log -> fit_piecewise_hazard ->
# JSON round trip -> a short CTMC study from the fitted hazard
python scripts/fit_hazard.py --selftest

# compile-count smokes: a tiny mixed-structure grid must compile exactly
# one XLA program per padded group, two same-bucket sweeps of different
# (P, R, step-budget) must share exactly one program, a
# repair-parameter grid under non-exponential repairs must compile
# once, a mixed-size multi-job capacity grid must compile once
# (J is the only static key), and a traced (checkpoint_interval x
# checkpoint_cost) grid must compile once — plus a golden-section
# optimizer micro-run pinning its bounds/eval-count contract; exits
# nonzero on any regression.
if [[ -n "${CI_ARTIFACTS_DIR:-}" ]]; then
    python benchmarks/engine_perf.py --smoke \
        | tee "${CI_ARTIFACTS_DIR}/bench_smoke.json"
else
    python benchmarks/engine_perf.py --smoke
fi

# bench regression gate, quick mode: scaled-down warm-speedup
# measurements against the committed BENCH_sweep.json baselines (loose
# tolerance — catches a fast path silently collapsing, not noise)
python scripts/check_bench.py --quick

if [[ "${1:-}" == "--bench" ]]; then
    # full benchmarks regenerate BENCH_sweep.json; gate the fresh
    # numbers against the pre-run baseline and append the perf record
    BASELINE="$(mktemp)"
    cp BENCH_sweep.json "${BASELINE}"
    python benchmarks/engine_perf.py
    python scripts/check_bench.py --baseline "${BASELINE}" \
        --fresh BENCH_sweep.json --append-history BENCH_history.jsonl
    rm -f "${BASELINE}"
    if [[ -n "${CI_ARTIFACTS_DIR:-}" ]]; then
        cp BENCH_sweep.json "${CI_ARTIFACTS_DIR}/BENCH_sweep.json"
    fi
fi
