#!/usr/bin/env bash
# Tier-1 verification in one command (what the roadmap calls "tier-1
# verify"), plus the machine-readable sweep-performance artifact.
#
#   scripts/ci.sh           # tests + compile smokes (structure + bucketing)
#   scripts/ci.sh --bench   # also: full sweep benchmarks -> BENCH_sweep.json
#                           #       (incl. the "bucketing" section)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# -p no:randomly pins collection order if pytest-randomly is ever
# installed, so the tier-1 pass is reproducible run to run
python -m pytest -x -q -p no:randomly

# public-API doctests: the runnable examples in the core docstrings
# (Params, HistogramSpec, run_replications_batch, the sweep classes,
# vectorized.supports) are executable documentation — they fail here
# the moment the API drifts from what docs/ promises
python -m pytest -q -p no:randomly -p no:cacheprovider --doctest-modules \
    src/repro/core/params.py src/repro/core/histograms.py \
    src/repro/core/backend.py src/repro/core/sweeps.py \
    src/repro/core/vectorized.py src/repro/core/hazards.py

# docs suite link check: every relative markdown link in README/docs
# must resolve to a real file (no network; scheme links are skipped)
python scripts/check_links.py

# ordering-independence check (--lf-safe): the distribution/bucketing/
# non-exponential suites must pass rerun standalone with a cold pytest
# cache — exactly what a `pytest --lf` retry after a failure would run
python -m pytest -q -p no:randomly -p no:cacheprovider \
    tests/test_histograms.py tests/test_bucketing.py tests/test_nonexp.py

# compile-count smokes: a tiny mixed-structure grid must compile exactly
# one XLA program per padded group, and two same-bucket sweeps of
# different (P, R, step-budget) must share exactly one program; exits
# nonzero on either regression.
python benchmarks/engine_perf.py --smoke

if [[ "${1:-}" == "--bench" ]]; then
    python benchmarks/engine_perf.py
fi
