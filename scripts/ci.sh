#!/usr/bin/env bash
# Tier-1 verification in one command (what the roadmap calls "tier-1
# verify"), plus the machine-readable sweep-performance artifact.
#
#   scripts/ci.sh           # tests only
#   scripts/ci.sh --bench   # tests + sweep benchmark -> BENCH_sweep.json
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

if [[ "${1:-}" == "--bench" ]]; then
    python benchmarks/engine_perf.py
fi
