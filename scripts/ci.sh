#!/usr/bin/env bash
# Tier-1 verification in one command (what the roadmap calls "tier-1
# verify"), plus the machine-readable sweep-performance artifact.
#
#   scripts/ci.sh           # tests + structural-sweep compile smoke
#   scripts/ci.sh --bench   # also: full sweep benchmarks -> BENCH_sweep.json
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# structural-sweep benchmark in smoke mode: a tiny mixed-structure grid
# must compile exactly one XLA program per padded group; exits nonzero
# on a compile-count regression.
python benchmarks/engine_perf.py --smoke

if [[ "${1:-}" == "--bench" ]]; then
    python benchmarks/engine_perf.py
fi
