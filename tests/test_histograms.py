"""Streaming histogram telemetry: cross-engine distribution agreement.

The CTMC scan accumulates log-spaced run-duration / recovery / waiting
histograms with no run-count bound; the event engine fills the identical
bin layout from its per-run Python lists (the pure-numpy reference
accumulator in ``core.histograms``).  These tests pin:

  * exact-count invariants — every recorded run lands in exactly one bin,
    so histogram totals equal ``n_runs`` even deep in the regime where
    the ``max_run_records`` ring buffer truncates;
  * cross-engine agreement — the event engine's empirical CDF and
    percentiles match the CTMC histogram within bin resolution on pinned
    seeds (the acceptance criterion: p50/p90/p99 within one bin width on
    a 64-replica config whose run count overflows the ring buffer);
  * spec plumbing — channel subsetting, ``histogram=None`` compiling the
    accumulator out, and dict round trips.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import (Histogram, HistogramSpec, OneWaySweep, Params,
                        aggregate, histograms_from_arrays,
                        histograms_from_results, run_replications, simulate)
from repro.core.vectorized import simulate_ctmc

#: pinned acceptance config: ~100 runs/replica >> max_run_records=16,
#: so the ring buffer truncates and the histogram is the only unbounded
#: distribution record
BASE = Params(job_size=24, working_pool_size=32, spare_pool_size=4,
              warm_standbys=2, job_length=2 * DAY,
              random_failure_rate=2.0 / DAY, recovery_time=5.0,
              auto_repair_time=30.0, manual_repair_time=120.0, seed=5,
              max_run_records=16)


# ---------------------------------------------------------------------------
# exact-count invariants
# ---------------------------------------------------------------------------

def test_histogram_counts_equal_n_runs_under_ring_buffer_overflow():
    out = simulate_ctmc(BASE, n_replicas=64, seed=2)
    # the interesting regime: every replica overflowed the ring buffer
    assert (out["n_runs"] > BASE.max_run_records).all()
    per_replica = out["hist_run_duration"].sum(axis=1)
    np.testing.assert_array_equal(per_replica, out["n_runs"])
    # no bin count is negative and the grand total is exact
    assert (out["hist_run_duration"] >= 0).all()
    assert out["hist_run_duration"].sum() == out["n_runs"].sum()


def test_recovery_and_waiting_counts_track_failures():
    out = simulate_ctmc(BASE, n_replicas=64, seed=7)
    rec = out["hist_recovery"].sum(axis=1)
    wait = out["hist_waiting"].sum(axis=1)
    # one downtime + one waiting record per *resolved* failure; a stall
    # pending at scan end is the only failure that can be unrecorded
    np.testing.assert_array_equal(rec, wait)
    assert (rec <= out["n_failures"]).all()
    done = out["completed"] > 0
    assert done.any()
    # a completed job cannot end stalled, so every failure was resolved
    np.testing.assert_array_equal(rec[done], out["n_failures"][done])


def test_run_duration_histogram_sums_to_useful_time_within_bins():
    """Histogram mass sits in the right bins: reconstructing the total
    from bin bounds brackets the exact recorded time."""
    out = simulate_ctmc(BASE, n_replicas=32, seed=3)
    h = histograms_from_arrays(out)["run_duration"]
    lo = np.concatenate([[0.0], h.edges[:-1], [h.edges[-1]]])
    recorded = (out["useful_work"] + out["lost_work"] - out["cur_run"]).sum()
    assert (h.counts * lo).sum() <= recorded * (1 + 1e-5)
    assert h.counts[-1] == 0, "no run can exceed the 19-year top edge here"
    hi = np.concatenate([h.edges, [h.edges[-1]]])
    assert (h.counts * hi).sum() >= recorded * (1 - 1e-5)


# ---------------------------------------------------------------------------
# cross-engine agreement (acceptance criterion)
# ---------------------------------------------------------------------------

def test_ctmc_histogram_percentiles_match_event_engine_within_one_bin():
    """p50/p90/p99 of run duration from the CTMC histogram vs the event
    engine's exact empirical percentiles, pinned seeds, ring buffer
    overflowing — each within one bin width."""
    out = simulate_ctmc(BASE, n_replicas=64, seed=2)
    assert (out["n_runs"] > BASE.max_run_records).all()
    h = histograms_from_arrays(out)["run_duration"]
    pool = np.concatenate([r.run_durations for r in simulate(BASE, 64)])
    assert len(pool) > 1000 and h.total > 1000
    for q in (50, 90, 99):
        emp = float(np.percentile(pool, q))
        est = h.percentile(q)
        assert abs(est - emp) <= h.bin_width_at(emp), (q, est, emp)


def test_cross_engine_cdf_agreement_within_bin_resolution():
    """Empirical (event) CDF vs CTMC histogram CDF over the shared bin
    layout: sup distance at sampling-error scale, far below 1."""
    out = simulate_ctmc(BASE, n_replicas=64, seed=2)
    hc = histograms_from_arrays(out)
    he = histograms_from_results(simulate(BASE, 64), BASE.histogram)
    for ch in ("run_duration", "recovery"):
        sup = np.abs(hc[ch].cdf() - he[ch].cdf()).max()
        assert sup < 0.08, (ch, sup)
    # both engines put the standby-swap zeros in the waiting underflow
    wc, we = hc["waiting"], he["waiting"]
    assert wc.counts[0] > 0 and we.counts[0] > 0
    assert abs(wc.counts[0] / wc.total - we.counts[0] / we.total) < 0.08


def test_per_replica_dispersion_stats_both_engines():
    """Cross-replica spread of distribution tails: each replica's own
    p99 (binned identically on both engines) aggregates into a
    ``{channel}_p99_replica`` Stat whose .iqr is the p99 IQR across
    replicas."""
    from repro.core.histograms import percentiles_per_row

    rc = run_replications(BASE, 64, engine="ctmc")
    re_ = run_replications(BASE.replace(job_length=0.5 * DAY), 16,
                           engine="event")
    for rep in (rc, re_):
        for ch in ("run_duration", "recovery", "waiting"):
            st = rep.stats[f"{ch}_p99_replica"]
            assert np.isfinite(st.mean)
            assert np.isfinite(st.iqr) and st.iqr >= 0.0
            # per-replica p99 estimates can never exceed the pooled
            # histogram's top edge
            assert st.maximum <= rep.histograms[ch].edges[-1] + 1e-9
    # the CTMC stat is the vectorized per-row percentile of the raw
    # per-replica counts
    arr = rc.arrays["hist_run_duration"]
    per = percentiles_per_row(rc.arrays["hist_edges"], arr, 99)
    st = rc.stats["run_duration_p99_replica"]
    assert st.mean == pytest.approx(np.nanmean(per))
    # replicas genuinely disagree about their tail in this config
    assert st.iqr > 0.0
    # histogram=None compiles the stat away
    off = run_replications(BASE.replace(histogram=None), 8, engine="ctmc")
    assert "run_duration_p99_replica" not in off.stats


def test_dist_stats_surface_through_replications_both_engines():
    rc = run_replications(BASE, 64, engine="ctmc")
    re_ = run_replications(BASE.replace(job_length=0.5 * DAY), 8,
                           engine="event")
    for rep in (rc, re_):
        assert set(rep.histograms) == set(BASE.histogram.channels)
        for ch in BASE.histogram.channels:
            st = rep.stats[f"{ch}_dist"]
            assert np.isfinite(st.percentiles[50])
            assert st.percentiles[99.9] >= st.percentiles[50]


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

SHORT = BASE.replace(job_length=0.25 * DAY)


def test_channel_subsetting_filters_outputs():
    p = SHORT.replace(histogram=HistogramSpec(channels=("run_duration",)))
    out = simulate_ctmc(p, n_replicas=8, seed=1)
    assert "hist_run_duration" in out and "hist_edges" in out
    assert "hist_recovery" not in out and "hist_waiting" not in out
    rep = run_replications(p, 8, engine="ctmc")
    assert set(rep.histograms) == {"run_duration"}
    assert "recovery_dist" not in rep.stats


def test_channel_subsetting_shrinks_scan_state():
    """Unselected channels are compiled out of the scan carry: the
    in-scan accumulator allocates one lane per *selected* channel, and
    the kept channel's counts are unchanged bit for bit."""
    from repro.core.vectorized import _initial_state

    sub = SHORT.replace(histogram=HistogramSpec(channels=("recovery",)))
    state = _initial_state(sub, 4)
    full = _initial_state(SHORT, 4)
    assert state["hist"].shape == (4, 1, sub.histogram.n_counts)
    assert full["hist"].shape == (4, 3, SHORT.histogram.n_counts)
    # identical trajectory, identical kept-channel counts
    a = simulate_ctmc(sub, n_replicas=16, seed=3)
    b = simulate_ctmc(SHORT, n_replicas=16, seed=3)
    np.testing.assert_array_equal(a["hist_recovery"], b["hist_recovery"])
    np.testing.assert_array_equal(a["total_time"], b["total_time"])
    # an empty channel tuple behaves like histogram=None inside the scan
    none_ch = SHORT.replace(histogram=HistogramSpec(channels=()))
    assert "hist" not in _initial_state(none_ch, 4)
    out = simulate_ctmc(none_ch, n_replicas=8, seed=1)
    assert not any(k.startswith("hist") for k in out)


def test_histogram_none_compiles_accumulator_out():
    p = SHORT.replace(histogram=None)
    out = simulate_ctmc(p, n_replicas=8, seed=1)
    assert not any(k.startswith("hist") for k in out)
    on = simulate_ctmc(SHORT, n_replicas=8, seed=1)
    # recording never perturbs the trajectory itself
    np.testing.assert_array_equal(out["n_failures"], on["n_failures"])
    np.testing.assert_array_equal(out["total_time"], on["total_time"])
    rep = run_replications(p, 8, engine="ctmc")
    assert rep.histograms == {}
    assert "run_duration_dist" not in rep.stats


def test_spec_round_trips_through_params_dict():
    p = BASE.replace(histogram=HistogramSpec(low=0.5, high=1e5, n_bins=32,
                                             channels=["run_duration"]))
    q = Params.from_dict(p.to_dict())
    assert q.histogram == p.histogram
    assert isinstance(q.histogram.channels, tuple)
    assert Params.from_dict(BASE.replace(histogram=None).to_dict()) \
        .histogram is None


def test_mixed_spec_grid_rejected_on_ctmc_sweep():
    """The batch shares one in-scan accumulator layout, so a grid mixing
    histogram specs must be rejected loudly, never silently resolved to
    the first point's spec."""
    from repro.core.vectorized import simulate_ctmc_sweep

    for other in (None, HistogramSpec(n_bins=16)):
        grid = [SHORT, SHORT.replace(histogram=other)]
        with pytest.raises(ValueError, match="same\\s+Params.histogram"):
            simulate_ctmc_sweep(grid, n_replicas=4, max_steps=64)


def test_spec_validation():
    with pytest.raises(ValueError, match="0 < low < high"):
        Params(histogram=HistogramSpec(low=10.0, high=1.0)).validate()
    with pytest.raises(ValueError, match="n_bins"):
        Params(histogram=HistogramSpec(n_bins=0)).validate()
    with pytest.raises(ValueError, match="unknown histogram channels"):
        Params(histogram=HistogramSpec(channels=("ettf",))).validate()


def test_sweep_rows_carry_percentile_columns(tmp_path):
    sweep = OneWaySweep("h", "recovery_time", [5.0, 15.0],
                        n_replications=8, base_params=BASE.replace(
                            job_length=0.25 * DAY))
    res = sweep.run()
    row = res.to_rows()[0]
    for ch in ("run_duration", "recovery", "waiting"):
        for q in (50, 90, 99):
            assert f"{ch}_p{q}" in row
    assert row["run_duration_p50"] > 0
    path = str(tmp_path / "h.csv")
    res.write_csv(path)
    with open(path) as f:
        header = f.readline()
    assert "run_duration_p99" in header and "recovery_p50" in header


def test_event_engine_histograms_via_aggregate():
    results = simulate(BASE.replace(job_length=0.5 * DAY), 4)
    stats = aggregate(results, histogram=BASE.histogram)
    # per-failure downtime records exist and include the recovery reload
    assert all(len(r.recovery_durations) == r.n_failures for r in results)
    assert all(len(r.waiting_durations) == r.n_failures for r in results)
    assert all(min(r.recovery_durations, default=BASE.recovery_time)
               >= BASE.recovery_time - 1e-9 for r in results)
    assert stats["recovery_dist"].percentiles[50] >= BASE.recovery_time - 1e-9
    # without a spec, aggregate stays dist-free (backwards compatible)
    assert "recovery_dist" not in aggregate(results)
