"""Trace-driven empirical hazards: fitting layer + CTMC fast path.

Pins the PR's acceptance criteria end to end:

  * the fitting layer turns event logs / MTTF tables into valid
    piecewise-constant segments (Nelson–Aalen and binned estimators);
  * ``hazard_kind``/``repair_kind`` dispatch the ``empirical`` family,
    collapse a single-segment builtin to the exponential program, and
    refuse degenerate segment sets (event-engine fallback);
  * cross-engine parity on pinned seeds: z<3.5 means, histogram
    percentiles within one bin, and a hazard *fitted from a timestamped
    failure log* runs on the CTMC engine in agreement with the oracle;
  * an N-point grid over different fitted edges/rates compiles as ONE
    XLA program (segment count is the only static key);
  * a single-segment empirical hazard is bit-identical to the
    exponential program, on both the failure and repair sides;
  * satellites: every degenerate hazard/repair parameterization falls
    back to the event engine and still completes; re-registered builtin
    names route off the fast path; scipy absence warns once; the
    ``engine="ctmc"`` refusals name the *actual* exclusion reasons.
"""

import json
import sys
import warnings

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import (Params, register_distribution, resolve_engine,
                        resolve_engine_multijob, simulate)
from repro.core.distributions import _REGISTRY, Distribution, Weibull
from repro.core.empirical import (Empirical, PiecewiseFit,
                                  fit_piecewise_hazard, from_log,
                                  from_mttf_table, segments_mean,
                                  validate_segments)
from repro.core.hazards import (_scipy_available, hazard_kind,
                                hazard_segment_count, repair_kind,
                                repair_segment_count)
from repro.core.metrics import histograms_from_arrays
from repro.core.multijob import JobSpec
from repro.core.vectorized import (default_max_steps, simulate_ctmc,
                                   simulate_ctmc_sweep, supports,
                                   unsupported_reasons)

N_EVENT = 40
N_CTMC = 768

BASE = dict(job_size=24, working_pool_size=32, spare_pool_size=4,
            warm_standbys=2, job_length=2 * DAY,
            random_failure_rate=2.0 / DAY,
            systematic_failure_rate=4.0 / DAY, recovery_time=5.0,
            auto_repair_time=30.0, manual_repair_time=120.0, seed=5)

#: shape whose hazard genuinely varies over the ages the job visits:
#: edges land at ~0.4x and ~1.9x the configured mean after rescaling
EMP_SHAPE = {"edges": [0.4, 2.0], "rates": [0.3, 1.5, 0.7]}
EMPIRICAL = Params(failure_distribution="empirical",
                   distribution_kwargs=EMP_SHAPE, **BASE)
EMP_REPAIR = Params(repair_distribution="empirical",
                    distribution_kwargs={"edges": [0.5],
                                         "rates": [0.1, 2.0]}, **BASE)


def compare(p, metrics, n_event=N_EVENT, n_ctmc=N_CTMC, z_tol=3.5):
    out = simulate_ctmc(p, n_replicas=n_ctmc, seed=0)
    assert out["completed"].mean() > 0.99, "CTMC replicas did not finish"
    res = simulate(p, n_event)
    for m in metrics:
        ev = np.array([getattr(r, m) for r in res], float)
        ct = out[m]
        se = np.sqrt(ct.std() ** 2 / len(ct) + ev.std(ddof=1) ** 2 / len(ev))
        z = (ev.mean() - ct.mean()) / max(se, 1e-9)
        assert abs(z) < z_tol, (m, ev.mean(), ct.mean(), z)
    return out, res


# ---------------------------------------------------------------------------
# fitting / ingestion layer
# ---------------------------------------------------------------------------

def test_fit_flat_hazard_recovers_exponential_rate():
    rng = np.random.default_rng(0)
    d = rng.exponential(100.0, size=4000)
    for method in ("nelson-aalen", "binned"):
        fit = fit_piecewise_hazard(d, n_bins=5, method=method)
        assert validate_segments(fit.edges, fit.rates)
        assert fit.n_events == 4000 and fit.method == method
        # a flat hazard at ~1/100 in every segment, mean ~100
        assert np.allclose(fit.rates, 0.01, rtol=0.25), fit.rates
        assert 80.0 < fit.mean < 125.0, fit.mean


def test_fit_two_regime_hazard_sees_both_levels():
    rng = np.random.default_rng(1)
    # infant regime: rate 1/20 until ~40, then 1/400
    d = np.where(rng.random(6000) < 0.6, rng.exponential(20.0, 6000),
                 40.0 + rng.exponential(400.0, 6000))
    fit = fit_piecewise_hazard(d, n_bins=6)
    assert fit.rates[0] > 4 * fit.rates[-1], fit.rates


def test_fit_round_trips_through_json_and_params(tmp_path):
    fit = fit_piecewise_hazard(
        np.random.default_rng(2).exponential(50.0, 500), n_bins=4)
    blob = json.dumps(fit.to_json())
    rt = PiecewiseFit.from_json(json.loads(blob))
    assert rt.edges == fit.edges and rt.rates == fit.rates
    p = Params(**BASE, failure_distribution="empirical",
               distribution_kwargs=fit.distribution_kwargs)
    p.validate()
    assert hazard_kind(p) in ("empirical", "exponential")


def test_from_log_csv_and_jsonl(tmp_path):
    csvp = tmp_path / "events.csv"
    csvp.write_text("time,server,event\n10,a,failure\n30,a,failure\n"
                    "5,b,failure\n45,b,failure\n12,b,repair\n")
    d = from_log(csvp, event="failure")
    assert sorted(d) == [20.0, 40.0]          # per-entity interarrivals

    jp = tmp_path / "events.jsonl"
    jp.write_text('{"duration": 12.5}\n{"duration": 30.0}\n')
    assert sorted(from_log(jp)) == [12.5, 30.0]

    with pytest.raises(ValueError):
        empty = tmp_path / "empty.csv"
        empty.write_text("time,server\n")
        from_log(empty)


def test_from_mttf_table_and_empirical_distribution_sampling():
    edges, rates = from_mttf_table([0.0, 100.0, 500.0],
                                   [50.0, 200.0, 100.0])
    assert list(edges) == [100.0, 500.0]
    assert np.allclose(rates, [1 / 50, 1 / 200, 1 / 100])
    dist = Empirical(mean_value=300.0, edges=tuple(edges),
                     rates=tuple(rates))
    rng = np.random.default_rng(3)
    xs = np.array([dist.sample(rng) for _ in range(4000)])
    assert abs(xs.mean() - 300.0) < 4 * xs.std() / np.sqrt(len(xs))
    seg = dist.hazard_segments()
    assert seg is not None
    assert abs(segments_mean(*seg) - 300.0) / 300.0 < 1e-6


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_empirical_dispatch_and_segment_counts():
    assert hazard_kind(EMPIRICAL) == "empirical"
    assert hazard_segment_count(EMPIRICAL) == 3
    assert repair_kind(EMP_REPAIR) == "empirical"
    assert repair_segment_count(EMP_REPAIR) == 2
    assert supports(EMPIRICAL) and supports(EMP_REPAIR)
    assert resolve_engine(EMPIRICAL, "auto") == "ctmc"
    assert resolve_engine(EMP_REPAIR, "auto") == "ctmc"


def test_single_segment_collapses_to_exponential_kind():
    one = Params(**BASE, failure_distribution="empirical",
                 distribution_kwargs={"rates": [2.0]})
    assert hazard_kind(one) == "exponential"
    rone = Params(**BASE, repair_distribution="empirical",
                  distribution_kwargs={"rates": [0.7]})
    assert repair_kind(rone) == "exponential"


def test_degenerate_segments_fall_off_the_fast_path():
    dup = Params(**BASE, failure_distribution="empirical",
                 distribution_kwargs={"edges": [60.0, 60.0],
                                      "rates": [1.0, 2.0, 3.0]})
    assert hazard_kind(dup) is None and not supports(dup)
    neg = Params(**BASE, failure_distribution="empirical",
                 distribution_kwargs={"edges": [60.0],
                                      "rates": [1.0, -2.0]})
    assert hazard_kind(neg) is None
    # defective hazard (terminal rate 0): repair slots could wedge on an
    # infinite quantile — event engine only
    defective = Params(**BASE, repair_distribution="empirical",
                       distribution_kwargs={"edges": [60.0],
                                            "rates": [1.0, 0.0]})
    assert repair_kind(defective) is None


def test_hazard_segments_protocol_opts_registered_dist_onto_fast_path():
    class StepDist(Distribution):
        def __init__(self, mean_value):
            self.mean_value = mean_value

        def sample(self, rng):
            return float(rng.exponential(self.mean_value))

        def hazard_segments(self):
            r = 1.0 / self.mean_value
            return (np.array([self.mean_value]),
                    np.array([0.5 * r, 2.0 * r]))

        @property
        def mean(self):
            return self.mean_value

    register_distribution("stepdist", lambda mean, **_: StepDist(mean))
    try:
        p = Params(**BASE, failure_distribution="stepdist")
        # protocol families never collapse to exponential (their rates
        # have no guaranteed tie to the params rate) — always empirical
        assert hazard_kind(p) == "empirical"
        assert hazard_segment_count(p) == 2
        assert supports(p)
        out = simulate_ctmc(p, n_replicas=32, seed=0)
        assert out["completed"].mean() > 0.99
    finally:
        _REGISTRY.pop("stepdist", None)


# ---------------------------------------------------------------------------
# cross-engine parity (acceptance criteria)
# ---------------------------------------------------------------------------

def test_empirical_failures_match_event_oracle():
    compare(EMPIRICAL, ["total_time", "n_failures", "n_random_failures",
                        "n_systematic_failures", "n_auto_repairs",
                        "n_manual_repairs", "recovery_overhead",
                        "useful_work"])


def test_empirical_repairs_match_event_oracle():
    compare(EMP_REPAIR, ["total_time", "n_failures", "n_auto_repairs",
                         "n_manual_repairs", "stall_time",
                         "recovery_overhead"])


def test_empirical_histogram_percentiles_within_one_bin_of_oracle():
    out, res = compare(EMPIRICAL, ["total_time"], n_event=64, n_ctmc=512)
    hc = histograms_from_arrays(out)["run_duration"]
    pool = np.concatenate([r.run_durations for r in res])
    assert hc.total > 1000 and len(pool) > 1000
    for q in (50, 90, 99):
        emp = float(np.percentile(pool, q))
        est = hc.percentile(q)
        assert abs(est - emp) <= hc.bin_width_at(emp), (q, est, emp)


def test_hazard_fitted_from_timestamped_log_runs_on_ctmc(tmp_path):
    """The PR's headline path: timestamped CSV -> fit -> CTMC parity."""
    rng = np.random.default_rng(11)
    rows = []
    for server in range(60):
        t = 0.0
        for k in range(5):
            t += float(rng.exponential(200.0 if k < 1 else 900.0))
            rows.append((t, f"s{server}"))
    log = tmp_path / "failures.csv"
    with log.open("w") as fh:
        fh.write("time,server\n")
        for t, server in sorted(rows):
            fh.write(f"{t:.3f},{server}\n")
    fit = fit_piecewise_hazard(from_log(log), n_bins=4)
    p = Params(**dict(BASE, random_failure_rate=fit.rate,
                      systematic_failure_rate=2.0 * fit.rate),
               failure_distribution="empirical",
               distribution_kwargs=fit.distribution_kwargs)
    assert resolve_engine(p, "auto") == "ctmc"
    compare(p, ["total_time", "n_failures", "useful_work"], n_event=30)


# ---------------------------------------------------------------------------
# compile sharing + bit-identical reductions (acceptance criteria)
# ---------------------------------------------------------------------------

def test_edge_and_rate_grid_compiles_once():
    from repro.core import vectorized

    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    short = dict(BASE, job_length=0.25 * DAY)
    grid = [Params(failure_distribution="empirical",
                   distribution_kwargs={"edges": [0.3 + 0.1 * i, 2.0 + i],
                                        "rates": [0.4, 1.2 + 0.2 * i, 0.8]},
                   **short).replace(max_run_records=17)   # module-unique
            for i in range(4)]
    assert {hazard_segment_count(p) for p in grid} == {3}
    c0 = vectorized.compile_cache_size()
    res = simulate_ctmc_sweep(grid, n_replicas=12, seed=0, max_steps=2048)
    c1 = vectorized.compile_cache_size()
    assert c1 - c0 == 1, "an empirical edges/rates grid must share " \
        "one program (segment count is the only static key)"
    assert len(res) == 4


def test_single_segment_empirical_bit_identical_to_exponential():
    base = dict(BASE, max_run_records=17)
    p_exp = Params(**base)
    p_emp = Params(**base, failure_distribution="empirical",
                   distribution_kwargs={"rates": [3.0]})
    a = simulate_ctmc(p_exp, n_replicas=64, seed=3)
    b = simulate_ctmc(p_emp, n_replicas=64, seed=3)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # repair side: one segment == memoryless stage at rate 1/mean
    p_rem = Params(**base, repair_distribution="empirical",
                   distribution_kwargs={"rates": [1.0]})
    c = simulate_ctmc(p_rem, n_replicas=64, seed=3)
    for k in a:
        np.testing.assert_array_equal(a[k], c[k], err_msg=k)


def test_peak_segment_rate_budgets_more_steps():
    # a NARROW peak segment: mean rescaling normalizes the overall
    # level, so only a peak that is brief relative to the mean raises
    # the peak-to-average ratio the step budget keys on
    lo = Params(**BASE, failure_distribution="empirical",
                distribution_kwargs={"edges": [0.1], "rates": [1.0, 0.9]})
    hi = Params(**BASE, failure_distribution="empirical",
                distribution_kwargs={"edges": [0.1], "rates": [8.0, 0.9]})
    assert default_max_steps(hi) > default_max_steps(lo)


# ---------------------------------------------------------------------------
# satellite: degenerate parameterizations -> event engine, still complete
# ---------------------------------------------------------------------------

_TINY = dict(job_size=2, working_pool_size=3, spare_pool_size=1,
             warm_standbys=0, job_length=30.0, random_failure_rate=0.01,
             systematic_failure_rate=0.02, recovery_time=1.0,
             auto_repair_time=5.0, manual_repair_time=10.0, seed=1,
             histogram=None)

# (name, dist, kwargs, samplable): samplable=False marks kwargs the
# *distribution itself* cannot sample (k <= 0, sigma < 0, tau <= 0) —
# there the contract is routing off the compiled path plus a clear
# Python-level error from the generic sampler, never silent garbage
# out of an XLA program.  samplable=True cases are merely outside the
# fast-path envelope and must still complete on the event engine.
_DEGENERATE = [
    ("weibull-k0", "weibull", {"k": 0.0}, False),
    ("weibull-kneg", "weibull", {"k": -1.5}, False),
    ("lognormal-sigma0", "lognormal", {"sigma": 0.0}, True),
    ("lognormal-signeg", "lognormal", {"sigma": -2.0}, False),
    ("bathtub-infant-lt1", "bathtub", {"infant_factor": 0.5}, True),
    ("bathtub-tau0", "bathtub", {"infant_factor": 4.0, "infant_tau": 0.0},
     False),
    ("bathtub-weartau-neg", "bathtub", {"wear_start": 10.0,
                                        "wear_tau": -5.0}, True),
    ("empirical-empty", "empirical", {"edges": [], "rates": []}, True),
    ("empirical-dup-edges", "empirical", {"edges": [5.0, 5.0],
                                          "rates": [1.0, 2.0, 3.0]}, True),
]


@pytest.mark.parametrize("name,dist,kwargs,samplable", _DEGENERATE,
                         ids=[d[0] for d in _DEGENERATE])
def test_degenerate_failure_branch_falls_back_and_completes(name, dist,
                                                            kwargs,
                                                            samplable):
    p = Params(**_TINY, failure_distribution=dist,
               distribution_kwargs=kwargs)
    assert hazard_kind(p) is None
    assert resolve_engine(p, "auto") == "event"
    if samplable:
        res = simulate(p, 1)
        assert len(res) == 1 and res[0].total_time >= p.job_length
    else:
        with pytest.raises((ValueError, ZeroDivisionError, OverflowError)):
            simulate(p, 1)


@pytest.mark.parametrize("name,dist,kwargs,samplable", _DEGENERATE[:4]
                         + _DEGENERATE[-2:],
                         ids=[d[0] for d in _DEGENERATE[:4]
                              + _DEGENERATE[-2:]])
def test_degenerate_repair_branch_falls_back_and_completes(name, dist,
                                                           kwargs,
                                                           samplable):
    if dist == "bathtub":
        pytest.skip("bathtub is failure-only")
    p = Params(**_TINY, repair_distribution=dist,
               distribution_kwargs=kwargs)
    assert repair_kind(p) is None
    assert resolve_engine(p, "auto") == "event"
    if samplable:
        res = simulate(p, 1)
        assert len(res) == 1 and res[0].total_time >= p.job_length
    else:
        with pytest.raises((ValueError, ZeroDivisionError, OverflowError)):
            simulate(p, 1)


def test_reregistered_builtin_name_routes_off_the_fast_path():
    """A user redefinition of a builtin name must not silently run the
    builtin's CTMC program — the fast path verifies the *instance*."""
    saved = _REGISTRY["weibull"]

    class NotWeibull(Distribution):
        def __init__(self, mean_value):
            self.mean_value = mean_value

        def sample(self, rng):
            return float(rng.uniform(0, 2 * self.mean_value))

        @property
        def mean(self):
            return self.mean_value

    register_distribution("weibull", lambda mean, **_: NotWeibull(mean))
    try:
        pf = Params(**_TINY, failure_distribution="weibull",
                    distribution_kwargs={"k": 1.5})
        assert hazard_kind(pf) is None
        assert resolve_engine(pf, "auto") == "event"
        pr = Params(**_TINY, repair_distribution="weibull")
        assert repair_kind(pr) is None
        assert resolve_engine(pr, "auto") == "event"
    finally:
        _REGISTRY["weibull"] = saved
    assert isinstance(_REGISTRY["weibull"](100.0, k=1.5), Weibull)


# ---------------------------------------------------------------------------
# satellite: scipy-absence warning
# ---------------------------------------------------------------------------

def test_missing_scipy_warns_once_and_falls_back(monkeypatch):
    p = Params(**_TINY, failure_distribution="lognormal")
    assert hazard_kind(p) == "lognormal"       # scipy present: fast path
    _scipy_available.cache_clear()
    try:
        monkeypatch.setitem(sys.modules, "scipy", None)
        monkeypatch.setitem(sys.modules, "scipy.special", None)
        with pytest.warns(RuntimeWarning, match="scipy is unavailable"):
            assert hazard_kind(p) is None
        assert resolve_engine(p, "auto") == "event"
        # one-time: the lru_cache remembers the failed probe silently
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert hazard_kind(p) is None
    finally:
        monkeypatch.undo()          # restore sys.modules *now*, not at
        _scipy_available.cache_clear()   # teardown, so the probe re-runs
    assert hazard_kind(p) == "lognormal"


# ---------------------------------------------------------------------------
# satellite: refusal messages name the actual reasons
# ---------------------------------------------------------------------------

def test_scenario_plus_weibull_repair_refusal_names_real_cause():
    from repro.core.faultdomains import FaultTopology

    p = Params(**BASE, fault_domains=FaultTopology(n_racks=4,
                                                   rack_shock_rate=1e-4),
               repair_distribution="weibull")
    reasons = unsupported_reasons(p)
    assert len(reasons) == 1 and "exponential repairs" in reasons[0]
    with pytest.raises(ValueError, match="outside the CTMC envelope"):
        resolve_engine(p, "ctmc")
    with pytest.raises(ValueError, match="exponential repairs"):
        resolve_engine(p, "ctmc")
    # the stale pre-fix message named only distribution/extension causes
    # — assert the new one does NOT claim the distribution is at fault
    try:
        resolve_engine(p, "ctmc")
    except ValueError as e:
        assert "no fast-path" not in str(e)


def test_refusal_lists_every_applicable_reason():
    p = Params(**BASE, failure_distribution="deterministic",
               repair_servers=4, retirement_threshold=2)
    reasons = unsupported_reasons(p)
    assert len(reasons) == 3
    msg = "; ".join(reasons)
    assert "repair_servers" in msg and "retirement" in msg
    with pytest.raises(ValueError, match="repair_servers"):
        simulate_ctmc(p, n_replicas=2)
    assert unsupported_reasons(Params(**BASE)) == []


def test_multijob_refusal_names_real_cause():
    from repro.core.vectorized_multijob import unsupported_reasons_multijob

    cluster = Params(**BASE)
    jobs = [JobSpec(job_size=8, job_length=100.0, start_time=50.0)]
    reasons = unsupported_reasons_multijob(cluster, jobs)
    assert len(reasons) == 1 and "start" in reasons[0]
    with pytest.raises(ValueError, match="outside the CTMC envelope"):
        resolve_engine_multijob(cluster, jobs, "ctmc")
    with pytest.raises(ValueError, match="t=0"):
        resolve_engine_multijob(cluster, jobs, "ctmc")
