"""Per-architecture smoke tests (reduced configs, CPU).

Each assigned architecture instantiates its SMOKE_CONFIG (same family,
small dims) and runs: forward (shape check), loss + gradient (finiteness),
and a prefill -> decode step against a KV/SSM cache.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.module import tree_paths

B, S = 2, 16


def make_batch(cfg):
    batch = {
        "tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size,
        "labels": (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) + 1) % cfg.vocab_size,
    }
    if cfg.is_encdec:
        batch["frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.1,
                                   jnp.float32)
    elif cfg.cross_attn_period > 0:
        batch["image_embeds"] = jnp.full(
            (B, cfg.n_image_tokens, cfg.d_image), 0.1, jnp.float32)
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True).replace(dtype="float32")
            m = build_model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, smoke_models):
    cfg, m, params = smoke_models(arch)
    logits, _ = m.forward(params, make_batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grad_finite(arch, smoke_models):
    cfg, m, params = smoke_models(arch)
    batch = make_batch(cfg)

    def scalar_loss(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(scalar_loss)(params)
    assert bool(jnp.isfinite(loss))
    assert 0.0 < float(loss) < 20.0
    for path, g in tree_paths(grads):
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, smoke_models):
    cfg, m, params = smoke_models(arch)
    batch = make_batch(cfg)
    cache = m.make_cache(B, S)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :8]
    logits, cache = m.prefill(params, pre, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = m.decode(params, tok, cache, jnp.int32(8))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, smoke_models):
    """Teacher-forced decode must reproduce the parallel forward logits."""
    cfg, m, params = smoke_models(arch)
    batch = make_batch(cfg)
    full_logits, _ = m.forward(params, batch)

    cache = m.make_cache(B, S)
    pre = dict(batch)
    n_pre = 4
    pre["tokens"] = batch["tokens"][:, :n_pre]
    logits, cache = m.prefill(params, pre, cache)
    assert jnp.allclose(logits[:, 0], full_logits[:, n_pre - 1],
                        atol=2e-2, rtol=2e-2), arch
    # decode the next few tokens teacher-forced and compare
    for t in range(n_pre, n_pre + 3):
        tok = batch["tokens"][:, t:t + 1]
        logits, cache = m.decode(params, tok, cache, jnp.int32(t))
        assert jnp.allclose(logits[:, 0], full_logits[:, t],
                            atol=2e-2, rtol=2e-2), (arch, t)


def test_param_counts_match_formula():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        from repro.models.module import tree_param_count
        assert tree_param_count(params) == cfg.param_count(), arch


def test_full_configs_are_sane():
    expected_scale = {  # billions, +-20%
        "whisper-base": 0.1, "falcon-mamba-7b": 7.0, "qwen2.5-3b": 3.1,
        "granite-34b": 47.0, "yi-9b": 8.8, "minicpm-2b": 2.7,
        "llama-3.2-vision-90b": 90.0, "jamba-1.5-large-398b": 398.0,
        "kimi-k2-1t-a32b": 1040.0, "arctic-480b": 477.0,
    }
    for arch, exp in expected_scale.items():
        cfg = get_config(arch)
        got = cfg.param_count() / 1e9
        assert abs(got - exp) / exp < 0.2, (arch, got, exp)
    # MoE active-param sanity
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() / 1e9 < 40
