"""Unit tests for the DES engine (SimPy-equivalent substrate)."""

import pytest

from repro.core.engine import Environment, Interrupt


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(delay, tag):
        yield env.timeout(delay)
        log.append((env.now, tag))

    env.process(proc(5, "b"))
    env.process(proc(1, "a"))
    env.process(proc(9, "c"))
    env.run()
    assert log == [(1.0, "a"), (5.0, "b"), (9.0, "c")]


def test_same_time_fifo():
    env = Environment()
    log = []

    def proc(tag):
        yield env.timeout(3)
        log.append(tag)

    for tag in "abc":
        env.process(proc(tag))
    env.run()
    assert log == list("abc")


def test_process_return_value():
    env = Environment()

    def inner():
        yield env.timeout(2)
        return 42

    def outer():
        value = yield env.process(inner())
        return value + 1

    proc = env.process(outer())
    assert env.run_until_process(proc) == 43
    assert env.now == 2.0


def test_event_succeed_wakes_waiter():
    env = Environment()
    evt = env.event()
    got = []

    def waiter():
        value = yield evt
        got.append((env.now, value))

    def trigger():
        yield env.timeout(7)
        evt.succeed("payload")

    env.process(waiter())
    env.process(trigger())
    env.run()
    assert got == [(7.0, "payload")]


def test_interrupt_resumes_with_cause():
    env = Environment()
    observed = []

    def victim():
        try:
            yield env.timeout(100)
        except Interrupt as exc:
            observed.append((env.now, exc.cause))

    def attacker(proc):
        yield env.timeout(4)
        proc.interrupt("stop")

    victim_proc = env.process(victim())
    env.process(attacker(victim_proc))
    env.run()
    assert observed == [(4.0, "stop")]


def test_interrupt_deregisters_pending_timeout():
    env = Environment()
    resumed = []

    def victim():
        try:
            yield env.timeout(10)
            resumed.append("timeout")
        except Interrupt:
            resumed.append("interrupt")
            yield env.timeout(100)
            resumed.append("after")

    proc = env.process(victim())

    def attacker():
        yield env.timeout(1)
        proc.interrupt()

    env.process(attacker())
    env.run()
    # the original timeout must NOT also resume the process
    assert resumed == ["interrupt", "after"]
    assert env.now == 101.0


def test_run_until_time():
    env = Environment()
    ticks = []

    def clock():
        while True:
            yield env.timeout(1)
            ticks.append(env.now)

    env.process(clock())
    env.run(until=5.5)
    assert ticks == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert env.now == 5.5


def test_any_of():
    env = Environment()
    winner = []

    def race():
        result = yield env.any_of([env.timeout(3, "slow"), env.timeout(1, "fast")])
        winner.append(sorted(result.values()))

    env.process(race())
    env.run()
    assert winner == [["fast"]]
    assert env.now >= 1.0


def test_all_of():
    env = Environment()
    done = []

    def gather():
        yield env.all_of([env.timeout(2), env.timeout(5)])
        done.append(env.now)

    env.process(gather())
    env.run()
    assert done == [5.0]


def test_process_exception_propagates():
    env = Environment()

    def boom():
        yield env.timeout(1)
        raise ValueError("kaput")

    proc = env.process(boom())
    with pytest.raises(ValueError, match="kaput"):
        env.run_until_process(proc)


def test_yield_already_processed_event():
    env = Environment()
    evt = env.event()
    evt.succeed("early")
    got = []

    def late_waiter():
        yield env.timeout(5)
        value = yield evt  # already processed by now
        got.append((env.now, value))

    env.process(late_waiter())
    env.run()
    assert got == [(5.0, "early")]


def test_negative_delay_rejected():
    env = Environment()
    with pytest.raises(ValueError):
        env.timeout(-1)
