"""Pallas kernel validation: interpret mode vs pure-jnp oracles.

Per the deliverable spec: each kernel sweeps shapes/dtypes and asserts
allclose against the ref.py oracle.  Interpret mode executes the kernel
body in Python on CPU, so these tests validate the kernel logic (tiling,
masking, accumulator handling) without TPU hardware.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(key_int, shape, dtype):
    return jax.random.normal(jax.random.fold_in(KEY, key_int), shape,
                             jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Sk, Hq, Hkv, d, causal)
    (1, 128, 128, 4, 4, 64, True),      # MHA
    (2, 256, 256, 4, 2, 64, True),      # GQA 2:1
    (1, 256, 256, 8, 1, 128, True),     # MQA
    (2, 128, 128, 4, 2, 128, False),    # bidirectional (encoder)
    (1, 384, 384, 2, 2, 64, True),      # non-power-of-two blocks (3 blocks)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, d, causal = case
    q = rand(1, (B, Sq, Hq, d), dtype)
    k = rand(2, (B, Sk, Hkv, d), dtype)
    v = rand(3, (B, Sk, Hkv, d), dtype)
    out_ref = ref.attention_ref(q, k, v, causal=causal)
    out = ops.flash_attention(q, k, v, causal=causal, impl="pallas_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_kv_len_mask():
    B, S, H, d = 1, 128, 2, 64
    q = rand(4, (B, S, H, d), jnp.float32)
    k = rand(5, (B, S, H, d), jnp.float32)
    v = rand(6, (B, S, H, d), jnp.float32)
    out_ref = ref.attention_ref(q, k, v, causal=False, kv_len=57)
    out = ops.flash_attention(q, k, v, causal=False, kv_len=57,
                              impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_q_offset():
    """Decode-style continuation: q block positioned mid-sequence."""
    B, Sq, Sk, H, d = 1, 128, 256, 2, 64
    q = rand(7, (B, Sq, H, d), jnp.float32)
    k = rand(8, (B, Sk, H, d), jnp.float32)
    v = rand(9, (B, Sk, H, d), jnp.float32)
    out_ref = ref.attention_ref(q, k, v, causal=True, q_offset=128)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=128,
                              impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-5, rtol=2e-5)


def test_attention_ref_blocked_equals_direct():
    """The q-block scan path of the oracle equals its direct path."""
    B, S, H, d = 2, 512, 4, 64
    q = rand(10, (B, S, H, d), jnp.float32)
    k = rand(11, (B, S, H, d), jnp.float32)
    v = rand(12, (B, S, H, d), jnp.float32)
    direct = ref.attention_ref(q, k, v, causal=True, q_block=None)
    blocked = ref.attention_ref(q, k, v, causal=True, q_block=128)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(direct),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_grad_matches_ref():
    B, S, H, d = 1, 128, 2, 64
    q = rand(13, (B, S, H, d), jnp.float32)
    k = rand(14, (B, S, H, d), jnp.float32)
    v = rand(15, (B, S, H, d), jnp.float32)

    g1 = jax.grad(lambda q_: ops.flash_attention(
        q_, k, v, impl="pallas_interpret").sum())(q)
    g2 = jax.grad(lambda q_: ref.attention_ref(q_, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [
    # (B, S, di, N, chunk, block_d)
    (1, 64, 64, 8, 16, 32),
    (2, 128, 128, 16, 32, 64),
    (2, 64, 256, 16, 64, 128),
]


@pytest.mark.parametrize("case", SCAN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_matches_ref(case, dtype):
    B, S, di, N, chunk, block_d = case
    x = rand(20, (B, S, di), dtype) * 0.5
    dt = jax.nn.softplus(rand(21, (B, S, di), jnp.float32)).astype(dtype) * 0.1
    A = -jnp.exp(rand(22, (di, N), jnp.float32) * 0.5)
    Bm = rand(23, (B, S, N), dtype)
    Cm = rand(24, (B, S, N), dtype)
    y_ref, h_ref = ref.selective_scan_ref(x, dt, A, Bm, Cm)
    y, h = ops.selective_scan(x, dt, A, Bm, Cm, impl="pallas_interpret",
                              chunk=chunk, block_d=block_d)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=tol,
                               rtol=tol)


def test_selective_scan_initial_state_continuation():
    """Scanning [0:S] equals scanning [0:S/2] then [S/2:S] with h0 carry."""
    B, S, di, N = 1, 64, 64, 8
    x = rand(30, (B, S, di), jnp.float32) * 0.5
    dt = jax.nn.softplus(rand(31, (B, S, di), jnp.float32)) * 0.1
    A = -jnp.exp(rand(32, (di, N), jnp.float32) * 0.5)
    Bm = rand(33, (B, S, N), jnp.float32)
    Cm = rand(34, (B, S, N), jnp.float32)
    y_full, h_full = ref.selective_scan_ref(x, dt, A, Bm, Cm)
    half = S // 2
    y1, h1 = ops.selective_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                                Cm[:, :half], impl="pallas_interpret",
                                chunk=16, block_d=32)
    y2, h2 = ops.selective_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                                Cm[:, half:], h0=h1, impl="pallas_interpret",
                                chunk=16, block_d=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4,
                               rtol=1e-4)


def test_selective_scan_step_matches_scan():
    """Decode steps replay the full scan one token at a time."""
    B, S, di, N = 2, 8, 32, 8
    x = rand(40, (B, S, di), jnp.float32) * 0.5
    dt = jax.nn.softplus(rand(41, (B, S, di), jnp.float32)) * 0.1
    A = -jnp.exp(rand(42, (di, N), jnp.float32) * 0.5)
    Bm = rand(43, (B, S, N), jnp.float32)
    Cm = rand(44, (B, S, N), jnp.float32)
    y_full, _ = ref.selective_scan_ref(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, di, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, h = ops.selective_scan_step(x[:, t], dt[:, t], A, Bm[:, t],
                                         Cm[:, t], h)
        ys.append(y_t)
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# DES event race
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,Ke,Kd", [
    (64, 4, 2), (256, 16, 4), (1024, 18, 2),
    # padded paths: replica axis not a block multiple, K lanes far off
    # the sublane width, degenerate single-lane races
    (100, 3, 1), (8, 1, 1), (130, 9, 5), (96, 23, 7),
])
def test_event_race_matches_ref(R, Ke, Kd):
    rng = np.random.default_rng(R)
    rates = jnp.asarray(rng.uniform(0, 2, (R, Ke)).astype(np.float32))
    rates = rates.at[:, Ke // 2].set(0.0)  # one family switched off
    resid = jnp.asarray(rng.uniform(0.01, 5, (R, Kd)).astype(np.float32))
    resid = resid.at[: R // 4, 0].set(np.inf)  # some timers off
    ut = jnp.asarray(rng.uniform(1e-6, 1, R).astype(np.float32))
    up = jnp.asarray(rng.uniform(0, 1, R).astype(np.float32))
    dt_r, ev_r = ref.event_race_ref(rates, resid, ut, up)
    dt_p, ev_p = ops.event_race(rates, resid, ut, up,
                                impl="pallas_interpret", block_r=64)
    np.testing.assert_allclose(np.asarray(dt_p), np.asarray(dt_r), rtol=1e-6)
    assert (np.asarray(ev_p) == np.asarray(ev_r)).all()


def test_event_race_all_rates_zero_picks_deterministic():
    R = 64
    rates = jnp.zeros((R, 4), jnp.float32)
    resid = jnp.tile(jnp.asarray([[3.0, 1.5]], jnp.float32), (R, 1))
    ut = jnp.full((R,), 0.5, jnp.float32)
    up = jnp.full((R,), 0.5, jnp.float32)
    dt, ev = ref.event_race_ref(rates, resid, ut, up)
    assert np.allclose(np.asarray(dt), 1.5)
    assert (np.asarray(ev) == 4 + 1).all()


def test_event_race_pallas_off_tpu_refused():
    """An explicit compiled-pallas request off-TPU names the config and
    the escape hatches instead of silently de-materializing."""
    if jax.default_backend() == "tpu":
        pytest.skip("compiled pallas is legitimate on TPU")
    rates = jnp.ones((8, 2), jnp.float32)
    resid = jnp.ones((8, 2), jnp.float32)
    u = jnp.full((8,), 0.5, jnp.float32)
    with pytest.raises(ValueError, match="pallas_interpret"):
        ops.event_race(rates, resid, u, u, impl="pallas")


def test_event_race_unknown_impl_refused():
    rates = jnp.ones((8, 2), jnp.float32)
    u = jnp.full((8,), 0.5, jnp.float32)
    with pytest.raises(ValueError, match="impl"):
        ops.event_race(rates, rates, u, u, impl="vulkan")


def test_event_race_zero_lane_refused():
    """K_det=0 has no next event to race on either side of the dispatch
    (ref cannot reduce a zero-width axis either) — refuse by name."""
    R = 16
    rates = jnp.ones((R, 2), jnp.float32)
    resid = jnp.zeros((R, 0), jnp.float32)
    u = jnp.full((R,), 0.5, jnp.float32)
    with pytest.raises(ValueError, match="zero-width lane"):
        ops.event_race(rates, resid, u, u, impl="pallas_interpret")


def test_event_race_statistics():
    """The winning-family distribution matches the rate proportions."""
    R = 200_000
    rng = np.random.default_rng(0)
    rates = jnp.tile(jnp.asarray([[1.0, 3.0, 0.0, 6.0]], jnp.float32), (R, 1))
    resid = jnp.full((R, 2), jnp.inf, jnp.float32)
    ut = jnp.asarray(rng.uniform(1e-9, 1, R).astype(np.float32))
    up = jnp.asarray(rng.uniform(0, 1, R).astype(np.float32))
    dt, ev = ref.event_race_ref(rates, resid, ut, up)
    ev = np.asarray(ev)
    freq = np.bincount(ev, minlength=4) / R
    np.testing.assert_allclose(freq[:4], [0.1, 0.3, 0.0, 0.6], atol=5e-3)
    # dt mean = 1/total_rate
    np.testing.assert_allclose(float(np.asarray(dt).mean()), 1 / 10.0,
                               rtol=2e-2)
