"""Sharding-rule unit tests (pure functions over (path, shape, mesh))."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.parallel.sharding import (ParallelConfig, activation_spec,
                                     mesh_axes, param_spec)


def _abstract_mesh(sizes, axes):
    # CPU test container has 1 device unless a dryrun-style subprocess
    # sets XLA_FLAGS; build an abstract mesh over a device grid of 1 —
    # shard_if() uses mesh.shape sizes, so use a fake via AbstractMesh.
    # jax 0.4.x takes ((name, size), ...); jax >= 0.5 takes (sizes, names).
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(sizes, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, sizes)))


@pytest.fixture(scope="module")
def mesh():
    return _abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def pod_mesh():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_mesh_axes(mesh, pod_mesh):
    assert mesh_axes(mesh) == (("data",), "model")
    assert mesh_axes(pod_mesh) == (("pod", "data"), "model")


def test_attention_weights(mesh):
    # stacked (n_sb, D, H, hd): heads over model, D over data
    assert param_spec("stack/layer0/attn/wq", (36, 2048, 16, 128), mesh) \
        == P(None, ("data",), "model", None)
    # MQA kv=1 cannot shard heads -> replicated head dim
    assert param_spec("stack/layer0/attn/wk", (88, 6144, 1, 128), mesh) \
        == P(None, ("data",), None, None)
    assert param_spec("stack/layer0/attn/wo", (36, 16, 128, 2048), mesh) \
        == P(None, "model", None, ("data",))


def test_mlp_and_moe_weights(mesh):
    assert param_spec("stack/layer0/mlp/wg", (36, 2048, 11008), mesh) \
        == P(None, ("data",), "model")
    assert param_spec("stack/layer0/mlp/wd", (36, 11008, 2048), mesh) \
        == P(None, "model", ("data",))
    # MoE experts over model, d_model over data
    assert param_spec("stack/layer0/moe/wg", (61, 384, 7168, 2048), mesh) \
        == P(None, "model", ("data",), None)
    assert param_spec("stack/layer0/moe/wd", (61, 384, 2048, 7168), mesh) \
        == P(None, "model", None, ("data",))
    # 16 experts on a 16-way axis still shard
    assert param_spec("stack/layer0/moe/wg", (9, 16, 8192, 24576), mesh) \
        == P(None, "model", ("data",), None)


def test_embeddings(mesh):
    assert param_spec("embed", (151936, 2048), mesh) \
        == P("model", ("data",))
    assert param_spec("head", (2048, 151936), mesh) \
        == P(("data",), "model")
    # odd vocab cannot shard over 16
    assert param_spec("embed", (122753, 2304), mesh) == P(None, ("data",))


def test_mamba_weights(mesh):
    assert param_spec("stack/layer0/ssm/in_proj", (64, 4096, 16384), mesh) \
        == P(None, ("data",), "model")
    assert param_spec("stack/layer0/ssm/A_log", (64, 8192, 16), mesh) \
        == P(None, "model", None)
    assert param_spec("stack/layer0/ssm/out_proj", (64, 8192, 4096), mesh) \
        == P(None, "model", ("data",))


def test_norms_replicated(mesh):
    assert param_spec("stack/layer0/norm1/scale", (36, 2048), mesh) \
        == P(None, None)
    assert param_spec("final_norm/scale", (2048,), mesh) == P(None)


def test_indivisible_dims_not_sharded(mesh):
    # d_model 2304 % 16 == 0 -> sharded; 2305 would not be
    spec = param_spec("stack/layer0/mlp/wg", (40, 2305, 5760), mesh)
    assert spec == P(None, None, "model")


def test_pod_axis_joins_fsdp(pod_mesh):
    spec = param_spec("stack/layer0/mlp/wg", (36, 2048, 11008), pod_mesh)
    assert spec == P(None, ("pod", "data"), "model")


def test_activation_spec_sequence_sharding(mesh):
    assert activation_spec(mesh, 256, 4096) == P(("data",), "model", None)
    off = ParallelConfig(shard_sequence=False)
    assert activation_spec(mesh, 256, 4096, off) == P(("data",), None, None)
    # batch=1 long-context: no batch sharding
    assert activation_spec(mesh, 1, 524288) == P(None, "model", None)
