"""Sweep harness tests (OneWaySweep / TwoWaySweep / experiment files)."""

import json
import os

import pytest
import yaml

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import OneWaySweep, Params, TwoWaySweep, load_experiment

BASE = Params(job_size=16, working_pool_size=22, spare_pool_size=4,
              warm_standbys=2, job_length=0.5 * DAY,
              random_failure_rate=1.0 / DAY)


def test_one_way_sweep_shape_and_columns():
    sweep = OneWaySweep("recovery", "recovery_time", [5.0, 20.0, 40.0],
                        n_replications=2, base_params=BASE)
    result = sweep.run()
    rows = result.to_rows()
    assert len(rows) == 3
    assert [r["recovery_time"] for r in rows] == [5.0, 20.0, 40.0]
    assert all("total_time" in r and "n_failures" in r for r in rows)
    # more recovery -> more total time (common random numbers)
    ts = result.column("total_time")
    assert ts[0] < ts[2]


def test_two_way_sweep_cross_product():
    sweep = TwoWaySweep("grid", "recovery_time", [10.0, 30.0],
                        "warm_standbys", [0, 4],
                        n_replications=2, base_params=BASE)
    result = sweep.run()
    assert len(result.points) == 4
    combos = {(p.values["recovery_time"], p.values["warm_standbys"])
              for p in result.points}
    assert combos == {(10.0, 0), (10.0, 4), (30.0, 0), (30.0, 4)}


def test_virtual_multiplier_parameter():
    sweep = OneWaySweep("sys-mult", "systematic_failure_rate_multiplier",
                        [0, 10], n_replications=2, base_params=BASE)
    result = sweep.run()
    f0 = result.points[0].stats["n_systematic_failures"].mean
    f10 = result.points[1].stats["n_systematic_failures"].mean
    assert f0 == 0.0
    assert f10 > 0.0


def test_unknown_parameter_raises():
    with pytest.raises(ValueError, match="unknown parameter"):
        OneWaySweep("x", "not_a_param", [1], base_params=BASE).run()


def test_csv_and_json_output(tmp_path):
    sweep = OneWaySweep("r", "recovery_time", [10.0, 20.0],
                        n_replications=2, base_params=BASE)
    result = sweep.run()
    csv_path = str(tmp_path / "out.csv")
    json_path = str(tmp_path / "out.json")
    result.write_csv(csv_path)
    result.write_json(json_path)
    assert os.path.exists(csv_path)
    with open(json_path) as f:
        data = json.load(f)
    assert data["parameters"] == ["recovery_time"]
    assert len(data["rows"]) == 2


def test_load_experiment_yaml(tmp_path):
    spec = {
        "base_params": {"job_size": 16, "working_pool_size": 22,
                        "spare_pool_size": 4, "warm_standbys": 2,
                        "job_length": 0.25 * DAY},
        "n_replications": 2,
        "sweeps": [
            {"title": "recovery", "parameter": "recovery_time",
             "values": [10, 20]},
            {"title": "grid", "parameter_a": "recovery_time",
             "values_a": [10], "parameter_b": "warm_standbys",
             "values_b": [0, 2]},
        ],
    }
    path = str(tmp_path / "exp.yaml")
    with open(path, "w") as f:
        yaml.safe_dump(spec, f)
    sweeps = load_experiment(path)
    assert len(sweeps) == 2
    r0 = sweeps[0].run()
    assert len(r0.points) == 2
    r1 = sweeps[1].run()
    assert len(r1.points) == 2
