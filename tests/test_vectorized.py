"""Vectorized CTMC engine vs the event-driven oracle (+ properties)."""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import Params, simulate
from repro.core.vectorized import default_max_steps, simulate_ctmc, supports

N_EVENT = 48
N_CTMC = 768


def compare(p: Params, metrics, n_event=N_EVENT, n_ctmc=N_CTMC, z_tol=3.5):
    out = simulate_ctmc(p, n_replicas=n_ctmc, seed=0)
    assert out["completed"].mean() > 0.99, "CTMC replicas did not finish"
    res = simulate(p, n_event)
    report = {}
    for m in metrics:
        ev = np.array([getattr(r, m) for r in res], float)
        ct = out[m]
        se = np.sqrt(ct.std() ** 2 / len(ct) + ev.std(ddof=1) ** 2 / len(ev))
        z = (ev.mean() - ct.mean()) / max(se, 1e-9)
        report[m] = (ev.mean(), ct.mean(), z)
        assert abs(z) < z_tol, (m, report[m])
    return report


def test_equivalence_default_regime():
    p = Params(job_size=64, working_pool_size=72, spare_pool_size=16,
               warm_standbys=4, job_length=4 * DAY,
               random_failure_rate=0.5 / DAY, seed=3)
    compare(p, ["total_time", "n_failures", "n_random_failures",
                "n_systematic_failures", "n_auto_repairs",
                "n_manual_repairs", "n_standby_swaps", "recovery_overhead"])


def test_equivalence_starved_regime():
    """Pools near-empty: stalls and preemptions must match too."""
    p = Params(job_size=32, working_pool_size=33, spare_pool_size=2,
               warm_standbys=1, job_length=2 * DAY,
               random_failure_rate=2.0 / DAY, auto_repair_time=240.0,
               manual_repair_time=2880.0, diagnosis_probability=1.0, seed=5)
    compare(p, ["total_time", "n_failures", "n_preemptions",
                "n_host_selections", "stall_time"])


def test_equivalence_diagnosis_regime():
    p = Params(job_size=48, working_pool_size=56, spare_pool_size=8,
               warm_standbys=4, job_length=2 * DAY,
               random_failure_rate=1.0 / DAY,
               diagnosis_probability=0.6, diagnosis_uncertainty=0.3, seed=7)
    compare(p, ["total_time", "n_failures", "n_undiagnosed",
                "n_misdiagnosed"])


def test_zero_failures_exact():
    p = Params(job_size=16, working_pool_size=20, spare_pool_size=2,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=0.0, systematic_failure_rate=0.0)
    out = simulate_ctmc(p, n_replicas=8, max_steps=128)
    np.testing.assert_allclose(
        out["total_time"], p.host_selection_time + p.job_length, rtol=1e-5)
    assert (out["n_failures"] == 0).all()


def test_unsupported_params_rejected():
    assert not supports(Params(retirement_threshold=3))
    # weibull/bathtub/lognormal failures AND weibull/lognormal/
    # deterministic repairs are on the fast path now (tests/test_nonexp.py
    # and tests/test_repair_dist.py); deterministic/user-registered
    # *failure* processes and user-registered repairs still fall back
    assert supports(Params(failure_distribution="lognormal"))
    assert supports(Params(failure_distribution="weibull",
                           repair_distribution="weibull"))
    assert not supports(Params(failure_distribution="deterministic"))
    # checkpoint rollback + write cost joined the fast path (PR 9)
    assert supports(Params(checkpoint_interval=60.0))
    assert supports(Params(checkpoint_interval=60.0, checkpoint_cost=2.0))
    with pytest.raises(ValueError):
        simulate_ctmc(Params(retirement_threshold=3), n_replicas=4)


def test_conservation_of_servers():
    """Total server count is invariant across the simulation."""
    p = Params(job_size=32, working_pool_size=40, spare_pool_size=8,
               warm_standbys=4, job_length=1 * DAY,
               random_failure_rate=2.0 / DAY, seed=9)
    import jax
    from repro.core.vectorized import (_initial_state, _params_vector,
                                       _step)
    R = 16
    state = _initial_state(p, R)
    total0 = sum(np.asarray(state[k]).sum(-1) for k in
                 ("run", "sb", "auto", "man", "fw", "fs"))
    pv = _params_vector(p)
    key = jax.random.PRNGKey(0)
    for i in range(200):
        state = _step(state, jax.random.fold_in(key, i), pv, None)
    total = sum(np.asarray(state[k]).sum(-1) for k in
                ("run", "sb", "auto", "man", "fw", "fs"))
    np.testing.assert_allclose(total, total0, atol=1e-3)
    # no compartment may go negative
    for k in ("run", "sb", "auto", "man", "fw", "fs"):
        assert (np.asarray(state[k]) > -1e-3).all(), k


def test_monotone_in_failure_rate():
    base = dict(job_size=32, working_pool_size=40, spare_pool_size=8,
                warm_standbys=4, job_length=2 * DAY)
    lo = simulate_ctmc(Params(random_failure_rate=0.2 / DAY, **base),
                       n_replicas=512, seed=0)
    hi = simulate_ctmc(Params(random_failure_rate=2.0 / DAY, **base),
                       n_replicas=512, seed=0)
    assert hi["n_failures"].mean() > lo["n_failures"].mean()
    assert hi["total_time"].mean() > lo["total_time"].mean()


def test_deterministic_given_seed():
    p = Params(job_size=16, working_pool_size=20, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=1.0 / DAY)
    a = simulate_ctmc(p, n_replicas=64, seed=11)
    b = simulate_ctmc(p, n_replicas=64, seed=11)
    np.testing.assert_array_equal(a["total_time"], b["total_time"])


def test_max_steps_headroom():
    p = Params(job_size=64, working_pool_size=72, spare_pool_size=8,
               warm_standbys=4, job_length=2 * DAY,
               random_failure_rate=1.0 / DAY)
    assert default_max_steps(p) > 2 * p.expected_failures_per_minute() \
        * p.job_length
