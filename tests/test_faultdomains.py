"""Correlated failure domains + scripted injection campaigns, both engines.

Acceptance criteria for :mod:`repro.core.faultdomains` (see
docs/scenarios.md):

  * topology / campaign validation happens in ``Params.validate``;
  * a zero-rate topology plus an empty campaign is *bit-identical* to a
    plain run on BOTH engines (the scenario machinery must draw nothing
    from the RNG and add no compartment noise);
  * cross-engine metric means agree within sampling error (z < 3.5) on a
    scenario combining stochastic rack/pod shocks, a scripted domain
    kill, and a maintenance window that pauses the repair shop — the
    kill lands mid-repair for some replicas;
  * campaigns are honored *exactly*: event counts, kill times, and
    members struck are deterministic;
  * per-domain shock telemetry is consistent (``domain_shocks`` sums to
    ``n_domain_shocks``);
  * a shock-rate sweep is traced — the whole grid compiles one program;
  * scenarios combined with non-exponential repairs stay on the event
    oracle (``supports()`` gating), where struck in-shop servers are
    re-broken by redrawing the stage.
"""

import numpy as np
import pytest

from repro.core import (Campaign, CampaignEvent, FaultTopology, OneWaySweep,
                        Params, Tracer, resolve_engine, run_replications,
                        simulate, simulate_one)
from repro.core.metrics import aggregate, histograms_from_arrays
from repro.core.simulation import ClusterSimulation
from repro.core.vectorized import simulate_ctmc, simulate_ctmc_sweep, supports

N_EVENT = 48
N_CTMC = 768

#: fleet of 40 divides evenly by 4 racks, so every pool holds exactly 25%
#: of each rack and the CTMC's fleet-fraction kill is the exact
#: expectation of the event engine's member count in every compartment
TOPO = FaultTopology(n_racks=4, racks_per_pod=2,
                     rack_shock_rate=1.2e-4, pod_shock_rate=3e-5)
CAMPAIGN = Campaign(events=(
    CampaignEvent(time=400.0, kind="kill", domain=2),
    CampaignEvent(time=900.0, kind="maintenance", duration=300.0),
))
BASE = Params(job_size=24, working_pool_size=32, spare_pool_size=8,
              warm_standbys=4, job_length=3000.0,
              random_failure_rate=2e-4, systematic_failure_rate=1e-3,
              recovery_time=10.0, seed=5)
SCENARIO = BASE.replace(fault_domains=TOPO, campaign=CAMPAIGN)


def _z(a: np.ndarray, b: np.ndarray) -> float:
    se = np.sqrt(a.std() ** 2 / len(a) + b.std(ddof=1) ** 2 / len(b))
    return float((b.mean() - a.mean()) / max(se, 1e-9))


# ---------------------------------------------------------------------------
# validation + dispatch
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError, match="n_racks"):
        FaultTopology(n_racks=0).validate(8)
    with pytest.raises(ValueError, match="exceeds the fleet"):
        FaultTopology(n_racks=100).validate(8)
    with pytest.raises(ValueError, match="racks_per_pod"):
        FaultTopology(n_racks=4, pod_shock_rate=1e-4).validate(8)
    # validation is wired through Params.validate
    with pytest.raises(ValueError, match="exceeds the fleet"):
        BASE.replace(fault_domains=FaultTopology(n_racks=1000)).validate()


def test_campaign_validation_and_schedule():
    with pytest.raises(ValueError, match="require Params.fault_domains"):
        BASE.replace(campaign=Campaign(events=(
            CampaignEvent(time=1.0, kind="kill", domain=0),))).validate()
    with pytest.raises(ValueError, match="out of range"):
        SCENARIO.replace(campaign=Campaign(events=(
            CampaignEvent(time=1.0, kind="kill", domain=99),))).validate()
    with pytest.raises(ValueError, match="duration"):
        CampaignEvent(time=1.0, kind="maintenance").validate(None)
    # maintenance flattens to start/end; stable time sort
    assert CAMPAIGN.schedule() == [(400.0, 0, 2), (900.0, 1, 0),
                                   (1200.0, 2, 0)]


def test_domain_membership_stripes_fleet():
    total = BASE.working_pool_size + BASE.spare_pool_size
    racks = [TOPO.domain_members(d, total) for d in range(TOPO.n_racks)]
    assert sorted(s for r in racks for s in r) == list(range(total))
    assert all(len(r) == total // TOPO.n_racks for r in racks)
    # pod 1 = racks {2, 3}
    pod1 = TOPO.domain_members(TOPO.n_racks + 1, total)
    assert set(pod1) == set(racks[2]) | set(racks[3])


def test_supports_gates_scenario_with_nonexp_repairs_to_event():
    assert supports(SCENARIO)
    assert resolve_engine(SCENARIO, "auto") == "ctmc"
    nonexp = SCENARIO.replace(repair_distribution="weibull",
                              distribution_kwargs={"repair_k": 1.5})
    assert not supports(nonexp)
    assert resolve_engine(nonexp, "auto") == "event"


# ---------------------------------------------------------------------------
# bit-identity: inert scenario == plain run (both engines)
# ---------------------------------------------------------------------------

def test_inert_scenario_bit_identical_event():
    """Zero shock rates + empty campaign must not perturb the RNG or the
    event order: every metric of every replica is byte-identical."""
    inert = BASE.replace(
        fault_domains=FaultTopology(n_racks=4, racks_per_pod=2),
        campaign=Campaign())
    for seed in (5, 23, 77):
        a = simulate_one(BASE, seed=seed).to_dict()
        b = simulate_one(inert, seed=seed).to_dict()
        for k in ("n_domain_shocks", "n_shock_killed", "n_campaign_events"):
            assert b.pop(k) == 0
            a.pop(k)
        assert a == b, seed


def test_inert_scenario_reduces_exactly_ctmc():
    """The scenario program adds race lanes; with zero rates they never
    win, so every counter is bit-identical and the accumulated times
    agree to float32 reduction-order noise (one ulp)."""
    inert = BASE.replace(
        fault_domains=FaultTopology(n_racks=4, racks_per_pod=2),
        campaign=Campaign())
    plain = simulate_ctmc(BASE, n_replicas=64, seed=3, max_steps=4096)
    scen = simulate_ctmc(inert, n_replicas=64, seed=3, max_steps=4096)
    for k in plain:
        if k.startswith("n_") or k in ("completed", "domain_shocks"):
            np.testing.assert_array_equal(plain[k], scen[k], err_msg=k)
        else:
            np.testing.assert_allclose(plain[k], scen[k], rtol=1e-6,
                                       atol=1e-4, err_msg=k)
    assert scen["n_domain_shocks"].sum() == 0
    assert scen["domain_shocks"].sum() == 0


# ---------------------------------------------------------------------------
# cross-engine agreement (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def scenario_runs():
    out = simulate_ctmc(SCENARIO, n_replicas=N_CTMC, seed=6)
    assert out["completed"].mean() > 0.99, "CTMC replicas did not finish"
    res = simulate(SCENARIO, N_EVENT, base_seed=5)
    return out, res


def test_scenario_matches_event_oracle(scenario_runs):
    """Shocks + mid-run domain kill + maintenance window: metric means
    agree across engines within sampling error."""
    out, res = scenario_runs
    for m in ("total_time", "n_failures", "n_standby_swaps",
              "n_host_selections", "n_preemptions", "recovery_overhead",
              "n_domain_shocks", "n_shock_killed", "n_campaign_events"):
        ev = np.array([getattr(r, m) for r in res], float)
        z = _z(out[m], ev)
        assert abs(z) < 3.5, (m, ev.mean(), float(out[m].mean()), z)


def test_scenario_histogram_percentiles_one_bin(scenario_runs):
    out, res = scenario_runs
    hc = histograms_from_arrays(out)["run_duration"]
    pool = np.concatenate([r.run_durations for r in res])
    assert hc.total > 1000 and len(pool) > 500
    for q in (50, 90):
        emp = float(np.percentile(pool, q))
        est = hc.percentile(q)
        assert abs(est - emp) <= hc.bin_width_at(emp), (q, est, emp)


def test_per_domain_telemetry_consistent(scenario_runs):
    out, res = scenario_runs
    # CTMC: per-replica rows sum to the scalar counter
    assert out["domain_shocks"].shape == (N_CTMC, TOPO.n_domains)
    np.testing.assert_allclose(out["domain_shocks"].sum(axis=1),
                               out["n_domain_shocks"], rtol=1e-6)
    # event: same invariant, and the aggregate surfaces the scalar
    for r in res:
        assert len(r.domain_shocks) == TOPO.n_domains
        assert sum(r.domain_shocks) == r.n_domain_shocks
    stats = aggregate(res)
    assert stats["n_domain_shocks"].mean >= 0.0
    # rack shocks dominate: rack rate is 4x the pod rate
    ev_per_dom = np.sum([r.domain_shocks for r in res], axis=0)
    ct_per_dom = np.asarray(out["domain_shocks"]).sum(axis=0)
    assert ev_per_dom[:4].sum() > ev_per_dom[4:].sum()
    assert ct_per_dom[:4].sum() > ct_per_dom[4:].sum()


# ---------------------------------------------------------------------------
# campaigns are exact
# ---------------------------------------------------------------------------

def test_campaign_kill_is_exact_event():
    """No stochastic shocks: the kill fires at exactly t=400 and strikes
    exactly the 10 servers of rack 2, every replica, every seed."""
    p = SCENARIO.replace(fault_domains=FaultTopology(n_racks=4,
                                                     racks_per_pod=2))
    members = p.fault_domains.domain_members(
        2, p.working_pool_size + p.spare_pool_size)
    for seed in (1, 9):
        sim = ClusterSimulation(p, seed=seed)
        tracer = Tracer()
        tracer.attach(sim)
        r = sim.run()
        assert r.n_domain_shocks == 0
        assert r.n_campaign_events == 3
        assert r.n_shock_killed == len(members) == 10
        kills = [e for e in tracer.events if e.kind == "kill"]
        assert [e.time for e in kills] == [400.0]
        assert kills[0].detail == "domain=2 members=10"
        starts = [e for e in tracer.events if e.kind == "maint_start"]
        ends = [e for e in tracer.events if e.kind == "maint_end"]
        assert [e.time for e in starts] == [900.0]
        assert [e.time for e in ends] == [1200.0]


def test_campaign_kill_is_exact_ctmc():
    """Schedule counts are exact per replica; the *kill size* is exact
    only in expectation — the CTMC strikes ``fraction x count`` per
    compartment with systematic rounding, and per-replica occupancies
    need not divide evenly at t=400."""
    p = SCENARIO.replace(fault_domains=FaultTopology(n_racks=4,
                                                     racks_per_pod=2))
    out = simulate_ctmc(p, n_replicas=256, seed=2)
    np.testing.assert_array_equal(out["n_campaign_events"], 3.0)
    np.testing.assert_array_equal(out["n_domain_shocks"], 0.0)
    killed = np.asarray(out["n_shock_killed"], float)
    assert np.all((killed >= 7) & (killed <= 13))
    assert abs(killed.mean() - 10.0) < 0.3


def test_maintenance_pauses_repairs_resume_with_remaining():
    """Deterministic repairs: a repair in flight when the window opens
    finishes exactly ``window length`` later than it would have."""
    window = CampaignEvent(time=60.0, kind="maintenance", duration=500.0)
    p = BASE.replace(
        job_size=8, working_pool_size=12, spare_pool_size=4,
        warm_standbys=0, job_length=2000.0,
        random_failure_rate=2e-3, systematic_failure_rate=0.0,
        automated_repair_probability=1.0,
        auto_repair_failure_probability=0.0,
        manual_repair_failure_probability=0.0,
        repair_distribution="deterministic",
        auto_repair_time=100.0,
        campaign=Campaign(events=(window,)))
    sim = ClusterSimulation(p, seed=4)
    tracer = Tracer()
    tracer.attach(sim)
    sim.run()
    starts: dict = {}
    for e in tracer.events:
        if e.kind == "repair_start":
            starts.setdefault(e.server, []).append(e.time)
    dones = [(e.server, e.time) for e in tracer.events
             if e.kind == "repair_done"]
    assert dones, "need at least one completed repair"
    w0, w1 = window.time, window.time + window.duration
    for sid, t_done in dones:
        t0 = starts[sid].pop(0)  # visits per server pair up in order
        expect = t0 + p.auto_repair_time
        if t0 < w1 and expect > w0:        # overlaps the window: paused
            expect += w1 - max(t0, w0) if t0 >= w0 else window.duration
        # no repair may complete strictly inside the window
        assert not (w0 < t_done < w1), (sid, t_done)
        assert t_done == pytest.approx(expect, abs=1e-6), (sid, t0, t_done)


# ---------------------------------------------------------------------------
# traced shock rates: one compiled program per grid
# ---------------------------------------------------------------------------

def test_shock_rate_grid_compiles_once():
    from repro.core import vectorized

    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    base = SCENARIO.replace(job_length=500.0,
                            max_run_records=17)   # module-unique shape
    grid = [base.replace(fault_domains=FaultTopology(
                n_racks=4, racks_per_pod=2, rack_shock_rate=r,
                pod_shock_rate=3e-5))
            for r in (5e-5, 1.2e-4, 4e-4)]
    c0 = vectorized.compile_cache_size()
    out = simulate_ctmc_sweep(grid, n_replicas=96, seed=0, max_steps=2048)
    c1 = vectorized.compile_cache_size()
    assert c1 - c0 == 1, "a shock-rate grid must share one program"
    shocks = [r["n_domain_shocks"].mean() for r in out]
    assert shocks[0] < shocks[1] < shocks[2], shocks


def test_sweep_axis_and_csv_columns(tmp_path):
    """``rack_shock_rate`` is a first-class sweep axis and the scenario /
    truncation telemetry lands in the sweep table."""
    sweep = OneWaySweep("shock", "rack_shock_rate", [0.0, 4e-4],
                        n_replications=8,
                        base_params=SCENARIO.replace(job_length=500.0,
                                                     campaign=None),
                        engine="event")
    res = sweep.run()
    rows = res.to_rows()
    assert rows[0]["n_domain_shocks"] <= rows[1]["n_domain_shocks"]
    assert all("n_incomplete" in row for row in rows)
    path = tmp_path / "shock.csv"
    res.write_csv(str(path))
    header = path.read_text().splitlines()[0]
    assert "n_domain_shocks" in header and "n_incomplete" in header
    with pytest.raises(ValueError, match="requires Params.fault_domains"):
        OneWaySweep("bad", "rack_shock_rate", [1e-4], n_replications=1,
                    base_params=BASE).run()


# ---------------------------------------------------------------------------
# event-only: scenarios + non-exponential repairs (rebreak redraws)
# ---------------------------------------------------------------------------

def test_scenario_with_weibull_repairs_event_only():
    p = SCENARIO.replace(job_length=1500.0,
                         repair_distribution="weibull",
                         distribution_kwargs={"repair_k": 1.5})
    reps = run_replications(p, 6, engine="auto", base_seed=11)
    assert reps.engine == "event"
    assert reps.stats["n_campaign_events"].mean == 3.0
    assert reps.stats["n_shock_killed"].mean >= 10.0  # the scripted kill
    assert all(r.total_time < p.max_sim_time for r in reps.results)


# ---------------------------------------------------------------------------
# truncation telemetry (n_incomplete)
# ---------------------------------------------------------------------------

def test_n_incomplete_event_engine():
    p = BASE.replace(max_sim_time=100.0)  # job cannot finish in time
    r = simulate_one(p, seed=0)
    assert r.timed_out and r.n_incomplete == 1
    assert r.to_dict()["n_incomplete"] == 1
    stats = aggregate([r, simulate_one(BASE, seed=0)])
    assert stats["n_incomplete"].mean == pytest.approx(0.5)


def test_n_incomplete_ctmc_arrays():
    out = simulate_ctmc(BASE, n_replicas=16, seed=0, max_steps=8)
    from repro.core.metrics import aggregate_arrays
    stats = aggregate_arrays(out)
    assert stats["n_incomplete"].mean == pytest.approx(
        1.0 - float(out["completed"].mean()))
    assert stats["n_incomplete"].mean > 0.0
