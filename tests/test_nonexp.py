"""Non-exponential hazards on the vectorized fast path vs the event oracle.

The CTMC engine now runs Weibull and bathtub failure processes (see
docs/distributions.md and :mod:`repro.core.hazards`): Weibull via exact
closed-form conditional inversion, bathtub via piecewise-constant hazard
majorization + Ogata thinning.  These tests pin the acceptance criteria:

  * ``supports()`` says yes and ``engine=auto`` dispatches to ``ctmc``;
  * metric *means* match the event oracle within sampling error on
    pinned seeds (the same z-test discipline as tests/test_vectorized.py);
  * histogram percentiles match within one bin width and the CDFs agree
    at sampling-error scale;
  * degenerate parameterizations (Weibull k=1, flat bathtub) reproduce
    the exponential baseline — the two new sampling mechanisms are
    cross-checked against the already-validated exponential program.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import OneWaySweep, Params, resolve_engine, simulate
from repro.core.hazards import hazard_kind
from repro.core.metrics import histograms_from_arrays, histograms_from_results
from repro.core.vectorized import (default_max_steps, simulate_ctmc,
                                   simulate_ctmc_sweep, supports)

N_EVENT = 40
N_CTMC = 768

#: small cluster with enough failures for tight statistics but cheap
#: event-oracle replications (the oracle's non-exponential sampler is
#: O(cluster size) per restart).  The systematic rate is cranked up so
#: systematic counts are O(several) per run — a near-zero-count metric
#: makes the z-test degenerate (the event side legitimately sees zero).
BASE = dict(job_size=24, working_pool_size=32, spare_pool_size=4,
            warm_standbys=2, job_length=2 * DAY,
            random_failure_rate=2.0 / DAY,
            systematic_failure_rate=4.0 / DAY, recovery_time=5.0,
            auto_repair_time=30.0, manual_repair_time=120.0, seed=5)

WEIBULL = Params(failure_distribution="weibull",
                 distribution_kwargs={"k": 1.5}, **BASE)
WEIBULL_INFANT = Params(failure_distribution="weibull",
                        distribution_kwargs={"k": 0.8}, **BASE)
BATHTUB = Params(failure_distribution="bathtub",
                 distribution_kwargs={"infant_factor": 8.0,
                                      "infant_tau": 0.25 * DAY},
                 **BASE)


def compare(p: Params, metrics, n_event=N_EVENT, n_ctmc=N_CTMC, z_tol=3.5):
    out = simulate_ctmc(p, n_replicas=n_ctmc, seed=0)
    assert out["completed"].mean() > 0.99, "CTMC replicas did not finish"
    res = simulate(p, n_event)
    for m in metrics:
        ev = np.array([getattr(r, m) for r in res], float)
        ct = out[m]
        se = np.sqrt(ct.std() ** 2 / len(ct) + ev.std(ddof=1) ** 2 / len(ev))
        z = (ev.mean() - ct.mean()) / max(se, 1e-9)
        assert abs(z) < z_tol, (m, ev.mean(), ct.mean(), z)
    return out, res


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_supported_families_and_dispatch():
    assert hazard_kind(WEIBULL) == "weibull"
    assert hazard_kind(BATHTUB) == "bathtub"
    assert supports(WEIBULL) and supports(BATHTUB)
    assert resolve_engine(WEIBULL, "auto") == "ctmc"
    assert resolve_engine(BATHTUB, "auto") == "ctmc"
    # lognormal failures and non-exponential repairs joined the fast
    # path (tests/test_repair_dist.py); user-registered families and
    # degenerate parameterizations are still outside the envelope
    assert supports(WEIBULL.replace(failure_distribution="lognormal"))
    assert supports(WEIBULL.replace(repair_distribution="weibull"))
    assert not supports(WEIBULL.replace(failure_distribution="deterministic"))
    assert hazard_kind(WEIBULL.replace(
        distribution_kwargs={"k": -1.0})) is None


def test_sweep_engine_auto_takes_fast_path():
    sweep = OneWaySweep("bt", "recovery_time", [5.0, 15.0],
                        n_replications=16, base_params=BATHTUB.replace(
                            job_length=0.25 * DAY), engine="auto")
    res = sweep.run()
    assert [pt.engine for pt in res.points] == ["ctmc", "ctmc"]
    assert res.points[0].stats["total_time"].mean \
        < res.points[1].stats["total_time"].mean


# ---------------------------------------------------------------------------
# cross-engine agreement (acceptance criteria)
# ---------------------------------------------------------------------------

def test_weibull_wearout_matches_event_oracle():
    compare(WEIBULL, ["total_time", "n_failures", "n_random_failures",
                      "n_systematic_failures", "n_auto_repairs",
                      "n_manual_repairs", "recovery_overhead",
                      "useful_work"])


def test_weibull_infant_mortality_matches_event_oracle():
    """k < 1: the hazard diverges at age zero — exactly the regime where
    thinning has no finite majorant and the closed-form conditional
    inversion must carry the load."""
    compare(WEIBULL_INFANT, ["total_time", "n_failures", "stall_time",
                             "n_standby_swaps"])


def test_bathtub_matches_event_oracle():
    compare(BATHTUB, ["total_time", "n_failures", "n_random_failures",
                      "n_systematic_failures", "n_auto_repairs",
                      "recovery_overhead"])


def test_weibull_histogram_percentiles_within_one_bin_of_oracle():
    out, res = compare(WEIBULL, ["total_time"], n_event=64, n_ctmc=512)
    hc = histograms_from_arrays(out)["run_duration"]
    pool = np.concatenate([r.run_durations for r in res])
    assert hc.total > 1000 and len(pool) > 1000
    for q in (50, 90, 99):
        emp = float(np.percentile(pool, q))
        est = hc.percentile(q)
        assert abs(est - emp) <= hc.bin_width_at(emp), (q, est, emp)


@pytest.mark.parametrize("params", [WEIBULL, BATHTUB],
                         ids=["weibull", "bathtub"])
def test_cross_engine_cdf_agreement(params):
    out = simulate_ctmc(params, n_replicas=512, seed=2)
    hc = histograms_from_arrays(out)
    he = histograms_from_results(simulate(params, 64), params.histogram)
    for ch in ("run_duration", "recovery"):
        sup = np.abs(hc[ch].cdf() - he[ch].cdf()).max()
        assert sup < 0.08, (ch, sup)


# ---------------------------------------------------------------------------
# degenerate parameterizations reduce to the exponential baseline
# ---------------------------------------------------------------------------

def _z(a: np.ndarray, b: np.ndarray) -> float:
    se = np.sqrt(a.std() ** 2 / len(a) + b.std() ** 2 / len(b))
    return float((a.mean() - b.mean()) / max(se, 1e-9))


def test_weibull_k1_reduces_to_exponential():
    """Weibull with k=1 *is* exponential; the inversion mechanism must
    reproduce the validated exponential program statistically."""
    pw = WEIBULL.replace(distribution_kwargs={"k": 1.0})
    exp_out = simulate_ctmc(Params(**BASE), n_replicas=768, seed=0)
    wb_out = simulate_ctmc(pw, n_replicas=768, seed=1)
    for m in ("total_time", "n_failures", "recovery_overhead"):
        assert abs(_z(exp_out[m], wb_out[m])) < 3.5, m


def test_flat_bathtub_reduces_to_exponential():
    """infant_factor=1 and wear beyond the horizon make g(t) == 1: every
    thinning candidate is accepted and the process is exponential."""
    pb = Params(failure_distribution="bathtub",
                distribution_kwargs={"infant_factor": 1.0,
                                     "wear_start": 1e9},
                **BASE)
    exp_out = simulate_ctmc(Params(**BASE), n_replicas=768, seed=0)
    bt_out = simulate_ctmc(pb, n_replicas=768, seed=1)
    for m in ("total_time", "n_failures", "recovery_overhead"):
        assert abs(_z(exp_out[m], bt_out[m])) < 3.5, m


# ---------------------------------------------------------------------------
# mechanics
# ---------------------------------------------------------------------------

def test_deterministic_given_seed_weibull():
    a = simulate_ctmc(WEIBULL, n_replicas=64, seed=11)
    b = simulate_ctmc(WEIBULL, n_replicas=64, seed=11)
    np.testing.assert_array_equal(a["total_time"], b["total_time"])


def test_single_point_sweep_bit_identical_weibull_and_bathtub():
    for p in (WEIBULL, BATHTUB):
        sweep = simulate_ctmc_sweep([p], n_replicas=21, seed=9,
                                    max_steps=4096)[0]
        single = simulate_ctmc(p, n_replicas=21, seed=9, max_steps=4096)
        assert set(sweep) == set(single)
        for k in sweep:
            np.testing.assert_array_equal(sweep[k], single[k], err_msg=k)


def test_mixed_family_grid_runs_in_input_order():
    short = dict(BASE, job_length=0.25 * DAY)
    grid = [Params(**short),
            Params(failure_distribution="weibull",
                   distribution_kwargs={"k": 1.5}, **short),
            Params(failure_distribution="bathtub", **short),
            Params(**short).replace(recovery_time=40.0)]
    res = simulate_ctmc_sweep(grid, n_replicas=32, seed=1)
    assert len(res) == len(grid)
    for r in res:
        assert r["completed"].mean() > 0.99
    # point 3 differs from point 0 only by a larger recovery time
    assert res[3]["total_time"].mean() > res[0]["total_time"].mean()


def test_weibull_k_is_traced_one_compile_per_bucket():
    from repro.core import vectorized

    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    short = dict(BASE, job_length=0.25 * DAY)
    base = Params(failure_distribution="weibull",
                  distribution_kwargs={"k": 1.5},
                  **short).replace(max_run_records=13)   # module-unique shape
    grid = [base.replace(distribution_kwargs={"k": kk})
            for kk in (0.9, 1.2, 1.5)]
    c0 = vectorized.compile_cache_size()
    simulate_ctmc_sweep(grid, n_replicas=12, seed=0, max_steps=1024)
    c1 = vectorized.compile_cache_size()
    assert c1 - c0 == 1, "a weibull-k grid must share one program"
    # infant mortality (smaller k) concentrates failures: monotone check
    out = simulate_ctmc_sweep(grid, n_replicas=128, seed=0)
    fails = [r["n_failures"].mean() for r in out]
    assert fails[0] > fails[1] > fails[2], fails


def test_budget_is_hazard_aware():
    """Infant-heavy hazards generate more events; the derived step
    budget must scale with the age-zero hazard, not the flat rate."""
    exp_steps = default_max_steps(Params(**BASE))
    assert default_max_steps(BATHTUB) > 2 * exp_steps
    assert default_max_steps(WEIBULL_INFANT) > exp_steps
