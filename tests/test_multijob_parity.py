"""Cross-engine contention parity: multi-job CTMC vs the event oracle.

The multi-job compartment engine
(:mod:`repro.core.vectorized_multijob`) promotes the event-loop
``MultiJobSimulation`` semantics — J jobs sharing one spare pool and one
finite-server repair shop — onto the compiled fast path.  This suite
pins it against the event oracle:

  * 2-job and 4-job mixed-size clusters under spare-pool and
    repair-shop contention agree within |z| < 3.5 on per-job
    ETTF/recovery/waiting means and fleet/shop counters;
  * per-job distribution channels agree to one histogram bin;
  * conservation: servers across jobs + pools + shop sum to the fleet
    size at every recorded point on BOTH engines (the CTMC lane checks
    every scan step in-program; the event engine is stepped event by
    event and re-counted here);
  * reduction: a 1-job multi-job sweep is bit-identical to the
    single-job CTMC program and compiles nothing new; a J-job cluster
    with per-job standby headroom, a deep spare pool, and an unbounded
    shop factorizes into independent single-job runs;
  * regression (satellite): ``MultiJobResult`` surfaces the shared
    shop's counters and per-job recovery/waiting channels.

Documented approximations (see docs/multijob.md): ``n_host_selections``
and ``n_standby_swaps`` can drift beyond sampling error in *saturated*
regimes because the event engine's multi-set job membership has no
count-based twin; the metrics pinned here avoid relying on them.
"""

import math

import numpy as np
import pytest

from repro.core import (JobSpec, Params, aggregate_multijob_arrays,
                        pool_histograms, resolve_engine_multijob,
                        run_replications_multijob, simulate_multijob,
                        simulate_multijob_ctmc_sweep, supports_multijob)
from repro.core import vectorized as vz
from repro.core import vectorized_multijob as vmj
from repro.core.multijob import MultiJobSimulation

Z_MAX = 3.5


def _z(a, b):
    a = np.asarray(a, float)
    b = np.asarray(b, float)
    se = math.sqrt(a.var(ddof=1) / len(a) + b.var(ddof=1) / len(b))
    return (a.mean() - b.mean()) / max(se, 1e-12)


def _z_hist(ha, hb):
    na, nb = ha.total, hb.total
    if na < 2 or nb < 2:
        return 0.0
    se = math.sqrt(ha.std() ** 2 / na + hb.std() ** 2 / nb)
    return (ha.mean() - hb.mean()) / max(se, 1e-12)


def _cdf_at(h, value):
    """Fraction of a histogram's mass in bins strictly below ``value``."""
    idx = int(np.searchsorted(np.asarray(h.edges, float), value,
                              side="right"))
    cum = np.cumsum(np.asarray(h.counts, float))
    below = cum[idx - 1] if idx > 0 else 0.0
    return below / max(h.total, 1)


def _assert_one_bin(ha, hb, what, qs=(50, 90)):
    """Percentiles agree to one bin of the shared log-binned layout.

    Bimodal channels (waiting: a zero-wait standby mode and a
    host-selection mode with empty bins between) can put a percentile on
    a knife edge where a <1% mass shift jumps many *empty* bins; there
    the criterion is the CDF form — the other engine assigns nearly the
    same cumulative mass at that percentile value.
    """
    edges = np.asarray(ha.edges, float)
    for q in qs:
        va, vb = ha.percentile(q), hb.percentile(q)
        ia = int(np.searchsorted(edges, va, side="right"))
        ib = int(np.searchsorted(edges, vb, side="right"))
        cdf_gap = abs(_cdf_at(hb, va) - q / 100.0)
        assert abs(ia - ib) <= 1 or cdf_gap <= 0.05, (
            f"{what} p{q}: bins {ia} vs {ib} ({va:.3f} vs {vb:.3f}), "
            f"cdf gap {cdf_gap:.3f}")


# Moderate contention: the shop queues and the spare pool runs dry
# sometimes, but the cluster is not saturated (where the event engine's
# multi-set membership approximation dominates host-selection counts).
TWO_JOB_CLUSTER = Params(
    working_pool_size=110, spare_pool_size=16, job_size=16,
    job_length=4000.0, random_failure_rate=0.001,
    systematic_failure_rate=0.005, auto_repair_time=180.0,
    manual_repair_time=480.0, repair_servers=6)
TWO_JOBS = (JobSpec(32, 4000.0, warm_standbys=2),
            JobSpec(16, 6000.0, warm_standbys=1))

FOUR_JOB_CLUSTER = Params(
    working_pool_size=110, spare_pool_size=12, job_size=16,
    job_length=3000.0, random_failure_rate=0.001,
    systematic_failure_rate=0.005, auto_repair_time=150.0,
    manual_repair_time=420.0, repair_servers=5)
FOUR_JOBS = (JobSpec(24, 3000.0, warm_standbys=2),
             JobSpec(16, 4000.0, warm_standbys=1),
             JobSpec(12, 3500.0, warm_standbys=1),
             JobSpec(8, 5000.0, warm_standbys=1))

_PINNED_JOB_METRICS = ("total_time", "n_failures", "stall_time",
                       "n_preemptions", "recovery_overhead")
_PINNED_FLEET_METRICS = ("makespan", "stall_handoffs", "n_auto_repairs",
                         "n_manual_repairs", "n_shop_queued")


def _parity_case(cluster, jobs, n_ctmc, n_event, seed):
    assert resolve_engine_multijob(cluster, jobs) == "ctmc"
    point = simulate_multijob_ctmc_sweep([(cluster, jobs)],
                                         n_replicas=n_ctmc, seed=seed)[0]
    agg = aggregate_multijob_arrays(point)
    results = simulate_multijob(cluster, list(jobs),
                                n_replications=n_event,
                                base_seed=seed + 1)

    # the contention machinery must actually be exercised on both sides
    assert float(np.mean(point["n_shop_queued"])) > 0
    assert np.mean([r.queue_events for r in results]) > 0
    assert float(np.max(point["conservation_err"])) == 0.0

    spec = cluster.histogram
    for j in range(len(jobs)):
        cj = point["per_job"][j]
        for metric in _PINNED_JOB_METRICS:
            ev = [float(getattr(r.per_job[j], metric)) for r in results]
            z = _z(cj[metric], ev)
            assert abs(z) < Z_MAX, f"job{j} {metric}: z={z:+.2f}"
        ct_hists = agg["per_job_histograms"][j]
        ev_hists = pool_histograms(
            [r.per_job_histograms(spec)[j] for r in results])
        for ch in ("run_duration", "recovery", "waiting"):
            z = _z_hist(ct_hists[ch], ev_hists[ch])
            assert abs(z) < Z_MAX, f"job{j} {ch} mean: z={z:+.2f}"
            _assert_one_bin(ct_hists[ch], ev_hists[ch], f"job{j} {ch}")

    fleet_event = {
        "makespan": [r.makespan for r in results],
        "stall_handoffs": [float(r.stall_events) for r in results],
        "n_auto_repairs": [float(r.cluster.n_auto_repairs)
                           for r in results],
        "n_manual_repairs": [float(r.cluster.n_manual_repairs)
                             for r in results],
        "n_shop_queued": [float(r.queue_events) for r in results],
    }
    for metric in _PINNED_FLEET_METRICS:
        z = _z(point[metric], fleet_event[metric])
        assert abs(z) < Z_MAX, f"fleet {metric}: z={z:+.2f}"


def test_two_job_contention_parity():
    _parity_case(TWO_JOB_CLUSTER, TWO_JOBS, n_ctmc=1024, n_event=96,
                 seed=17)


def test_four_job_contention_parity():
    _parity_case(FOUR_JOB_CLUSTER, FOUR_JOBS, n_ctmc=1024, n_event=80,
                 seed=29)


def test_backend_multijob_replications_structure():
    rep = run_replications_multijob(TWO_JOB_CLUSTER, TWO_JOBS, n=64,
                                    engine="auto", base_seed=11)
    assert rep.engine == "ctmc"
    assert len(rep.per_job) == len(TWO_JOBS)
    assert rep.fleet["makespan"].mean > 0
    assert rep.fleet["conservation_err"].maximum == 0.0
    assert set(rep.histograms) >= {"run_duration", "recovery", "waiting"}
    for jr in rep.per_job:
        assert jr.stats["total_time"].mean > 0


# ---------------------------------------------------------------------------
# conservation at every recorded point
# ---------------------------------------------------------------------------

def test_ctmc_conservation_every_step():
    """The in-scan invariant lane records the max per-step deviation of
    sum(job blocks) + pools + shop from the fleet size — exactly zero."""
    for cluster, jobs in ((TWO_JOB_CLUSTER, TWO_JOBS),
                          (FOUR_JOB_CLUSTER, FOUR_JOBS)):
        out = simulate_multijob_ctmc_sweep([(cluster, jobs)],
                                           n_replicas=256, seed=5)[0]
        assert float(np.max(out["conservation_err"])) == 0.0


def _accounted_sids(sim):
    """Every server, exactly once: pools, shop, job blocks, hand-offs."""
    sids = []
    pools, shop = sim.pools, sim.repair_shop
    sids += [s.sid for s in pools.working_free]
    sids += [s.sid for s in pools.spare_free]
    sids += [s.sid for s in pools.retired]
    sids += [s.sid for s in shop.in_repair]
    for coord in sim.coordinators:
        sids += [s.sid for s in coord.running_good + coord.running_bad]
        sched = coord.scheduler
        sids += [s.sid for s in sched.standbys]
        if sched._inflight is not None:
            sids.append(sched._inflight.sid)
        if (sched._stall_event is not None and sched._stall_event.triggered
                and sched._stall_server is not None):
            sids.append(sched._stall_server.sid)
    return sids


def test_event_conservation_every_step():
    """Step the event simulation one event at a time and re-count: job
    blocks, both pools, the shop (service + queue), and in-flight
    hand-offs partition the fleet at every event boundary."""
    sim = MultiJobSimulation(TWO_JOB_CLUSTER, list(TWO_JOBS), seed=23)
    total = (TWO_JOB_CLUSTER.working_pool_size
             + TWO_JOB_CLUSTER.spare_pool_size)
    procs = [sim.env.process(sim._run_job(i, spec), name=f"job{i}")
             for i, spec in enumerate(sim.jobs)]
    checked = 0
    while any(p.is_alive for p in procs):
        sim.env.step()
        sids = _accounted_sids(sim)
        assert sorted(sids) == list(range(total)), (
            f"conservation broke at t={sim.env.now:.2f}: "
            f"{len(sids)} accounted ({len(set(sids))} unique) of {total}")
        checked += 1
    assert checked > 500  # the walk actually covered a contended run
    assert sim.repair_shop.n_queued_events > 0


# ---------------------------------------------------------------------------
# reduction: 1 job == the single-job program; infinite pool factorizes
# ---------------------------------------------------------------------------

def test_one_job_reduction_bit_identical_and_no_new_compiles():
    single = Params(working_pool_size=40, spare_pool_size=6, job_size=24,
                    job_length=2000.0, random_failure_rate=0.002,
                    systematic_failure_rate=0.01,
                    auto_repair_time=120.0, manual_repair_time=300.0)
    spec = JobSpec(24, 2000.0, warm_standbys=2)
    sj = single.replace(warm_standbys=2)
    ref = vz.simulate_ctmc_sweep([sj], n_replicas=64, seed=13)[0]

    c_sj = vz.compile_cache_size()
    c_mj = vmj.compile_cache_size()
    out = simulate_multijob_ctmc_sweep([(single, (spec,))],
                                       n_replicas=64, seed=13)[0]
    # same compile-cache key class: the 1-job sweep reuses the warm
    # single-job program and never builds a multi-job one
    assert vz.compile_cache_size() == c_sj
    assert vmj.compile_cache_size() == c_mj

    assert len(out["per_job"]) == 1
    arrays = out["per_job"][0]
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(arrays[k]), np.asarray(ref[k]),
            err_msg=f"1-job reduction differs on {k}")
    np.testing.assert_array_equal(np.asarray(out["makespan"]),
                                  np.asarray(ref["total_time"]))
    assert float(np.max(out["conservation_err"])) == 0.0
    assert float(np.max(out["n_shop_queued"])) == 0.0


def test_infinite_pool_and_shop_factorizes():
    """With per-job standby headroom, a deep spare pool, and an
    unbounded shop, jobs never contend: each job's marginals match an
    independent single-job run within |z| < 3.5."""
    cluster = Params(working_pool_size=220, spare_pool_size=150,
                     job_size=16, job_length=2000.0,
                     random_failure_rate=0.0015,
                     systematic_failure_rate=0.008,
                     recovery_time=10.0, auto_repair_time=120.0,
                     manual_repair_time=300.0, repair_servers=0)
    jobs = (JobSpec(24, 2000.0, warm_standbys=12),
            JobSpec(12, 3000.0, warm_standbys=12))
    out = simulate_multijob_ctmc_sweep([(cluster, jobs)],
                                       n_replicas=1024, seed=7)[0]
    assert float(np.max(out["conservation_err"])) == 0.0
    for j, spec in enumerate(jobs):
        solo = cluster.replace(job_size=spec.job_size,
                               job_length=spec.job_length,
                               warm_standbys=spec.warm_standbys)
        ref = vz.simulate_ctmc_sweep([solo], n_replicas=1024,
                                     seed=101 + j)[0]
        for metric in ("total_time", "n_failures", "stall_time"):
            z = _z(out["per_job"][j][metric], ref[metric])
            assert abs(z) < Z_MAX, f"job{j} {metric}: z={z:+.2f}"


# ---------------------------------------------------------------------------
# satellite regression: MultiJobResult surfaces shop + per-job channels
# ---------------------------------------------------------------------------

def test_multijob_result_surfaces_cluster_and_histograms():
    """The shared shop's repair counters historically vanished (written
    to a RunResult nobody kept) and per-job recovery/waiting channels
    had no accessor — the CTMC parity suite needs both as its oracle."""
    res = simulate_multijob(TWO_JOB_CLUSTER, list(TWO_JOBS),
                            n_replications=3, base_seed=41)
    spec = TWO_JOB_CLUSTER.histogram
    for r in res:
        assert r.cluster.n_auto_repairs > 0
        assert r.cluster.n_auto_repairs + r.cluster.n_manual_repairs > 0
        hists = r.per_job_histograms(spec)
        assert len(hists) == len(TWO_JOBS)
        for j, hd in enumerate(hists):
            rj = r.per_job[j]
            assert hd["recovery"].total == len(rj.recovery_durations)
            assert hd["waiting"].total == len(rj.waiting_durations)
            assert hd["run_duration"].total == len(rj.run_durations)
    assert any(r.queue_events > 0 for r in res)


def test_supports_multijob_gates():
    ok = TWO_JOB_CLUSTER
    assert supports_multijob(ok, TWO_JOBS)
    assert not supports_multijob(
        ok.replace(failure_distribution="weibull"), TWO_JOBS)
    assert not supports_multijob(
        ok.replace(checkpoint_interval=100.0), TWO_JOBS)
    assert not supports_multijob(
        ok, (JobSpec(8, 100.0, 0, start_time=5.0),))
    with pytest.raises(ValueError):
        resolve_engine_multijob(ok.replace(checkpoint_interval=100.0),
                                TWO_JOBS, engine="ctmc")
