"""Tests for the paper-named extensions: multi-job, bathtub, tracing."""

import math
import os

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import Params, simulate_one
from repro.core.bathtub import Bathtub
from repro.core.multijob import JobSpec, MultiJobSimulation, simulate_multijob
from repro.core.trace import Tracer
from repro.core.simulation import ClusterSimulation


# ---------------------------------------------------------------------------
# multi-job
# ---------------------------------------------------------------------------

def cluster(**kw) -> Params:
    base = dict(job_size=16, working_pool_size=64, spare_pool_size=8,
                warm_standbys=2, job_length=1 * DAY,
                random_failure_rate=1.0 / DAY, seed=11)
    base.update(kw)
    return Params(**base)


def test_two_jobs_complete():
    jobs = [JobSpec(job_size=16, job_length=1 * DAY, warm_standbys=2),
            JobSpec(job_size=24, job_length=0.5 * DAY, warm_standbys=2)]
    result = MultiJobSimulation(cluster(), jobs).run()
    assert len(result.per_job) == 2
    for spec, r in zip(jobs, result.per_job):
        assert r.useful_work == pytest.approx(spec.job_length)
        assert not r.timed_out
    assert result.makespan >= max(r.total_time for r in result.per_job) - 1e-9


def test_multijob_capacity_validation():
    jobs = [JobSpec(job_size=40, job_length=DAY),
            JobSpec(job_size=40, job_length=DAY)]
    with pytest.raises(ValueError, match="cannot host"):
        MultiJobSimulation(cluster(working_pool_size=64), jobs)


def test_staggered_start():
    jobs = [JobSpec(job_size=16, job_length=0.25 * DAY),
            JobSpec(job_size=16, job_length=0.25 * DAY,
                    start_time=0.5 * DAY)]
    result = MultiJobSimulation(cluster(random_failure_rate=0.0,
                                        systematic_failure_rate=0.0),
                                jobs).run()
    t0, t1 = (r.total_time for r in result.per_job)
    assert t1 > t0  # second job started later, finished later


def test_contention_raises_stalls():
    """Two big jobs on a tight pool contend; the dispatcher hands
    repaired servers to starved jobs."""
    jobs = [JobSpec(job_size=24, job_length=1 * DAY, warm_standbys=0),
            JobSpec(job_size=24, job_length=1 * DAY, warm_standbys=0)]
    tight = cluster(working_pool_size=48, spare_pool_size=1,
                    random_failure_rate=4.0 / DAY,
                    auto_repair_time=3 * 60.0, diagnosis_probability=1.0)
    reps = simulate_multijob(tight, jobs, n_replications=3)
    total_stall = sum(sum(r.stall_time for r in rep.per_job)
                      for rep in reps)
    assert total_stall > 0.0
    assert any(rep.stall_events > 0 for rep in reps)


def test_multijob_reproducible():
    jobs = [JobSpec(job_size=16, job_length=0.5 * DAY)]
    a = MultiJobSimulation(cluster(), jobs, seed=5).run()
    b = MultiJobSimulation(cluster(), jobs, seed=5).run()
    assert a.per_job[0].total_time == b.per_job[0].total_time


# ---------------------------------------------------------------------------
# bathtub hazard
# ---------------------------------------------------------------------------

def test_bathtub_hazard_shape():
    bt = Bathtub(mean_value=100 * DAY, infant_factor=20.0,
                 infant_tau=7 * DAY, wear_start=200 * DAY,
                 wear_tau=50 * DAY)
    h0 = bt.hazard(0.0)
    h_flat = bt.hazard(100 * DAY)
    h_old = bt.hazard(400 * DAY)
    assert h0 == pytest.approx(20.0 * h_flat / bt.hazard(100 * DAY) * h_flat,
                               rel=0.1) or h0 > 5 * h_flat
    assert h_old > h_flat  # wear-out rises


def test_bathtub_sampling_matches_cumhazard():
    """KS-style check: H(T) of samples should be Exp(1)-distributed."""
    bt = Bathtub(mean_value=30 * DAY, infant_factor=10.0,
                 infant_tau=2 * DAY, wear_start=60 * DAY, wear_tau=20 * DAY)
    rng = np.random.default_rng(0)
    samples = np.array([bt.sample(rng) for _ in range(2000)])
    transformed = np.array([bt.cumulative_hazard(t) for t in samples])
    # mean of Exp(1) is 1, variance 1
    assert np.mean(transformed) == pytest.approx(1.0, abs=0.08)
    assert np.var(transformed) == pytest.approx(1.0, abs=0.25)


def test_bathtub_infant_mortality_shifts_mass_early():
    flat = Bathtub(mean_value=30 * DAY, infant_factor=1.0)
    infant = Bathtub(mean_value=30 * DAY, infant_factor=50.0,
                     infant_tau=2 * DAY)
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(1)
    s_flat = np.median([flat.sample(rng1) for _ in range(800)])
    s_inf = np.median([infant.sample(rng2) for _ in range(800)])
    assert s_inf < s_flat


def test_bathtub_in_simulation():
    p = Params(job_size=16, working_pool_size=22, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               failure_distribution="bathtub",
               random_failure_rate=1.0 / DAY,
               distribution_kwargs={"infant_factor": 15.0,
                                    "infant_tau": 0.5 * DAY},
               seed=3)
    r = simulate_one(p)
    assert not r.timed_out
    assert r.useful_work == pytest.approx(p.job_length)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_tracer_records_and_exports(tmp_path):
    p = Params(job_size=16, working_pool_size=22, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=2.0 / DAY, seed=7)
    sim = ClusterSimulation(p)
    tracer = Tracer()
    tracer.attach(sim)
    result = sim.run()

    counts = tracer.counts()
    assert counts.get("failure", 0) == result.n_failures
    assert counts.get("repair_start", 0) \
        == result.n_failures - result.n_undiagnosed
    # n_host_selections already includes preempted spare-pool draws
    assert counts.get("standby_swap", 0) + counts.get("host_selection", 0) \
        == result.n_standby_swaps + result.n_host_selections

    csv_path = str(tmp_path / "trace.csv")
    tracer.write_csv(csv_path)
    assert os.path.getsize(csv_path) > 0
    chrome_path = str(tmp_path / "trace.json")
    tracer.write_chrome_trace(chrome_path)
    assert os.path.getsize(chrome_path) > 0
    assert "failure" in tracer.summary()


def test_tracer_repeat_offenders():
    p = Params(job_size=8, working_pool_size=12, spare_pool_size=2,
               warm_standbys=1, job_length=4 * DAY,
               systematic_failure_fraction=0.5,
               systematic_failure_rate=20.0 / DAY,
               auto_repair_failure_probability=1.0,
               manual_repair_failure_probability=1.0,
               random_failure_rate=0.1 / DAY, seed=1)
    sim = ClusterSimulation(p)
    tracer = Tracer()
    tracer.attach(sim)
    sim.run()
    offenders = tracer.repeat_offenders(top=3)
    assert offenders and offenders[0][1] >= 2  # chronic bad server visible
