"""Behaviour + invariant tests for the AIReSim cluster simulation."""

import math

import numpy as np
import pytest

from repro.core import (MINUTES_PER_DAY, ClusterSimulation, Params, aggregate,
                        expected_failures, expected_total_time, simulate,
                        simulate_one)
from repro.core.server import ServerState

DAY = MINUTES_PER_DAY


def tiny(**kw) -> Params:
    base = dict(job_size=32, working_pool_size=40, spare_pool_size=8,
                warm_standbys=4, job_length=2 * DAY, seed=123)
    base.update(kw)
    return Params(**base)


# ---------------------------------------------------------------------------
# exactness checks
# ---------------------------------------------------------------------------

def test_zero_failure_rate_gives_exact_job_length():
    p = tiny(random_failure_rate=0.0, systematic_failure_rate=0.0)
    r = simulate_one(p)
    assert r.n_failures == 0
    # total = host_selection (t=0) + job_length exactly
    assert r.total_time == pytest.approx(p.host_selection_time + p.job_length)
    assert r.useful_work == pytest.approx(p.job_length)
    assert r.run_durations == [pytest.approx(p.job_length)]


def test_zero_systematic_fraction_has_no_systematic_failures():
    p = tiny(systematic_failure_fraction=0.0,
             random_failure_rate=0.5 / DAY, job_length=4 * DAY)
    r = simulate_one(p)
    assert r.n_systematic_failures == 0
    assert r.n_failures == r.n_random_failures
    assert r.n_failures > 0


def test_deterministic_recovery_accounting():
    """Every failure charges exactly recovery_time when standbys suffice."""
    p = tiny(random_failure_rate=0.2 / DAY, systematic_failure_fraction=0.0,
             warm_standbys=32, working_pool_size=100, job_length=2 * DAY,
             recovery_time=17.0)
    r = simulate_one(p)
    assert r.recovery_overhead == pytest.approx(17.0 * r.n_failures)
    # total = t0 host selection + work + recovery (+ possible host selections)
    assert r.total_time >= p.host_selection_time + p.job_length \
        + r.recovery_overhead - 1e-6


def test_total_time_decomposition():
    p = tiny(random_failure_rate=1.0 / DAY, job_length=DAY)
    r = simulate_one(p)
    overhead = r.total_time - r.useful_work
    assert overhead >= r.recovery_overhead + r.stall_time - 1e-6
    assert r.useful_work == pytest.approx(p.job_length)


# ---------------------------------------------------------------------------
# failure counting / classification
# ---------------------------------------------------------------------------

def test_failure_split_sums():
    p = tiny(random_failure_rate=0.5 / DAY, job_length=4 * DAY)
    r = simulate_one(p)
    assert r.n_failures == r.n_random_failures + r.n_systematic_failures


def test_expected_failures_close_to_analytical():
    # disable repair-driven healing so the rate stays constant:
    # repairs always fail (bad stays bad)
    p = Params(job_size=512, working_pool_size=560, spare_pool_size=50,
               warm_standbys=16, job_length=8 * DAY,
               auto_repair_failure_probability=1.0,
               manual_repair_failure_probability=1.0,
               random_failure_rate=0.05 / DAY, seed=7)
    results = simulate(p, 8)
    mean_failures = np.mean([r.n_failures for r in results])
    # analytical uses work-time only; failures also accrue slightly less
    # because clocks pause during recovery — allow 15% band
    expected = expected_failures(p)
    assert abs(mean_failures - expected) / expected < 0.15


def test_higher_failure_rate_more_failures_paired_seeds():
    lo = tiny(random_failure_rate=0.1 / DAY, job_length=4 * DAY)
    hi = tiny(random_failure_rate=1.0 / DAY, job_length=4 * DAY)
    r_lo = np.mean([r.n_failures for r in simulate(lo, 6)])
    r_hi = np.mean([r.n_failures for r in simulate(hi, 6)])
    assert r_hi > r_lo


# ---------------------------------------------------------------------------
# replacement waterfall
# ---------------------------------------------------------------------------

def test_standby_swap_has_no_host_selection():
    p = tiny(warm_standbys=30, working_pool_size=70,
             random_failure_rate=0.3 / DAY, job_length=2 * DAY,
             # keep servers in repair long so standbys are consumed
             auto_repair_time=50 * DAY, manual_repair_time=50 * DAY)
    r = simulate_one(p)
    if r.n_failures <= 30:
        assert r.n_host_selections == 0
        assert r.n_standby_swaps == r.n_failures - r.n_undiagnosed


def test_preemption_only_after_pools_exhausted():
    # working pool has zero headroom beyond job + standbys
    p = tiny(job_size=32, warm_standbys=2, working_pool_size=34,
             spare_pool_size=10, random_failure_rate=2.0 / DAY,
             job_length=2 * DAY,
             auto_repair_time=50 * DAY, manual_repair_time=50 * DAY)
    r = simulate_one(p)
    if r.n_failures > 2:
        assert r.n_preemptions > 0


def test_stall_when_everything_exhausted():
    p = tiny(job_size=16, warm_standbys=0, working_pool_size=16,
             spare_pool_size=1, random_failure_rate=4.0 / DAY,
             job_length=2 * DAY, diagnosis_probability=1.0,
             auto_repair_time=2 * DAY, manual_repair_time=10 * DAY)
    r = simulate_one(p)
    assert r.stall_time > 0.0
    assert not r.timed_out


def test_no_preemptions_with_big_working_pool():
    p = tiny(working_pool_size=500, random_failure_rate=0.5 / DAY,
             job_length=2 * DAY)
    r = simulate_one(p)
    assert r.n_preemptions == 0


# ---------------------------------------------------------------------------
# repair pipeline
# ---------------------------------------------------------------------------

def test_all_failures_go_through_auto_repair_when_diagnosed():
    p = tiny(diagnosis_probability=1.0, random_failure_rate=0.5 / DAY,
             job_length=4 * DAY, auto_repair_time=1.0, manual_repair_time=2.0)
    r = simulate_one(p)
    # every diagnosed failure triggers an auto attempt; all complete quickly
    assert r.n_auto_repairs == r.n_failures


def test_manual_repairs_follow_escalation_probability():
    p = tiny(diagnosis_probability=1.0, automated_repair_probability=0.5,
             random_failure_rate=1.0 / DAY, job_length=8 * DAY,
             auto_repair_time=1.0, manual_repair_time=1.0, seed=3)
    results = simulate(p, 6)
    autos = sum(r.n_auto_repairs for r in results)
    manuals = sum(r.n_manual_repairs for r in results)
    assert autos > 50
    ratio = manuals / autos
    assert 0.35 < ratio < 0.65  # ~0.5 escalation


def test_repair_heals_bad_servers():
    """With perfect repair, systematic failures decay over the run."""
    p = Params(job_size=256, working_pool_size=300, spare_pool_size=32,
               warm_standbys=16, job_length=32 * DAY,
               systematic_failure_fraction=0.3,
               systematic_failure_rate=10 * 0.01 / DAY,
               auto_repair_failure_probability=0.0,
               manual_repair_failure_probability=0.0,
               diagnosis_probability=1.0, auto_repair_time=10.0,
               manual_repair_time=60.0, seed=11)
    r = simulate_one(p)
    sim = ClusterSimulation(p, seed=11)
    result = sim.run()
    n_bad_left = sum(1 for s in sim.fleet.servers if s.is_bad)
    n_bad_start = int(round(0.3 * len(sim.fleet.servers)))
    # bad servers in the job get healed; only unexercised ones stay bad
    assert n_bad_left < n_bad_start


def test_retirement_removes_repeat_offenders():
    p = tiny(retirement_threshold=2, retirement_window=100 * DAY,
             systematic_failure_fraction=0.5,
             systematic_failure_rate=20 * 0.01 / DAY,
             random_failure_rate=0.01 / DAY,
             auto_repair_failure_probability=1.0,   # repairs never fix
             manual_repair_failure_probability=1.0,
             diagnosis_probability=1.0,
             auto_repair_time=5.0, manual_repair_time=10.0,
             job_length=16 * DAY, working_pool_size=64, spare_pool_size=32)
    r = simulate_one(p)
    assert r.n_retired > 0


# ---------------------------------------------------------------------------
# diagnosis
# ---------------------------------------------------------------------------

def test_undiagnosed_failures_counted():
    p = tiny(diagnosis_probability=0.5, random_failure_rate=1.0 / DAY,
             job_length=4 * DAY, seed=5)
    results = simulate(p, 6)
    undiag = sum(r.n_undiagnosed for r in results)
    total = sum(r.n_failures for r in results)
    assert total > 40
    assert 0.3 < undiag / total < 0.7


def test_misdiagnosis_sends_wrong_server():
    p = tiny(diagnosis_probability=1.0, diagnosis_uncertainty=0.5,
             random_failure_rate=1.0 / DAY, job_length=4 * DAY, seed=9)
    results = simulate(p, 6)
    mis = sum(r.n_misdiagnosed for r in results)
    total = sum(r.n_failures for r in results)
    assert mis > 0
    assert mis / total < 0.7


# ---------------------------------------------------------------------------
# conservation invariant
# ---------------------------------------------------------------------------

def test_server_conservation_after_run():
    p = tiny(random_failure_rate=1.0 / DAY, job_length=2 * DAY)
    sim = ClusterSimulation(p)
    sim.run()
    counts = sim.pools.conservation_counts()
    assert sum(counts.values()) == p.working_pool_size + p.spare_pool_size
    # after release_all, nothing should be RUNNING or STANDBY
    assert counts.get(ServerState.RUNNING.value, 0) == 0
    assert counts.get(ServerState.STANDBY.value, 0) == 0


def test_checkpoint_interval_loses_work():
    p = tiny(checkpoint_interval=60.0, random_failure_rate=2.0 / DAY,
             job_length=2 * DAY)
    r = simulate_one(p)
    if r.n_failures > 0:
        assert r.lost_work > 0.0
        assert r.useful_work == pytest.approx(p.job_length)


# ---------------------------------------------------------------------------
# distributions / regeneration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "weibull"])
def test_alternative_distributions_run(dist):
    p = tiny(failure_distribution=dist, random_failure_rate=0.5 / DAY,
             job_length=DAY)
    r = simulate_one(p)
    assert r.total_time > 0
    assert not r.timed_out


def test_bad_set_regeneration_runs():
    p = tiny(bad_set_regeneration_period=0.5 * DAY,
             random_failure_rate=0.5 / DAY, job_length=2 * DAY)
    r = simulate_one(p)
    assert not r.timed_out


def test_seeds_are_reproducible():
    p = tiny(random_failure_rate=1.0 / DAY)
    a = simulate_one(p, seed=42)
    b = simulate_one(p, seed=42)
    assert a.total_time == b.total_time
    assert a.n_failures == b.n_failures


def test_different_seeds_differ():
    p = tiny(random_failure_rate=1.0 / DAY)
    a = simulate_one(p, seed=1)
    b = simulate_one(p, seed=2)
    assert (a.total_time, a.n_failures) != (b.total_time, b.n_failures)


def test_validate_rejects_bad_configs():
    with pytest.raises(ValueError):
        Params(working_pool_size=10, job_size=100).validate()
    with pytest.raises(ValueError):
        Params(systematic_failure_fraction=1.5).validate()
    with pytest.raises(ValueError):
        Params(recovery_time=-1).validate()


def test_aggregate_statistics():
    p = tiny(random_failure_rate=0.5 / DAY)
    results = simulate(p, 5)
    agg = aggregate(results)
    st = agg["total_time"]
    assert st.minimum <= st.median <= st.maximum
    assert st.percentiles[25] <= st.percentiles[75]
    assert st.std >= 0
