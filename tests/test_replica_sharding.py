"""Replica-axis sharding: exactness contract, merges, loud refusals.

Two tiers:

* single-device tests (always run): a 1-device mesh must be
  BIT-IDENTICAL to the unsharded engine — same programs, same streams —
  plus the seed-splitting units and every refusal path;
* multi-device tests (``skipif jax.device_count() < N``): run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (scripts/ci.sh
  runs them in a forced-4-device subprocess).  These pin the per-shard
  independence contract *exactly*: shard ``s`` of a sharded run equals
  an independent unsharded run over ``R/n`` replicas with the folded key
  ``shard_keys(key, n)[s]`` — across every output lane, including the
  histogram accumulators and the run-duration ring buffers, so the
  ``out_specs`` concatenation merge is exact, not just exact-in-law.

See docs/scaling.md for the contract these tests enforce.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.vectorized as vz
import repro.core.vectorized_multijob as mj
from repro.core import faultdomains, hazards
from repro.core.multijob import JobSpec
from repro.core.params import Params
from repro.parallel import sharding as rsharding

N_DEV = jax.device_count()

needs4 = pytest.mark.skipif(
    N_DEV < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


def small_params(**kw):
    base = dict(working_pool_size=32, spare_pool_size=4, job_size=16,
                job_length=500.0)
    base.update(kw)
    return Params(**base)


def assert_same(a, b, path=""):
    if isinstance(a, dict):
        assert a.keys() == b.keys(), path
        for k in a:
            assert_same(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, list):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            assert_same(x, y, f"{path}[{i}]")
    else:
        assert np.array_equal(np.asarray(a), np.asarray(b)), path


# ---------------------------------------------------------------------------
# seed splitting units
# ---------------------------------------------------------------------------

def test_shard_keys_mesh1_is_base_key():
    key = jax.random.PRNGKey(3)
    keys = rsharding.shard_keys(key, 1)
    assert keys.shape == (1,) + key.shape
    assert np.array_equal(np.asarray(keys[0]), np.asarray(key))


def test_shard_keys_are_folded_and_distinct():
    key = jax.random.PRNGKey(3)
    keys = rsharding.shard_keys(key, 4)
    assert keys.shape == (4,) + key.shape
    rows = {tuple(np.asarray(k).tolist()) for k in keys}
    assert len(rows) == 4
    for s in range(4):
        expect = jax.random.fold_in(key, np.uint32(s))
        assert np.array_equal(np.asarray(keys[s]), np.asarray(expect))


def test_replica_mesh_too_many_devices_refused():
    with pytest.raises(ValueError, match="host_platform_device_count"):
        rsharding.replica_mesh(10 ** 6)


# ---------------------------------------------------------------------------
# mesh-size-1 bit-identity (single device — tier-1)
# ---------------------------------------------------------------------------

def test_mesh1_simulate_ctmc_bit_identical():
    p = small_params()
    r0 = vz.simulate_ctmc(p, n_replicas=64, seed=7, max_steps=256)
    r1 = vz.simulate_ctmc(p, n_replicas=64, seed=7, max_steps=256,
                          shards=1)
    assert_same(r0, r1)


def test_mesh1_sweep_bit_identical():
    pts = [small_params(), small_params(spare_pool_size=8),
           small_params(random_failure_rate=0.001)]
    r0 = vz.simulate_ctmc_sweep(pts, n_replicas=32, seed=7, max_steps=256)
    r1 = vz.simulate_ctmc_sweep(pts, n_replicas=32, seed=7, max_steps=256,
                                shards=1)
    assert_same(r0, r1)


def test_mesh1_via_params_knob():
    p0, p1 = small_params(), small_params(engine_shards=1)
    r0 = vz.simulate_ctmc(p0, n_replicas=64, seed=7, max_steps=256)
    r1 = vz.simulate_ctmc(p1, n_replicas=64, seed=7, max_steps=256)
    assert_same(r0, r1)


def test_mesh1_multijob_bit_identical():
    cluster = Params(working_pool_size=64, spare_pool_size=8,
                     repair_servers=2)
    jobs = (JobSpec(job_size=16, job_length=400.0),
            JobSpec(job_size=24, job_length=300.0, warm_standbys=2))
    pts = [(cluster, jobs), (cluster.replace(spare_pool_size=4), jobs)]
    r0 = mj.simulate_multijob_ctmc_sweep(pts, n_replicas=16, seed=5,
                                         max_steps=256)
    r1 = mj.simulate_multijob_ctmc_sweep(pts, n_replicas=16, seed=5,
                                         max_steps=256, shards=1)
    assert_same(r0, r1)


# ---------------------------------------------------------------------------
# refusal paths (single device)
# ---------------------------------------------------------------------------

def test_non_divisible_replica_count_refused():
    with pytest.raises(ValueError, match="does not divide"):
        vz.simulate_ctmc(small_params(), n_replicas=10, seed=0,
                         max_steps=64, shards=3)


def test_missing_devices_refused():
    if N_DEV >= 8:
        pytest.skip("enough devices — refusal not reachable")
    with pytest.raises(ValueError, match="host_platform_device_count"):
        vz.simulate_ctmc(small_params(), n_replicas=64, seed=0,
                         max_steps=64, shards=8)


def test_mixed_engine_shards_grid_refused():
    pts = [small_params(engine_shards=0), small_params(engine_shards=1)]
    with pytest.raises(ValueError, match="engine_shards"):
        vz.simulate_ctmc_sweep(pts, n_replicas=32, max_steps=64)


def test_bad_knob_values_refused():
    with pytest.raises(ValueError, match="engine_shards"):
        small_params(engine_shards=-1).validate()
    with pytest.raises(ValueError, match="event_race_impl"):
        small_params(event_race_impl="cuda").validate()


# ---------------------------------------------------------------------------
# kernel dispatch through the engine (single device)
# ---------------------------------------------------------------------------

def test_engine_pallas_interpret_matches_ref():
    p = small_params()
    r0 = vz.simulate_ctmc(p, n_replicas=64, seed=7, max_steps=256,
                          impl="ref")
    r1 = vz.simulate_ctmc(p, n_replicas=64, seed=7, max_steps=256,
                          impl="pallas_interpret")
    assert_same(r0, r1)


def test_engine_pallas_off_tpu_refused():
    if jax.default_backend() == "tpu":
        pytest.skip("compiled pallas is legitimate on TPU")
    with pytest.raises(ValueError, match="pallas_interpret"):
        vz.simulate_ctmc(small_params(), n_replicas=32, seed=0,
                         max_steps=64, impl="pallas")


# ---------------------------------------------------------------------------
# multi-device exactness (forced host devices)
# ---------------------------------------------------------------------------

def _reference_shard(p, key_s, R_loc, max_steps, max_runs=None):
    """Unsharded engine run a shard must reproduce exactly."""
    chunk = min(vz.DEFAULT_CHUNK_STEPS, max_steps)
    channels = vz._hist_channels([p])
    init_state = vz._initial_state(p, R_loc, max_runs)
    out = vz._run_chunked(
        vz._params_vector(p), key_s, 1, R_loc, chunk,
        jnp.int32(max_steps // chunk), max_steps % chunk, None, True,
        vz._struct_key(p), hazards.hazard_kind(p), hazards.repair_kind(p),
        channels, faultdomains.scenario_key(p), init_state,
        hazards.hazard_segment_count(p), hazards.repair_segment_count(p))
    return vz._extract(out, channels=channels)


@needs4
@pytest.mark.parametrize("n_shards", [2, 4])
def test_per_shard_independence_exact(n_shards):
    """Shard s of a sharded run == an independent unsharded run with the
    folded key — every lane, including histograms and run records."""
    p = small_params(max_run_records=4)   # small ring so it wraps
    R, steps = 64, 512
    R_loc = R // n_shards
    sharded = vz.simulate_ctmc(p, n_replicas=R, seed=3, max_steps=steps,
                               shards=n_shards)
    keys = rsharding.shard_keys(jax.random.PRNGKey(3), n_shards)
    for s in range(n_shards):
        ref = _reference_shard(p, keys[s], R_loc, steps)
        rows = slice(s * R_loc, (s + 1) * R_loc)
        got = {k: np.asarray(v)[rows] if np.asarray(v).ndim and
               np.asarray(v).shape[0] == R else np.asarray(v)
               for k, v in sharded.items()}
        assert_same(got, ref, f"shard{s}")


@needs4
def test_histogram_merge_exact_across_devices():
    """The concatenation merge preserves every per-replica histogram row
    — summing merged rows equals summing the per-shard references."""
    p = small_params()
    assert p.histogram is not None
    R, steps = 64, 512
    sharded = vz.simulate_ctmc(p, n_replicas=R, seed=11, max_steps=steps,
                               shards=4)
    keys = rsharding.shard_keys(jax.random.PRNGKey(11), 4)
    hist_keys = [k for k in sharded if k.startswith("hist_")
                 and k != "hist_edges"]
    assert hist_keys, "default HistogramSpec should emit channels"
    for hk in hist_keys:
        merged = np.asarray(sharded[hk])
        parts = [np.asarray(_reference_shard(p, keys[s], R // 4,
                                             steps)[hk])
                 for s in range(4)]
        assert np.array_equal(merged, np.concatenate(parts, axis=0)), hk
        assert np.array_equal(merged.sum(0),
                              sum(pt.sum(0) for pt in parts)), hk


@needs4
def test_sharded_sweep_matches_per_shard_runs():
    """A 2-point sweep on 4 devices: per-point rows still concatenate
    shard-major and match the sharded single-point runs."""
    pts = [small_params(), small_params(spare_pool_size=8)]
    sw = vz.simulate_ctmc_sweep(pts, n_replicas=32, seed=9, max_steps=256,
                                shards=4)
    for p, got in zip(pts, sw):
        single = vz.simulate_ctmc(p, n_replicas=32, seed=9, max_steps=256,
                                  shards=4)
        assert_same(got, single)


@needs4
def test_sharded_multijob_runs_and_merges():
    cluster = Params(working_pool_size=96, spare_pool_size=8,
                     repair_servers=2)
    jobs = (JobSpec(job_size=16, job_length=400.0),
            JobSpec(job_size=24, job_length=300.0))
    out = mj.simulate_multijob_ctmc_sweep([(cluster, jobs)], n_replicas=32,
                                          seed=5, max_steps=256, shards=4)
    [res] = out
    assert res["makespan"].shape == (32,)
    assert len(res["per_job"]) == 2
    assert set(np.asarray(res["completed"])) <= {0.0, 1.0}


@needs4
def test_sharded_sweep_one_compile_per_signature():
    pts = [small_params(), small_params(random_failure_rate=0.001)]
    vz.simulate_ctmc_sweep(pts, n_replicas=32, seed=1, max_steps=128,
                           shards=4)
    before = vz.shard_compile_cache_size()
    vz.simulate_ctmc_sweep([small_params(random_failure_rate=0.002),
                            small_params(spare_pool_size=2)],
                           n_replicas=32, seed=2, max_steps=128, shards=4)
    after = vz.shard_compile_cache_size()
    if before is None or after is None:
        pytest.skip("jax cache introspection unavailable")
    assert after == before, "same static signature must not recompile"
