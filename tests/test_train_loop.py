"""End-to-end training substrate: loop, checkpoint/restart, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core.params import Params as ClusterParams
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault_tolerance import StragglerPolicy
from repro.train.loop import TrainLoopConfig, checkpoint_cadence, train
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_at)

SHAPE = ShapeSpec("tiny_train", 32, 4, "train")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic_loss():
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}   # d/dw of w^2
        params, state, stats = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert stats["grad_norm"] >= 0


def test_lr_schedule_warmup_and_decay():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=100, min_lr_fraction=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_grad_clipping():
    cfg = OptimizerConfig(learning_rate=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    _, _, stats = adamw_update(params, {"w": jnp.asarray([1e3, 0., 0.])},
                               state, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(1e3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    p1 = SyntheticTokenPipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    p2 = SyntheticTokenPipeline(cfg)
    p2.seek(3)
    np.testing.assert_array_equal(next(p2)["tokens"], batches[3]["tokens"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])


def test_pipeline_shards_are_disjoint():
    a = SyntheticTokenPipeline(DataConfig(1000, 16, 8, seed=1, n_shards=2,
                                          shard_id=0)).batch_at(0)
    b = SyntheticTokenPipeline(DataConfig(1000, 16, 8, seed=1, n_shards=2,
                                          shard_id=1)).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_state_roundtrip():
    cfg = DataConfig(100, 8, 2, seed=3)
    p = SyntheticTokenPipeline(cfg)
    for _ in range(4):
        next(p)
    state = p.state_dict()
    q = SyntheticTokenPipeline(cfg)
    q.load_state_dict(state)
    np.testing.assert_array_equal(next(p)["tokens"], next(q)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
             "opt": {"step": np.int32(7)}}
    save_checkpoint(str(tmp_path), 7, state, extra={"data_step": 7})
    step, restored, extra = restore_checkpoint(str(tmp_path))
    assert step == 7 and extra["data_step"] == 7
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])


def test_checkpoint_bfloat16_roundtrip(tmp_path):
    """bf16 leaves bit-cast through npz (raw void otherwise) — regression
    for the production dtype of every full-size config."""
    import ml_dtypes
    w = np.arange(16, dtype=np.float32).astype(ml_dtypes.bfloat16)
    state = {"params": {"w": w}, "opt": {"v": np.float32(2.0)}}
    save_checkpoint(str(tmp_path), 3, state)
    _, restored, _ = restore_checkpoint(str(tmp_path))
    assert restored["params"]["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        restored["params"]["w"].view(np.uint16), w.view(np.uint16))
    # and it must be jnp-consumable (the restart path)
    arr = jnp.asarray(restored["params"]["w"])
    assert arr.dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": np.ones(64, np.float32)}
    path = save_checkpoint(str(tmp_path), 1, state)
    # corrupt the shard
    import numpy as _np
    shard = os.path.join(path, "shard_00000.npz")
    with _np.load(shard) as z:
        data = {k: z[k] for k in z.files}
    data["w"][:8] = -99.0
    _np.savez(shard, **data)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path))


def test_async_checkpointer_keeps_latest(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, {"w": np.full(4, step, np.float32)})
    ck.close()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert len(steps) <= 2


# ---------------------------------------------------------------------------
# straggler policy
# ---------------------------------------------------------------------------

def test_straggler_policy_fires_after_patience():
    pol = StragglerPolicy(threshold=2.0, patience=2, window=16)
    fired = []
    for i in range(10):
        fired.append(pol.observe(1.0))
    for i in range(3):
        fired.append(pol.observe(5.0))
    assert any(fired)
    assert pol.n_stragglers >= 2


# ---------------------------------------------------------------------------
# end-to-end loop (tiny model, real steps on CPU)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup(tmp_path_factory):
    cfg = get_config("qwen2.5-3b", smoke=True).replace(dtype="float32")
    bundle = build_model(cfg)
    mesh = make_host_mesh()
    return cfg, bundle, mesh


def test_train_loop_runs_and_loss_finite(tiny_setup, tmp_path):
    cfg, bundle, mesh = tiny_setup
    out = train(bundle, mesh, SHAPE,
                TrainLoopConfig(total_steps=8, log_every=2,
                                checkpoint_dir=str(tmp_path / "ck"),
                                checkpoint_every=4),
                OptimizerConfig(learning_rate=1e-3, warmup_steps=2,
                                total_steps=8))
    assert out["steps"] == 8
    assert np.isfinite(out["final_loss"])
    assert latest_step(str(tmp_path / "ck")) == 8


def test_train_loop_restarts_from_checkpoint(tiny_setup, tmp_path):
    """Inject a failure mid-run; the loop must restore and converge on the
    same step count, with lost steps accounted."""
    cfg, bundle, mesh = tiny_setup
    ckdir = str(tmp_path / "ck2")
    out = train(bundle, mesh, SHAPE,
                TrainLoopConfig(total_steps=10, log_every=5,
                                checkpoint_dir=ckdir, checkpoint_every=3,
                                inject_failures=True,
                                deterministic_failure_steps=[7],
                                cluster=ClusterParams(
                                    random_failure_rate=0.0,
                                    systematic_failure_rate=0.0)),
                OptimizerConfig(learning_rate=1e-3, warmup_steps=2,
                                total_steps=10))
    assert out["recovery"]["n_failures"] == 1
    assert out["recovery"]["n_restores"] == 1
    assert out["recovery"]["lost_steps"] == 1   # 7 -> back to checkpoint @6
    assert out["steps"] >= 10
    assert np.isfinite(out["final_loss"])


def test_resume_after_process_restart(tiny_setup, tmp_path):
    """Simulates a full job restart: second train() call resumes from the
    checkpoint directory rather than starting over."""
    cfg, bundle, mesh = tiny_setup
    ckdir = str(tmp_path / "ck3")
    train(bundle, mesh, SHAPE,
          TrainLoopConfig(total_steps=4, checkpoint_dir=ckdir,
                          checkpoint_every=2),
          OptimizerConfig(warmup_steps=1, total_steps=8))
    out = train(bundle, mesh, SHAPE,
                TrainLoopConfig(total_steps=8, checkpoint_dir=ckdir,
                                checkpoint_every=2),
                OptimizerConfig(warmup_steps=1, total_steps=8))
    assert out["steps"] == 4  # resumed at 4, ran to 8


def test_checkpoint_cadence_from_young_daly():
    cluster = ClusterParams()  # paper defaults
    cfg = TrainLoopConfig(checkpoint_cost_minutes=1.0, step_minutes=1.0,
                          cluster=cluster)
    cadence = checkpoint_cadence(cfg)
    # MTBF ~ 1/0.0305 per min -> tau = sqrt(2*1*32.8) ~ 8.1 steps
    assert 2 <= cadence <= 30


def test_checkpoint_cadence_explicit_override():
    assert checkpoint_cadence(TrainLoopConfig(checkpoint_every=17)) == 17
