"""Property-based tests (hypothesis) for system invariants."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep; skip module cleanly
from hypothesis import given, settings, strategies as st

from repro.core import (MINUTES_PER_DAY, ClusterSimulation, Params,
                        expected_failures, simulate_one)
from repro.core.server import ServerState

DAY = MINUTES_PER_DAY

param_strategy = st.fixed_dictionaries({
    "job_size": st.integers(4, 48),
    "extra_working": st.integers(0, 16),
    "spare_pool_size": st.integers(0, 8),
    "warm_standbys": st.integers(0, 6),
    "random_failure_rate": st.floats(0.0, 4.0 / DAY),
    "systematic_mult": st.integers(0, 10),
    "systematic_failure_fraction": st.floats(0.0, 0.5),
    "recovery_time": st.floats(0.0, 60.0),
    "host_selection_time": st.floats(0.0, 15.0),
    "waiting_time": st.floats(0.0, 60.0),
    "diagnosis_probability": st.floats(0.0, 1.0),
    "diagnosis_uncertainty": st.floats(0.0, 1.0),
    "automated_repair_probability": st.floats(0.0, 1.0),
    "auto_repair_failure_probability": st.floats(0.0, 1.0),
    "manual_repair_failure_probability": st.floats(0.0, 1.0),
    "auto_repair_time": st.floats(1.0, 4 * 1440.0),
    "manual_repair_time": st.floats(1.0, 8 * 1440.0),
    "seed": st.integers(0, 2 ** 31 - 1),
})


def build(draw: dict) -> Params:
    d = dict(draw)
    job = d.pop("job_size")
    extra = d.pop("extra_working")
    mult = d.pop("systematic_mult")
    rate = d["random_failure_rate"]
    return Params(job_size=job,
                  working_pool_size=job + d["warm_standbys"] + extra,
                  job_length=1 * DAY,
                  systematic_failure_rate=mult * rate,
                  **d)


@settings(max_examples=25, deadline=None)
@given(param_strategy)
def test_invariants_hold_for_random_configs(draw):
    p = build(draw)
    sim = ClusterSimulation(p)
    r = sim.run()

    # total time covers the useful work plus accounted overheads
    assert r.total_time >= p.job_length - 1e-6
    assert r.useful_work == pytest.approx(p.job_length, rel=1e-9) or r.timed_out
    assert r.total_time + 1e-6 >= (p.host_selection_time + r.useful_work
                                   + r.recovery_overhead + r.stall_time
                                   + r.lost_work)

    # failure taxonomy adds up
    assert r.n_failures == r.n_random_failures + r.n_systematic_failures
    assert r.n_undiagnosed <= r.n_failures
    assert r.n_misdiagnosed <= r.n_failures - r.n_undiagnosed
    assert r.n_manual_repairs <= r.n_auto_repairs

    # replacement events can't exceed diagnosed failures
    diagnosed = r.n_failures - r.n_undiagnosed
    assert (r.n_standby_swaps + r.n_host_selections) <= diagnosed + 1

    # non-negativity
    for field in ("stall_time", "recovery_overhead", "lost_work"):
        assert getattr(r, field) >= -1e-9

    # server conservation across all states
    counts = sim.pools.conservation_counts()
    assert sum(counts.values()) == p.working_pool_size + p.spare_pool_size
    assert counts.get(ServerState.RUNNING.value, 0) == 0  # released at end


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.floats(0.1 / DAY, 4.0 / DAY))
def test_paired_seeds_monotone_in_rate(seed, rate):
    """Same seed, higher failure rate => at least as many failures."""
    base = dict(job_size=16, working_pool_size=24, spare_pool_size=4,
                warm_standbys=2, job_length=0.5 * DAY, seed=seed)
    lo = simulate_one(Params(random_failure_rate=rate, **base))
    hi = simulate_one(Params(random_failure_rate=rate * 3, **base))
    # statistical monotonicity at matched seeds isn't guaranteed per-path
    # (different sample streams), so compare against analytic expectation
    assert hi.n_failures + 3 * math.sqrt(hi.n_failures + 1) >= lo.n_failures


@settings(max_examples=10, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(0, 100))
def test_diagnosis_probability_bounds_repairs(dp, seed):
    p = Params(job_size=16, working_pool_size=22, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=2.0 / DAY, diagnosis_probability=dp,
               auto_repair_time=5.0, manual_repair_time=10.0, seed=seed)
    r = simulate_one(p)
    # every auto repair stems from a diagnosed failure
    assert r.n_auto_repairs <= r.n_failures - r.n_undiagnosed
    if dp == 0.0:
        assert r.n_undiagnosed == r.n_failures
        assert r.n_auto_repairs == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 60))
def test_expected_failures_scaling(seed):
    """Doubling job length ~doubles failures (renewal property)."""
    base = dict(job_size=64, working_pool_size=80, spare_pool_size=8,
                warm_standbys=8, random_failure_rate=1.0 / DAY, seed=seed)
    short = simulate_one(Params(job_length=1 * DAY, **base))
    long_ = simulate_one(Params(job_length=4 * DAY, **base))
    if short.n_failures >= 20:
        ratio = long_.n_failures / max(short.n_failures, 1)
        assert 2.0 < ratio < 8.0
