"""Property-based tests (hypothesis) for system invariants."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep; skip module cleanly
from hypothesis import given, settings, strategies as st

from repro.core import (MINUTES_PER_DAY, ClusterSimulation, Params,
                        expected_failures, simulate_one)
from repro.core.histograms import Histogram, HistogramSpec
from repro.core.server import ServerState

DAY = MINUTES_PER_DAY

param_strategy = st.fixed_dictionaries({
    "job_size": st.integers(4, 48),
    "extra_working": st.integers(0, 16),
    "spare_pool_size": st.integers(0, 8),
    "warm_standbys": st.integers(0, 6),
    "random_failure_rate": st.floats(0.0, 4.0 / DAY),
    "systematic_mult": st.integers(0, 10),
    "systematic_failure_fraction": st.floats(0.0, 0.5),
    "recovery_time": st.floats(0.0, 60.0),
    "host_selection_time": st.floats(0.0, 15.0),
    "waiting_time": st.floats(0.0, 60.0),
    "diagnosis_probability": st.floats(0.0, 1.0),
    "diagnosis_uncertainty": st.floats(0.0, 1.0),
    "automated_repair_probability": st.floats(0.0, 1.0),
    "auto_repair_failure_probability": st.floats(0.0, 1.0),
    "manual_repair_failure_probability": st.floats(0.0, 1.0),
    "auto_repair_time": st.floats(1.0, 4 * 1440.0),
    "manual_repair_time": st.floats(1.0, 8 * 1440.0),
    "seed": st.integers(0, 2 ** 31 - 1),
})


def build(draw: dict) -> Params:
    d = dict(draw)
    job = d.pop("job_size")
    extra = d.pop("extra_working")
    mult = d.pop("systematic_mult")
    rate = d["random_failure_rate"]
    return Params(job_size=job,
                  working_pool_size=job + d["warm_standbys"] + extra,
                  job_length=1 * DAY,
                  systematic_failure_rate=mult * rate,
                  **d)


@settings(max_examples=25, deadline=None)
@given(param_strategy)
def test_invariants_hold_for_random_configs(draw):
    p = build(draw)
    sim = ClusterSimulation(p)
    r = sim.run()

    # total time covers the useful work plus accounted overheads
    assert r.total_time >= p.job_length - 1e-6
    assert r.useful_work == pytest.approx(p.job_length, rel=1e-9) or r.timed_out
    assert r.total_time + 1e-6 >= (p.host_selection_time + r.useful_work
                                   + r.recovery_overhead + r.stall_time
                                   + r.lost_work)

    # failure taxonomy adds up
    assert r.n_failures == r.n_random_failures + r.n_systematic_failures
    assert r.n_undiagnosed <= r.n_failures
    assert r.n_misdiagnosed <= r.n_failures - r.n_undiagnosed
    assert r.n_manual_repairs <= r.n_auto_repairs

    # replacement events can't exceed diagnosed failures
    diagnosed = r.n_failures - r.n_undiagnosed
    assert (r.n_standby_swaps + r.n_host_selections) <= diagnosed + 1

    # non-negativity
    for field in ("stall_time", "recovery_overhead", "lost_work"):
        assert getattr(r, field) >= -1e-9

    # server conservation across all states
    counts = sim.pools.conservation_counts()
    assert sum(counts.values()) == p.working_pool_size + p.spare_pool_size
    assert counts.get(ServerState.RUNNING.value, 0) == 0  # released at end


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.floats(0.1 / DAY, 4.0 / DAY))
def test_paired_seeds_monotone_in_rate(seed, rate):
    """Same seed, higher failure rate => at least as many failures."""
    base = dict(job_size=16, working_pool_size=24, spare_pool_size=4,
                warm_standbys=2, job_length=0.5 * DAY, seed=seed)
    lo = simulate_one(Params(random_failure_rate=rate, **base))
    hi = simulate_one(Params(random_failure_rate=rate * 3, **base))
    # statistical monotonicity at matched seeds isn't guaranteed per-path
    # (different sample streams), so compare against analytic expectation
    assert hi.n_failures + 3 * math.sqrt(hi.n_failures + 1) >= lo.n_failures


@settings(max_examples=10, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(0, 100))
def test_diagnosis_probability_bounds_repairs(dp, seed):
    p = Params(job_size=16, working_pool_size=22, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=2.0 / DAY, diagnosis_probability=dp,
               auto_repair_time=5.0, manual_repair_time=10.0, seed=seed)
    r = simulate_one(p)
    # every auto repair stems from a diagnosed failure
    assert r.n_auto_repairs <= r.n_failures - r.n_undiagnosed
    if dp == 0.0:
        assert r.n_undiagnosed == r.n_failures
        assert r.n_auto_repairs == 0


# ---------------------------------------------------------------------------
# structure-padded CTMC sweeps: padded == unpadded
# ---------------------------------------------------------------------------

def _ctmc_base(job: int, spare: int, warm: int) -> Params:
    return Params(job_size=job, working_pool_size=job + warm + 4,
                  spare_pool_size=spare, warm_standbys=warm,
                  job_length=0.2 * DAY, random_failure_rate=2.0 / DAY,
                  recovery_time=5.0, auto_repair_time=30.0,
                  manual_repair_time=60.0)


@settings(max_examples=4, deadline=None)
@given(st.integers(4, 8), st.integers(0, 2), st.integers(0, 2),
       st.integers(1, 4), st.integers(0, 1000))
def test_padded_sweep_matches_unpadded_mixed_grid(job, spare, warm, dsize,
                                                  seed):
    """A mixed-structure grid through the single-compilation padded path
    must reproduce the legacy one-program-per-structure results per point
    (same seed -> same per-replica-column uniforms on both paths)."""
    from repro.core.vectorized import simulate_ctmc_sweep

    base = _ctmc_base(job, spare, warm)
    grid = [base,
            base.replace(job_size=job + dsize,
                         working_pool_size=job + dsize + warm + 4),
            base.replace(spare_pool_size=spare + 2)]
    pad = simulate_ctmc_sweep(grid, n_replicas=16, seed=seed, max_steps=256,
                              padded=True)
    ref = simulate_ctmc_sweep(grid, n_replicas=16, seed=seed, max_steps=256,
                              padded=False)
    for i, (a, b) in enumerate(zip(pad, ref)):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_allclose(
                a[k], b[k], rtol=1e-6, atol=1e-6,
                err_msg=f"point {i} metric {k}")


@settings(max_examples=4, deadline=None)
@given(st.integers(4, 8), st.integers(0, 2), st.integers(0, 1000))
def test_padded_sweep_bit_identical_same_structure(job, warm, seed):
    """Non-structural grids (rates/times only differ) must be bit-identical
    between the padded and per-structure paths — same program semantics,
    same random stream."""
    from repro.core.vectorized import simulate_ctmc_sweep

    base = _ctmc_base(job, 2, warm)
    grid = [base.replace(recovery_time=v) for v in (5.0, 15.0)]
    pad = simulate_ctmc_sweep(grid, n_replicas=16, seed=seed, max_steps=256,
                              padded=True)
    ref = simulate_ctmc_sweep(grid, n_replicas=16, seed=seed, max_steps=256,
                              padded=False)
    for i, (a, b) in enumerate(zip(pad, ref)):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"point {i} metric {k}")


# ---------------------------------------------------------------------------
# streaming histogram accumulator (pure-numpy reference)
# ---------------------------------------------------------------------------

_spec = st.builds(
    lambda low, span, bins: HistogramSpec(low=low, high=low * span,
                                          n_bins=bins),
    st.floats(1e-3, 10.0), st.floats(10.0, 1e6), st.integers(1, 64))

_values = st.lists(st.floats(0.0, 1e8, allow_nan=False), max_size=200)


@settings(max_examples=50, deadline=None)
@given(_spec, _values, st.integers(1, 5))
def test_histogram_merge_associative_commutative(spec, values, n_chunks):
    """Accumulation order across replica chunks never matters: any
    chunking + merge order equals one-shot accumulation."""
    whole = Histogram.from_values(spec, values)
    chunks = [values[i::n_chunks] for i in range(n_chunks)]
    parts = [Histogram.from_values(spec, c) for c in chunks]
    fold_fwd = parts[0]
    for p in parts[1:]:
        fold_fwd = fold_fwd.merge(p)
    fold_rev = parts[-1]
    for p in reversed(parts[:-1]):
        fold_rev = p.merge(fold_rev)        # flipped operand order too
    np.testing.assert_array_equal(whole.counts, fold_fwd.counts)
    np.testing.assert_array_equal(whole.counts, fold_rev.counts)
    assert whole.total == len(values)


@settings(max_examples=50, deadline=None)
@given(_spec, _values)
def test_histogram_cdf_monotone_and_percentiles_ordered(spec, values):
    h = Histogram.from_values(spec, values)
    cdf = h.cdf()
    assert (np.diff(cdf) >= -1e-12).all()
    if values:
        assert cdf[-1] == pytest.approx(1.0)
        qs = [h.percentile(q) for q in (10, 50, 90, 99, 99.9)]
        assert all(a <= b + 1e-12 for a, b in zip(qs, qs[1:]))
        assert h.minimum() <= h.maximum()


@settings(max_examples=50, deadline=None)
@given(_spec, st.integers(0, 1000))
def test_histogram_bin_edges_left_closed_right_open(spec, i):
    """A value exactly on edge k lands deterministically in the bin that
    edge *opens* (counts slot k+1), never the one it closes."""
    edges = spec.edges()
    k = i % len(edges)
    h = Histogram.from_values(spec, [edges[k]])
    assert h.counts[k + 1] == 1.0
    assert h.counts.sum() == 1.0
    # and a value epsilon below stays in the closing bin
    below = np.nextafter(edges[k], 0.0)
    h2 = Histogram.from_values(spec, [below])
    assert h2.counts[k] == 1.0


# ---------------------------------------------------------------------------
# shape-bucketed CTMC sweeps: real rows identical to unbucketed
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(1, 5), st.integers(3, 20), st.integers(0, 1000))
def test_bucketed_sweep_value_identical_on_real_rows(n_points, n_rep, seed):
    """Power-of-two padding points/replicas and the traced chunk count
    must leave every real row bit-identical for any (P, R, seed)."""
    from repro.core.vectorized import simulate_ctmc_sweep

    base = _ctmc_base(6, 2, 1)
    grid = [base.replace(recovery_time=4.0 + 2.0 * i)
            for i in range(n_points)]
    a = simulate_ctmc_sweep(grid, n_replicas=n_rep, seed=seed,
                            max_steps=256, bucketed=True)
    b = simulate_ctmc_sweep(grid, n_replicas=n_rep, seed=seed,
                            max_steps=256, bucketed=False)
    for i, (x, y) in enumerate(zip(a, b)):
        assert set(x) == set(y)
        for k in x:
            np.testing.assert_array_equal(x[k], y[k],
                                          err_msg=f"point {i} metric {k}")


# ---------------------------------------------------------------------------
# multi-job shared-pool properties (dispatcher fairness, determinism,
# job-permutation invariance) — both engines
# ---------------------------------------------------------------------------

def _mj_contended() -> Params:
    """Tight shared pool + slow finite shop: stalls are near-certain."""
    return Params(working_pool_size=26, spare_pool_size=2, job_size=8,
                  job_length=800.0, random_failure_rate=0.01,
                  systematic_failure_rate=0.02, auto_repair_time=120.0,
                  manual_repair_time=300.0, repair_servers=2,
                  histogram=None)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_multijob_dispatcher_fifo_fairness(seed):
    """A repaired server handed to a stalled job always goes to the one
    stalled *earliest*: a job that stalled first is never passed over in
    favor of one that stalled later (FIFO starvation-freedom)."""
    from repro.core import JobSpec
    from repro.core.multijob import MultiJobSimulation

    jobs = [JobSpec(12, 800.0, warm_standbys=0),
            JobSpec(12, 1000.0, warm_standbys=0)]
    sim = MultiJobSimulation(_mj_contended(), jobs, seed=seed)
    disp = sim.dispatcher
    orig = disp.on_server_return
    handoffs = []

    def checked(server):
        stalled = [s for s in disp.schedulers
                   if s._stall_event is not None
                   and not s._stall_event.triggered]
        before = disp.stall_handoffs
        orig(server)
        if disp.stall_handoffs == before + 1:
            receiver = next(s for s in stalled
                            if s._stall_event.triggered)
            handoffs.append((receiver._stall_since,
                             min(s._stall_since for s in stalled)))

    # the shop captured the dispatcher's bound method at construction
    sim.repair_shop.on_return = checked
    sim.run()
    assert handoffs, "config failed to produce any stall hand-off"
    for got, earliest in handoffs:
        assert got == earliest


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 1000))
def test_multijob_seed_deterministic_both_engines(seed):
    """Same seed => identical multi-job results, on each engine."""
    from repro.core import (JobSpec, simulate_multijob,
                            simulate_multijob_ctmc_sweep)

    cluster = Params(working_pool_size=30, spare_pool_size=3, job_size=8,
                     job_length=300.0, random_failure_rate=0.004,
                     systematic_failure_rate=0.01, auto_repair_time=60.0,
                     manual_repair_time=150.0, repair_servers=2,
                     histogram=None)
    jobs = (JobSpec(12, 300.0, warm_standbys=1),
            JobSpec(8, 400.0, warm_standbys=1))

    a, b = (simulate_multijob_ctmc_sweep([(cluster, jobs)], n_replicas=8,
                                         seed=seed)[0] for _ in range(2))
    np.testing.assert_array_equal(a["makespan"], b["makespan"])
    for j in range(len(jobs)):
        for k in ("total_time", "n_failures", "stall_time"):
            np.testing.assert_array_equal(a["per_job"][j][k],
                                          b["per_job"][j][k])

    r0, r1 = (simulate_multijob(cluster, list(jobs), n_replications=2,
                                base_seed=seed) for _ in range(2))
    for x, y in zip(r0, r1):
        assert x.makespan == y.makespan
        assert x.stall_events == y.stall_events
        for px, py in zip(x.per_job, y.per_job):
            assert px.total_time == py.total_time
            assert px.n_failures == py.n_failures


@settings(max_examples=6, deadline=None)
@given(st.permutations([0, 1, 2]), st.integers(0, 500))
def test_multijob_fleet_metrics_permutation_invariant(perm, seed):
    """Relabeling jobs must not change fleet-pooled outcomes.  With no
    failures the trajectories are deterministic, so the invariance is
    exact on both engines (per-job marginals follow the permutation)."""
    from repro.core import (JobSpec, simulate_multijob,
                            simulate_multijob_ctmc_sweep)

    cluster = Params(working_pool_size=30, spare_pool_size=2, job_size=4,
                     job_length=50.0, random_failure_rate=0.0,
                     systematic_failure_rate=0.0, histogram=None)
    jobs = [JobSpec(8, 50.0, warm_standbys=1),
            JobSpec(6, 80.0, warm_standbys=1),
            JobSpec(4, 30.0, warm_standbys=0)]
    permuted = [jobs[i] for i in perm]

    r0 = simulate_multijob(cluster, jobs, base_seed=seed)[0]
    r1 = simulate_multijob(cluster, permuted, base_seed=seed)[0]
    assert r0.makespan == r1.makespan
    assert (sorted(r.total_time for r in r0.per_job)
            == sorted(r.total_time for r in r1.per_job))

    p0 = simulate_multijob_ctmc_sweep([(cluster, tuple(jobs))],
                                      n_replicas=4, seed=seed)[0]
    p1 = simulate_multijob_ctmc_sweep([(cluster, tuple(permuted))],
                                      n_replicas=4, seed=seed)[0]
    np.testing.assert_array_equal(p0["makespan"], p1["makespan"])
    for j, pj in enumerate(perm):
        np.testing.assert_array_equal(p0["per_job"][pj]["total_time"],
                                      p1["per_job"][j]["total_time"])


def test_multijob_permutation_invariant_in_law_with_failures():
    """With failures the fleet-pooled distribution is exchangeable in
    the job labels: permuting the job list moves the per-job marginals
    with it and leaves fleet metrics statistically unchanged."""
    from repro.core import JobSpec, simulate_multijob_ctmc_sweep

    cluster = Params(working_pool_size=40, spare_pool_size=4, job_size=8,
                     job_length=500.0, random_failure_rate=0.003,
                     systematic_failure_rate=0.008, auto_repair_time=90.0,
                     manual_repair_time=240.0, repair_servers=2,
                     histogram=None)
    jobs = (JobSpec(16, 500.0, warm_standbys=1),
            JobSpec(8, 700.0, warm_standbys=1))
    p0 = simulate_multijob_ctmc_sweep([(cluster, jobs)],
                                      n_replicas=512, seed=3)[0]
    p1 = simulate_multijob_ctmc_sweep([(cluster, jobs[::-1])],
                                      n_replicas=512, seed=4)[0]
    for metric in ("makespan", "stall_handoffs", "n_auto_repairs",
                   "n_shop_queued"):
        a = np.asarray(p0[metric], float)
        b = np.asarray(p1[metric], float)
        se = math.sqrt(a.var(ddof=1) / len(a) + b.var(ddof=1) / len(b))
        z = (a.mean() - b.mean()) / max(se, 1e-12)
        assert abs(z) < 4.0, f"{metric}: z={z:+.2f}"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 60))
def test_expected_failures_scaling(seed):
    """Doubling job length ~doubles failures (renewal property)."""
    base = dict(job_size=64, working_pool_size=80, spare_pool_size=8,
                warm_standbys=8, random_failure_rate=1.0 / DAY, seed=seed)
    short = simulate_one(Params(job_length=1 * DAY, **base))
    long_ = simulate_one(Params(job_length=4 * DAY, **base))
    if short.n_failures >= 20:
        ratio = long_.n_failures / max(short.n_failures, 1)
        assert 2.0 < ratio < 8.0


# ---------------------------------------------------------------------------
# checkpoint rollback + goodput (deterministic twins live in
# tests/test_checkpoint_opt.py)
# ---------------------------------------------------------------------------

_CKPT = Params(job_size=16, working_pool_size=20, spare_pool_size=4,
               warm_standbys=2, job_length=1 * DAY,
               random_failure_rate=0.2 / DAY,
               checkpoint_interval=113.0, checkpoint_cost=5.0)


@settings(max_examples=15, deadline=None)
@given(iv=st.sampled_from([0.0, 30.0, 113.0, 300.0]),
       cost=st.sampled_from([0.0, 2.0, 10.0]),
       seed=st.integers(0, 2 ** 16))
def test_goodput_is_a_fraction(iv, cost, seed):
    """goodput = useful/wall in [0, 1] for any rollback configuration."""
    from repro.core import run_replications
    from repro.core.vectorized import simulate_ctmc

    p = _CKPT.replace(checkpoint_interval=iv, checkpoint_cost=cost,
                      seed=seed)
    out = simulate_ctmc(p, n_replicas=8, seed=seed)
    g = np.asarray(out["useful_work"]) / np.maximum(
        np.asarray(out["total_time"]), 1e-9)
    assert (g >= 0.0).all() and (g <= 1.0 + 1e-9).all()
    rep = run_replications(p, 8, engine="ctmc")
    assert 0.0 <= rep.stats["goodput"].mean <= 1.0 + 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_goodput_monotone_nonincreasing_in_cost(seed):
    """Under common random numbers a dearer write can only hurt: mean
    goodput is non-increasing in checkpoint_cost (same seed, same
    interval, CRN across the traced-cost grid)."""
    from repro.core import run_replications_batch

    grid = [_CKPT.replace(checkpoint_cost=c, seed=seed)
            for c in (0.0, 2.0, 8.0, 20.0)]
    reps = run_replications_batch(grid, 32, engine="ctmc")
    g = [r.stats["goodput"].mean for r in reps]
    for a, b in zip(g, g[1:]):
        assert b <= a + 1e-9, g


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), cost=st.sampled_from([0.0, 5.0, 25.0]))
def test_lost_work_zero_at_interval_zero(seed, cost):
    """With the interval off the rollback lanes must be exactly dead."""
    from repro.core.vectorized import simulate_ctmc

    p = _CKPT.replace(checkpoint_interval=0.0, checkpoint_cost=cost,
                      seed=seed)
    out = simulate_ctmc(p, n_replicas=8, seed=seed)
    assert float(np.abs(out["lost_work"]).max()) == 0.0
    assert float(np.abs(out["checkpoint_overhead"]).max()) == 0.0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 10))
def test_checkpoint_work_conservation_both_engines(seed):
    """Every compute minute is either banked (useful) or rolled back
    (lost): run records satisfy sum(records) = useful + lost - cur_run
    on the CTMC engine and sum(records) = useful + lost on completed
    event-engine runs."""
    from repro.core import simulate
    from repro.core.vectorized import simulate_ctmc

    p = _CKPT.replace(seed=seed, max_run_records=4096)
    for r in simulate(p, 2):
        if r.timed_out:
            continue
        assert sum(r.run_durations) == pytest.approx(
            r.useful_work + r.lost_work, rel=1e-6)
    out = simulate_ctmc(p, n_replicas=4, seed=seed)
    buf = np.asarray(out["run_durations"], np.float64)
    n_runs = np.asarray(out["n_runs"], np.int64)
    if (n_runs <= buf.shape[1]).all():
        valid = np.arange(buf.shape[1])[None, :] < n_runs[:, None]
        recorded = np.where(valid, buf, 0.0).sum(axis=1)
        expect = (np.asarray(out["useful_work"], np.float64)
                  + np.asarray(out["lost_work"], np.float64)
                  - np.asarray(out["cur_run"], np.float64))
        np.testing.assert_allclose(recorded, expect, rtol=1e-5, atol=1e-6)
