"""Dedicated scheduler + multi-job tests: spare-pool contention and the
priority order of preemptive replacement.

The single-job Scheduler waterfall (paper §II-B) is exercised here as
isolated unit tests with hand-driven environments — standby priority,
working-pool cost, spare-pool *preemption* cost, and the stall path with
its member/non-member host-selection asymmetry — and the multi-job
dispatcher's longest-stalled-first (FIFO) hand-off is pinned
deterministically rather than only statistically.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import Params
from repro.core.engine import Environment
from repro.core.metrics import RunResult
from repro.core.multijob import (Dispatcher, JobSpec, MultiJobSimulation,
                                 simulate_multijob)
from repro.core.pool import PoolManager
from repro.core.scheduler import Scheduler
from repro.core.server import Fleet, ServerState


def make_sched(**kw):
    base = dict(job_size=4, working_pool_size=8, spare_pool_size=3,
                warm_standbys=1, job_length=100.0, host_selection_time=3.0,
                waiting_time=20.0, preemption_cost=5.0, recovery_time=1.0,
                histogram=None)
    base.update(kw)
    p = Params(**base)
    env = Environment()
    fleet = Fleet(p, np.random.default_rng(0))
    pools = PoolManager(p, fleet)
    metrics = RunResult()
    return env, p, pools, metrics, Scheduler(env, p, pools, metrics)


def drive(env, gen):
    """Run one scheduler generator to completion, return its value."""
    proc = env.process(gen, name="drv")
    env.run_until_process(proc)
    return proc.value


# ---------------------------------------------------------------------------
# replacement priority order (the §II-B waterfall)
# ---------------------------------------------------------------------------

def test_standby_beats_working_beats_spare():
    env, p, pools, m, sched = make_sched()
    drive(env, sched.initial_allocation())
    t0 = env.now

    # 1. standby: immediate, no host selection, no preemption
    s = drive(env, sched.acquire_replacement())
    assert env.now == t0 and s.state is ServerState.RUNNING
    assert (m.n_standby_swaps, m.n_host_selections, m.n_preemptions) \
        == (1, 0, 0)

    # 2. standbys empty -> working pool at host-selection cost
    s = drive(env, sched.acquire_replacement())
    assert env.now == t0 + p.host_selection_time
    assert s.sid in sched.job_members
    assert (m.n_standby_swaps, m.n_host_selections, m.n_preemptions) \
        == (1, 1, 0)

    # 3. drain the working pool -> spare preemption pays waiting +
    #    preemption + host selection and bumps n_preemptions
    while pools.pop_working() is not None:
        pass
    t1 = env.now
    s = drive(env, sched.acquire_replacement())
    assert env.now == pytest.approx(
        t1 + p.waiting_time + p.preemption_cost + p.host_selection_time)
    assert (m.n_standby_swaps, m.n_host_selections, m.n_preemptions) \
        == (1, 2, 1)
    assert pools.n_spare_free == p.spare_pool_size - 1


def test_stall_member_rejoins_without_host_selection():
    env, p, pools, m, sched = make_sched(spare_pool_size=0)
    running = drive(env, sched.initial_allocation())
    while pools.pop_working() is not None:
        pass
    sched.standbys.clear()

    member = running[0]

    def stall_then_return():
        acq = env.process(sched.acquire_replacement(), name="acq")
        yield env.timeout(7.0)                   # starving for 7 min
        sched.on_server_return(member)           # repair completes
        yield acq
        return acq.value

    t0 = env.now
    hs_before = m.n_host_selections
    got = drive(env, stall_then_return())
    assert got is member
    # members skip host selection on return; only stall time is charged
    assert env.now == pytest.approx(t0 + 7.0)
    assert m.n_host_selections == hs_before
    assert m.stall_time == pytest.approx(7.0)


def test_stall_nonmember_pays_host_selection():
    env, p, pools, m, sched = make_sched(spare_pool_size=0)
    drive(env, sched.initial_allocation())
    while pools.pop_working() is not None:
        pass
    sched.standbys.clear()
    stranger = pools.fleet.servers[p.working_pool_size - 1]
    sched.job_members.discard(stranger.sid)

    def stall_then_return():
        acq = env.process(sched.acquire_replacement(), name="acq")
        yield env.timeout(2.0)
        sched.on_server_return(stranger)
        yield acq
        return acq.value

    t0 = env.now
    got = drive(env, stall_then_return())
    assert got is stranger
    assert env.now == pytest.approx(t0 + 2.0 + p.host_selection_time)
    assert stranger.sid in sched.job_members


def test_bulk_draw_waterfall_and_shortfall():
    """draw_replacements drains standbys, then working, then spares, and
    reports the shortfall when everything is dry."""
    env, p, pools, m, sched = make_sched(warm_standbys=2)
    drive(env, sched.initial_allocation())
    free_w = pools.n_working_free
    want = 2 + free_w + p.spare_pool_size + 2   # 2 more than exist
    out, t_fw, t_fs, shortfall = sched.draw_replacements(want)
    assert len(out) == want - 2 and shortfall == 2
    assert (m.n_standby_swaps, t_fw, t_fs) == (2, free_w, p.spare_pool_size)
    assert m.n_host_selections == free_w + p.spare_pool_size
    assert m.n_preemptions == p.spare_pool_size
    assert pools.n_working_free == 0 and pools.n_spare_free == 0


# ---------------------------------------------------------------------------
# multi-job: spare-pool contention + FIFO hand-off priority
# ---------------------------------------------------------------------------

def test_dispatcher_hands_to_longest_stalled_job():
    env, p, pools, m, sched_a = make_sched()
    sched_b = Scheduler(env, p, pools, RunResult())
    for s, since in ((sched_a, 10.0), (sched_b, 4.0)):
        s._stall_event = env.event()
        s._stall_server = None
        s._stall_since = since
    disp = Dispatcher(pools)
    disp.register(sched_a)
    disp.register(sched_b)
    server = pools.fleet.servers[0]
    disp.on_server_return(server)
    # job B stalled at t=4 < job A at t=10: B has waited longest
    assert sched_b._stall_event.triggered
    assert sched_b._stall_server is server
    assert not sched_a._stall_event.triggered
    assert disp.stall_handoffs == 1


def test_spare_pool_contention_between_jobs():
    """Two jobs share one tight spare pool: both record preemptions, and
    the spare pool is observably the contended resource."""
    jobs = [JobSpec(job_size=20, job_length=0.5 * DAY, warm_standbys=0),
            JobSpec(job_size=20, job_length=0.5 * DAY, warm_standbys=0)]
    tight = Params(job_size=20, working_pool_size=40, spare_pool_size=4,
                   warm_standbys=0, job_length=0.5 * DAY,
                   random_failure_rate=6.0 / DAY,
                   systematic_failure_rate=0.0,
                   diagnosis_probability=1.0, auto_repair_time=6 * 60.0,
                   seed=3)
    reps = simulate_multijob(tight, jobs, n_replications=4)
    pre = [sum(r.n_preemptions for r in rep.per_job) for rep in reps]
    assert sum(pre) > 0, "no spare-pool preemptions despite zero headroom"
    # with zero working-pool headroom every replacement is a spare draw
    # or a stall; host selections must match spare preemptions
    for rep in reps:
        for r in rep.per_job:
            assert r.n_standby_swaps == 0
            assert r.n_host_selections >= r.n_preemptions


def test_multijob_conserves_servers():
    jobs = [JobSpec(job_size=12, job_length=0.25 * DAY, warm_standbys=1),
            JobSpec(job_size=12, job_length=0.25 * DAY, warm_standbys=1)]
    p = Params(job_size=12, working_pool_size=32, spare_pool_size=4,
               warm_standbys=1, job_length=0.25 * DAY,
               random_failure_rate=3.0 / DAY, seed=7)
    sim = MultiJobSimulation(p, jobs)
    result = sim.run()
    assert all(not r.timed_out for r in result.per_job)
    total = p.working_pool_size + p.spare_pool_size
    # every server is accounted for: back in a pool, retired, or still
    # in the shared repair shop — none leaked into a finished job
    in_shop = len(sim.repair_shop.in_repair)
    assert (sim.pools.n_working_free + sim.pools.n_spare_free
            + sim.pools.n_retired + in_shop == total)
