"""Non-exponential repairs + lognormal failures vs the event oracle.

The CTMC engine now runs Weibull / lognormal / deterministic *repair*
distributions through the repair-slot lane (durations sampled at shop
entry by exact inverse CDF — the same machinery the failure race uses,
:class:`repro.core.hazards.HazardSampler`), and lognormal *failures*
via Ogata thinning against the numerically-located hazard-mode bound.
These tests pin the acceptance criteria:

  * ``supports()`` says yes and ``engine=auto`` dispatches to ``ctmc``;
  * metric *means* match the event oracle within sampling error
    (z < 3.5 on pinned seeds, the test_vectorized.py discipline);
  * histogram percentiles match within one bin width in a stall-bound
    regime where the ETTR distribution IS the repair distribution;
  * weibull k=1 repairs statistically reduce to the validated
    exponential program, and exponential repairs keep the PR 4 program
    structure exactly (no slot state, original 8-wide uniform stream);
  * a repair-parameter grid compiles exactly one XLA program;
  * truncated horizons: a repair still in flight when the job completes
    is dropped by BOTH engines, and a repair completing *exactly* at
    ``total_time`` counts on both (repair-first tie resolution, matching
    the event heap's insertion order);
  * the float64 age carve-out (``Params.age_dtype``) closes the
    large-age cancellation of the weibull conditional inversion.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import (OneWaySweep, Params, resolve_engine,
                        run_replications, simulate)
from repro.core.hazards import (hazard_kind, repair_kind,
                                weibull_conditional_ttf)
from repro.core.metrics import histograms_from_arrays, histograms_from_results
from repro.core.vectorized import (_initial_state, _n_uniforms,
                                   _params_vector, _step_u, simulate_ctmc,
                                   simulate_ctmc_sweep, supports)

N_EVENT = 40
N_CTMC = 768

#: same small-but-busy cluster as tests/test_nonexp.py: cheap event
#: replications, O(100) failures per run for tight statistics, repair
#: times short enough that the shop stays busy without stalling.
BASE = dict(job_size=24, working_pool_size=32, spare_pool_size=4,
            warm_standbys=2, job_length=2 * DAY,
            random_failure_rate=2.0 / DAY,
            systematic_failure_rate=4.0 / DAY, recovery_time=5.0,
            auto_repair_time=30.0, manual_repair_time=120.0, seed=5)

WB_REPAIR = Params(repair_distribution="weibull",
                   distribution_kwargs={"k": 0.7}, **BASE)
LN_REPAIR = Params(repair_distribution="lognormal",
                   distribution_kwargs={"sigma": 1.2}, **BASE)
DET_REPAIR = Params(repair_distribution="deterministic", **BASE)
LN_FAIL = Params(failure_distribution="lognormal", **BASE)
COMBINED = Params(failure_distribution="lognormal",
                  repair_distribution="weibull",
                  distribution_kwargs={"k": 0.7, "sigma": 1.0}, **BASE)


def compare(p: Params, metrics, n_event=N_EVENT, n_ctmc=N_CTMC, z_tol=3.5):
    out = simulate_ctmc(p, n_replicas=n_ctmc, seed=0)
    assert out["completed"].mean() > 0.99, "CTMC replicas did not finish"
    assert out["n_repair_overflow"].sum() == 0, "repair-slot lane overflowed"
    res = simulate(p, n_event)
    for m in metrics:
        ev = np.array([getattr(r, m) for r in res], float)
        ct = out[m]
        se = np.sqrt(ct.std() ** 2 / len(ct) + ev.std(ddof=1) ** 2 / len(ev))
        z = (ev.mean() - ct.mean()) / max(se, 1e-9)
        assert abs(z) < z_tol, (m, ev.mean(), ct.mean(), z)
    return out, res


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_supported_families_and_dispatch():
    assert repair_kind(WB_REPAIR) == "weibull"
    assert repair_kind(LN_REPAIR) == "lognormal"
    assert repair_kind(DET_REPAIR) == "deterministic"
    assert hazard_kind(LN_FAIL) == "lognormal"
    for p in (WB_REPAIR, LN_REPAIR, DET_REPAIR, LN_FAIL, COMBINED):
        assert supports(p)
        assert resolve_engine(p, "auto") == "ctmc"
    # degenerate parameterizations and user-registered families fall back
    assert repair_kind(WB_REPAIR.replace(
        distribution_kwargs={"k": -1.0})) is None
    assert hazard_kind(LN_FAIL.replace(
        distribution_kwargs={"sigma": 0.0})) is None
    assert not supports(WB_REPAIR.replace(repair_distribution="nonsense"))


def test_exponential_repairs_keep_pr4_program_structure():
    """The exponential reduction must be *structural*, not statistical:
    no slot lane in the scan state and the original 8-wide uniform
    stream, so the compiled program is the PR 4 one bit-for-bit."""
    state = _initial_state(Params(**BASE), 4)
    assert "repair_rem" not in state and "repair_stage" not in state
    assert _n_uniforms("exponential", "exponential") == 8
    # non-exponential repairs add exactly the slot lane + one uniform
    state = _initial_state(WB_REPAIR, 4)
    assert state["repair_rem"].shape[0] == 4
    assert bool(jnp.isinf(state["repair_rem"]).all())
    assert _n_uniforms("exponential", "weibull") == 9
    assert _n_uniforms("lognormal", "weibull") == 10


# ---------------------------------------------------------------------------
# cross-engine agreement (acceptance criteria)
# ---------------------------------------------------------------------------

def test_weibull_repairs_match_event_oracle():
    compare(WB_REPAIR, ["total_time", "n_failures", "n_auto_repairs",
                        "n_manual_repairs", "n_failed_repairs",
                        "recovery_overhead", "n_standby_swaps",
                        "useful_work"])


def test_lognormal_repairs_match_event_oracle():
    compare(LN_REPAIR, ["total_time", "n_failures", "n_auto_repairs",
                        "n_manual_repairs", "recovery_overhead"])


def test_deterministic_repairs_match_event_oracle():
    compare(DET_REPAIR, ["total_time", "n_failures", "n_auto_repairs",
                         "n_manual_repairs", "n_failed_repairs"])


def test_lognormal_failures_match_event_oracle():
    compare(LN_FAIL, ["total_time", "n_failures", "n_random_failures",
                      "n_systematic_failures", "n_auto_repairs",
                      "recovery_overhead", "useful_work"])


def test_combined_lognormal_failures_weibull_repairs():
    compare(COMBINED, ["total_time", "n_failures", "n_auto_repairs",
                       "n_manual_repairs", "recovery_overhead"])


def test_stall_bound_ettr_histogram_within_one_bin():
    """Starved pools: every failure stalls until its own repair returns,
    so the recovery (ETTR) histogram directly measures the sampled
    repair durations — percentile agreement here is the sharpest
    cross-engine check of the slot lane's inverse-CDF sampling."""
    p = Params(job_size=8, working_pool_size=9, spare_pool_size=0,
               warm_standbys=0, job_length=1 * DAY,
               random_failure_rate=4.0 / DAY,
               systematic_failure_rate=8.0 / DAY, recovery_time=5.0,
               auto_repair_time=45.0, manual_repair_time=180.0,
               diagnosis_probability=1.0,
               repair_distribution="weibull",
               distribution_kwargs={"k": 0.7}, seed=11)
    out = simulate_ctmc(p, n_replicas=512, seed=2)
    assert out["stall_time"].mean() > 0, "regime must actually stall"
    hc = histograms_from_arrays(out)
    he = histograms_from_results(simulate(p, 64), p.histogram)
    for ch in ("recovery", "run_duration"):
        sup = np.abs(hc[ch].cdf() - he[ch].cdf()).max()
        assert sup < 0.08, (ch, sup)
    hrec, erec = hc["recovery"], he["recovery"]
    assert hrec.total > 500 and erec.total > 500
    for q in (50, 90, 99):
        est, emp = hrec.percentile(q), erec.percentile(q)
        assert abs(est - emp) <= hrec.bin_width_at(emp), (q, est, emp)


def test_weibull_k1_repairs_reduce_to_exponential():
    """Weibull k=1 *is* exponential; the slot lane must reproduce the
    validated count-based exponential repair program statistically."""
    pw = WB_REPAIR.replace(distribution_kwargs={"k": 1.0})
    exp_out = simulate_ctmc(Params(**BASE), n_replicas=768, seed=0)
    wb_out = simulate_ctmc(pw, n_replicas=768, seed=1)
    for m in ("total_time", "n_failures", "n_auto_repairs",
              "n_manual_repairs", "recovery_overhead"):
        a, b = exp_out[m], wb_out[m]
        se = np.sqrt(a.std() ** 2 / len(a) + b.std() ** 2 / len(b))
        assert abs(a.mean() - b.mean()) / max(se, 1e-9) < 3.5, m


# ---------------------------------------------------------------------------
# batching mechanics
# ---------------------------------------------------------------------------

def test_repair_parameter_grid_compiles_once():
    from repro.core import vectorized

    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    short = dict(BASE, job_length=0.25 * DAY)
    base = Params(repair_distribution="weibull",
                  distribution_kwargs={"k": 0.7},
                  **short).replace(max_run_records=17)   # module-unique shape
    grid = [base.replace(auto_repair_time=v) for v in (20.0, 40.0, 60.0)]
    c0 = vectorized.compile_cache_size()
    simulate_ctmc_sweep(grid, n_replicas=12, seed=0, max_steps=1024)
    c1 = vectorized.compile_cache_size()
    assert c1 - c0 == 1, "a repair-parameter grid must share one program"


def test_single_point_sweep_bit_identical():
    for p in (WB_REPAIR, LN_FAIL, COMBINED):
        sweep = simulate_ctmc_sweep([p], n_replicas=21, seed=9,
                                    max_steps=4096)[0]
        single = simulate_ctmc(p, n_replicas=21, seed=9, max_steps=4096)
        assert set(sweep) == set(single)
        for k in sweep:
            np.testing.assert_array_equal(sweep[k], single[k], err_msg=k)


def test_mixed_repair_family_grid_runs_in_input_order():
    short = dict(BASE, job_length=0.25 * DAY)
    grid = [Params(**short),
            Params(repair_distribution="weibull",
                   distribution_kwargs={"k": 0.7}, **short),
            Params(failure_distribution="lognormal", **short),
            Params(**short).replace(recovery_time=40.0)]
    res = simulate_ctmc_sweep(grid, n_replicas=32, seed=1)
    assert len(res) == len(grid)
    for r in res:
        assert r["completed"].mean() > 0.99
    assert res[3]["total_time"].mean() > res[0]["total_time"].mean()


def test_sweep_engine_auto_takes_fast_path():
    sweep = OneWaySweep("rp", "auto_repair_time", [20.0, 60.0],
                        n_replications=16, base_params=WB_REPAIR.replace(
                            job_length=0.25 * DAY), engine="auto")
    res = sweep.run()
    assert [pt.engine for pt in res.points] == ["ctmc", "ctmc"]


def test_infinite_mean_repair_stage_sizes_to_physical_cap():
    """An infinite-mean repair stage (server never returns) must not
    crash the Little's-law slot sizing — the physical cap (every server
    in the shop) is the honest lane width there, including the NaN
    regime where the escalation term multiplies 0 * inf."""
    import math

    from repro.core.vectorized import _repair_slots_for

    p = WB_REPAIR.replace(manual_repair_time=math.inf)
    total = p.working_pool_size + p.spare_pool_size
    assert supports(p)
    assert 1 <= _repair_slots_for([p], "weibull") <= total
    nan_regime = p.replace(automated_repair_probability=1.0)
    assert 1 <= _repair_slots_for([nan_regime], "weibull") <= total


def test_repair_slot_overflow_is_surfaced():
    """A deliberately starved slot lane must count overflows and warn,
    never crash or silently drop the accounting."""
    p = Params(job_size=8, working_pool_size=16, spare_pool_size=0,
               warm_standbys=4, job_length=0.5 * DAY,
               random_failure_rate=8.0 / DAY, recovery_time=2.0,
               diagnosis_probability=1.0,
               repair_distribution="deterministic",
               auto_repair_time=5 * DAY, manual_repair_time=5 * DAY,
               repair_slots=1, seed=3)
    with pytest.warns(RuntimeWarning, match="repair-slot lane"):
        rep = run_replications(p, 64, engine="ctmc")
    assert rep.stats["n_repair_overflow"].mean > 0


# ---------------------------------------------------------------------------
# truncated horizons (engine parity at the job-completion boundary)
# ---------------------------------------------------------------------------

def test_repairs_in_flight_at_completion_dropped_on_both_engines():
    """A repair that has not finished when the job completes must not
    count on either engine (the event engine abandons pending repair
    processes; the CTMC scan freezes DONE replicas).  The pool is large
    enough that the job never stalls — a stalled job would legitimately
    wait out the 10-day repair and count it on both engines."""
    p = Params(job_size=4, working_pool_size=40, spare_pool_size=0,
               warm_standbys=8, job_length=0.5 * DAY,
               random_failure_rate=2.0 / DAY, systematic_failure_rate=0.0,
               recovery_time=2.0, diagnosis_probability=1.0,
               repair_distribution="deterministic",
               auto_repair_time=10 * DAY, manual_repair_time=10 * DAY,
               seed=7)
    out = simulate_ctmc(p, n_replicas=256, seed=0)
    res = simulate(p, 64)
    assert out["n_failures"].mean() > 0.3
    assert out["n_auto_repairs"].max() == 0
    assert max(r.n_auto_repairs for r in res) == 0
    assert any(r.n_failures > 0 for r in res)


def test_event_heap_runs_first_scheduled_at_equal_timestamps():
    """The event engine's convention the CTMC tie-break mirrors: at one
    timestamp, the earlier-scheduled timeout (the repair, submitted
    before the final phase started) runs first."""
    from repro.core.engine import Environment

    env = Environment()
    order = []

    def proc(name, delay):
        yield env.timeout(delay)
        order.append(name)

    env.process(proc("repair", 5.0), name="repair")       # scheduled first
    env.process(proc("complete", 5.0), name="complete")
    env.run()
    assert order == ["repair", "complete"]


def test_repair_completing_exactly_at_total_time_counts():
    """Exact tie between the repair residual and job completion: the
    repair resolves first (counted, histogram-binned) and the job
    completes at the same instant — identical to the event engine's
    heap order and total_time."""
    p = Params(job_size=4, working_pool_size=8, spare_pool_size=0,
               warm_standbys=0, job_length=100.0, host_selection_time=0.0,
               random_failure_rate=0.0, systematic_failure_rate=0.0,
               auto_repair_failure_probability=0.0,
               repair_distribution="deterministic", auto_repair_time=100.0,
               seed=0)
    state = _initial_state(p, 1)
    # one bad-class server mid-repair whose remaining time ties the
    # remaining work exactly
    state["repair_rem"] = state["repair_rem"].at[0, 0].set(100.0)
    state["repair_cls"] = state["repair_cls"].at[0, 0].set(1)
    pv = _params_vector(p)
    nu = _n_uniforms("exponential", "deterministic")
    u = jnp.full((1, nu), 0.5, jnp.float32)

    s1 = _step_u(state, u, pv, None, "exponential", "deterministic")
    assert float(s1["n_auto_repairs"][0]) == 1.0      # repair counted
    assert int(s1["phase"][0]) != 3                   # job not done yet
    assert float(s1["work_left"][0]) == 0.0
    assert bool(jnp.isinf(s1["repair_rem"]).all())    # slot freed
    t_tie = float(s1["t"][0])

    s2 = _step_u(s1, u, pv, None, "exponential", "deterministic")
    assert int(s2["phase"][0]) == 3                   # DONE at dt=0
    assert float(s2["total_time"][0]) == t_tie        # same instant
    assert float(s2["n_auto_repairs"][0]) == 1.0
    # the final run lands in the same histogram bin the event engine
    # would use for a 100-minute run duration
    edges = np.asarray(s2["hist_edges"])
    want_bin = int(np.searchsorted(edges, 100.0, side="right"))
    assert float(s2["hist"][0, 0, want_bin]) >= 1.0


# ---------------------------------------------------------------------------
# float64 age carve-out
# ---------------------------------------------------------------------------

def test_age_dtype_validation():
    with pytest.raises(ValueError, match="age_dtype"):
        Params(age_dtype="float16").validate()
    if not jax.config.jax_enable_x64:
        with pytest.raises(ValueError, match="x64"):
            simulate_ctmc(Params(age_dtype="float64", **BASE), n_replicas=4)


def test_float64_carve_out_closes_large_age_cancellation():
    """ROADMAP item: at age ~1e4 the float32 inversion
    ``(a^k + E/C)^(1/k) - a`` loses ~1e-3 min to cancellation; the
    float64 path must pin the error orders of magnitude lower."""
    age, k = 1.0e4, 1.5
    C, E = 1.0e-6, 0.1            # E/C << age^k: the cancellation regime
    ref = (age ** k + E / C) ** (1.0 / k) - age      # python float64

    f32 = float(weibull_conditional_ttf(
        jnp.float32(age), jnp.float32(C), k, jnp.float32(E)))
    err32 = abs(f32 - ref)

    jax.config.update("jax_enable_x64", True)
    try:
        f64 = float(weibull_conditional_ttf(
            jnp.float64(age), jnp.float64(C), k, jnp.float64(E)))
    finally:
        jax.config.update("jax_enable_x64", False)
    err64 = abs(f64 - ref)

    assert err32 > 1e-5, "test must sit in the cancellation regime"
    assert err64 < err32 / 10.0
    assert err64 < 1e-4 * max(ref, 1.0)


def test_age_dtype_float64_end_to_end():
    """The whole scan runs with the float64 age/repair lanes and stays
    statistically on top of the float32 program."""
    jax.config.update("jax_enable_x64", True)
    try:
        p64 = WB_REPAIR.replace(age_dtype="float64",
                                job_length=0.5 * DAY,
                                max_run_records=19)   # test-unique shapes
        p32 = p64.replace(age_dtype="float32")
        o64 = simulate_ctmc(p64, n_replicas=256, seed=0)
        o32 = simulate_ctmc(p32, n_replicas=256, seed=0)
    finally:
        jax.config.update("jax_enable_x64", False)
    assert o64["completed"].mean() > 0.99
    for m in ("total_time", "n_failures", "n_auto_repairs"):
        a, b = o64[m], o32[m]
        se = np.sqrt(a.std() ** 2 / len(a) + b.std() ** 2 / len(b))
        assert abs(a.mean() - b.mean()) / max(se, 1e-9) < 3.5, m


# ---------------------------------------------------------------------------
# budget sanity
# ---------------------------------------------------------------------------

def test_lognormal_budget_covers_thinning_candidates():
    """The derived step budget must absorb rejected thinning candidates
    (majorant-rate events), not just accepted failures — completion at
    the default budget is the observable contract."""
    out = simulate_ctmc(LN_FAIL, n_replicas=256, seed=4)
    assert out["completed"].mean() > 0.99
