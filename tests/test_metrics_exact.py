"""Exact run-duration tracking on the CTMC path (no approximation left).

The vectorized engine records failure-to-failure useful-compute intervals
in a fixed ring buffer per replica (``run_durations`` (R, max_runs) +
``n_runs`` + ``cur_run``).  These tests pin the invariants:

  * recorded intervals sum to the total useful time accrued;
  * ``run_duration_pooled`` matches the event engine's per-run records
    within 2 pooled standard errors (the former total_time/(n_failures+1)
    approximation fails this by construction);
  * the ``max_runs`` cap surfaces a truncation stat instead of silently
    dropping runs, and per-replica means stay exact under truncation.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import Params, run_replications, simulate
from repro.core.metrics import aggregate, aggregate_arrays
from repro.core.vectorized import simulate_ctmc

BASE = Params(job_size=24, working_pool_size=32, spare_pool_size=4,
              warm_standbys=2, job_length=1 * DAY,
              random_failure_rate=2.0 / DAY, recovery_time=5.0,
              auto_repair_time=30.0, manual_repair_time=120.0, seed=5)


def _valid_mask(buf: np.ndarray, n_runs: np.ndarray) -> np.ndarray:
    max_runs = buf.shape[1]
    return np.arange(max_runs)[None, :] < np.minimum(n_runs, max_runs)[:, None]


# ---------------------------------------------------------------------------
# interval bookkeeping invariants
# ---------------------------------------------------------------------------

def test_intervals_sum_to_total_useful_time():
    out = simulate_ctmc(BASE, n_replicas=64, seed=2)
    buf, n_runs, cur = out["run_durations"], out["n_runs"], out["cur_run"]
    assert (n_runs <= buf.shape[1]).all(), "grid sized to avoid truncation"
    sums = (buf * _valid_mask(buf, n_runs)).sum(axis=1)
    # every recorded interval is useful compute; the in-flight interval
    # (cur_run) is the only part not yet recorded
    np.testing.assert_allclose(sums + cur, out["useful_work"],
                               rtol=1e-4, atol=1.0)
    done = out["completed"] > 0
    assert done.any()
    np.testing.assert_allclose(sums[done], BASE.job_length, rtol=1e-4)
    assert (cur[done] == 0.0).all()


def test_run_count_is_failures_plus_completion():
    out = simulate_ctmc(BASE, n_replicas=64, seed=9)
    expected = out["n_failures"].astype(np.int64) \
        + (out["completed"] > 0).astype(np.int64)
    np.testing.assert_array_equal(out["n_runs"].astype(np.int64), expected)
    assert (out["run_durations"] >= 0.0).all()


# ---------------------------------------------------------------------------
# agreement with the event engine's per-run records
# ---------------------------------------------------------------------------

def test_run_duration_pooled_matches_event_engine():
    """Acceptance: CTMC run_duration_pooled within 2 pooled SEs of the
    event engine's exact per-run records on the seed comparison grid."""
    rep_c = run_replications(BASE, 512, engine="ctmc")
    results_e = simulate(BASE, 64)
    stats_e = aggregate(results_e)

    sc = rep_c.stats["run_duration_pooled"]
    se_ = stats_e["run_duration_pooled"]
    n_c = int(np.minimum(rep_c.arrays["n_runs"],
                         rep_c.arrays["run_durations"].shape[1]).sum())
    n_e = sum(len(r.run_durations) for r in results_e)
    assert n_c > 1000 and n_e > 100
    pooled_se = np.sqrt(sc.std ** 2 / n_c + se_.std ** 2 / n_e)
    z = (sc.mean - se_.mean) / max(pooled_se, 1e-9)
    assert abs(z) < 2.0, (sc.mean, se_.mean, z)
    # the distribution shape must agree too, not just the mean
    assert sc.percentiles[50] == pytest.approx(se_.percentiles[50], rel=0.25)


def test_mean_run_duration_is_not_the_old_approximation():
    """total_time/(n_failures+1) counts recovery/stall wall-clock inside
    the intervals; the exact records must exclude it."""
    rep = run_replications(BASE, 256, engine="ctmc")
    approx = rep.arrays["total_time"] / (rep.arrays["n_failures"] + 1.0)
    exact = rep.stats["mean_run_duration"].mean
    # overheads are ~5 min recovery per ~26 min run: the approximation
    # must be biased visibly high
    assert approx.mean() > exact * 1.05


# ---------------------------------------------------------------------------
# truncation behavior
# ---------------------------------------------------------------------------

def test_max_runs_cap_surfaces_truncation_stat():
    out = simulate_ctmc(BASE, n_replicas=16, seed=3, max_runs=4)
    assert out["run_durations"].shape == (16, 4)
    stats = aggregate_arrays(out)
    assert stats["run_duration_truncated"].mean > 0.0
    # n_runs keeps counting past the cap
    assert (out["n_runs"] > 4).any()


def test_mean_run_duration_exact_under_truncation():
    """The ring buffer overwrites old records, but the per-replica mean
    comes from the sum identity and must not move."""
    full = aggregate_arrays(simulate_ctmc(BASE, n_replicas=32, seed=4))
    trunc = aggregate_arrays(simulate_ctmc(BASE, n_replicas=32, seed=4,
                                           max_runs=4))
    assert trunc["mean_run_duration"].mean == pytest.approx(
        full["mean_run_duration"].mean, rel=1e-6)
    # pooled stats survive on the retained records (a tail sample of the
    # same stationary interval distribution)
    assert trunc["run_duration_pooled"].mean == pytest.approx(
        full["run_duration_pooled"].mean, rel=0.2)


def test_max_runs_zero_compiles_recording_out():
    """max_runs=0 drops the ring buffer from the scan (perf opt-out) but
    the exact mean survives via the n_runs/cur_run sum identity."""
    off = simulate_ctmc(BASE, n_replicas=16, seed=3, max_runs=0)
    assert off["run_durations"].shape == (16, 0)
    on = simulate_ctmc(BASE, n_replicas=16, seed=3)
    # recording never affects the trajectory itself
    np.testing.assert_array_equal(off["n_failures"], on["n_failures"])
    s_off, s_on = aggregate_arrays(off), aggregate_arrays(on)
    assert s_off["mean_run_duration"].mean == pytest.approx(
        s_on["mean_run_duration"].mean, rel=1e-6)
    # pooled stats degrade to pooling per-replica means, not NaN
    assert np.isfinite(s_off["run_duration_pooled"].mean)


def test_event_engine_reports_zero_truncation():
    stats = aggregate(simulate(BASE, 4))
    assert stats["run_duration_truncated"].mean == 0.0


def test_stat_of_empty_sequence_is_nan_filled_not_raising():
    """Empty inputs (empty sweeps, zero recorded runs) must produce a
    well-formed NaN Stat instead of raising from np.percentile, and the
    downstream CI helper must stay finite."""
    from repro.core.metrics import Stat, _PERCENTILES

    s = Stat.of([])
    for v in (s.mean, s.median, s.std, s.minimum, s.maximum):
        assert np.isnan(v)
    assert set(s.percentiles) == set(_PERCENTILES)
    assert all(np.isnan(v) for v in s.percentiles.values())
    assert s.ci95_halfwidth(0) == 0.0
    assert s.ci95_halfwidth(10) == 0.0   # NaN std -> 0, not NaN
    # singletons: well-defined with zero spread
    one = Stat.of([5.0])
    assert one.mean == 5.0 and one.std == 0.0 and one.ci95_halfwidth(1) == 0.0


def test_stat_from_empty_histogram_is_nan_filled():
    from repro.core.histograms import Histogram, HistogramSpec
    from repro.core.metrics import Stat

    s = Stat.from_histogram(Histogram(HistogramSpec()))
    assert np.isnan(s.mean) and np.isnan(s.percentiles[99.9])
    assert s.ci95_halfwidth(4) == 0.0


def test_aggregate_of_zero_replications_is_nan_not_error():
    stats = aggregate([])
    assert np.isnan(stats["total_time"].mean)
    assert np.isnan(stats["run_duration_pooled"].percentiles[99])


def test_fallback_approximation_for_foreign_arrays():
    """Arrays without run records (foreign producers) still aggregate,
    via the documented legacy approximation."""
    arrays = {"total_time": np.asarray([100.0, 200.0]),
              "useful_work": np.asarray([90.0, 150.0]),
              "n_failures": np.asarray([1.0, 3.0])}
    stats = aggregate_arrays(arrays)
    assert stats["mean_run_duration"].mean == pytest.approx(
        (100.0 / 2 + 200.0 / 4) / 2)
    assert stats["run_duration_truncated"].mean == 0.0
