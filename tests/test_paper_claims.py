"""Validation against the paper's own experimental claims (§IV).

Claims checked (at reduced replica counts for CI speed):
  1. Fig 2a: total training time increases monotonically with recovery
     time, at every working-pool size.
  2. Fig 2b: total training time increases with spare-pool waiting time,
     and the effect is strongest at the smallest pool.
  3. Capacity finding: pools beyond +32 servers over job+standbys give
     no significant further improvement (<1%) at Table-I rates.
  4. Flat-knob finding: repair-pipeline knobs have <5% effect in the
     over-provisioned regime.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY, Params
from repro.core.vectorized import simulate_ctmc

N = 160
JOB_DAYS = 16


def cell(pool: int, n=N, **kw) -> float:
    p = Params(job_length=JOB_DAYS * MINUTES_PER_DAY,
               working_pool_size=pool, **kw)
    out = simulate_ctmc(p, n_replicas=n, seed=0)
    return float(out["total_time"].mean())


@pytest.mark.slow
def test_fig2a_recovery_time_monotone():
    for pool in (4112, 4160):
        times = [cell(pool, recovery_time=rt) for rt in (10.0, 20.0, 30.0)]
        assert times[0] < times[1] < times[2], (pool, times)


@pytest.mark.slow
def test_fig2a_magnitude_matches_renewal_math():
    """Doubling recovery time adds ~E[failures]*delta to total time."""
    t10 = cell(4160, recovery_time=10.0)
    t30 = cell(4160, recovery_time=30.0)
    p = Params(job_length=JOB_DAYS * MINUTES_PER_DAY)
    expected_delta = p.expected_failures_per_minute() * p.job_length * 20.0
    assert t30 - t10 == pytest.approx(expected_delta, rel=0.35)


@pytest.mark.slow
def test_fig2b_waiting_time_hurts_small_pools_most():
    # zero-headroom pool: every post-standby failure must preempt
    tight_10 = cell(4112, waiting_time=10.0, warm_standbys=16)
    tight_30 = cell(4112, waiting_time=30.0, warm_standbys=16)
    big_10 = cell(4192, waiting_time=10.0, warm_standbys=16)
    big_30 = cell(4192, waiting_time=30.0, warm_standbys=16)
    assert tight_30 >= tight_10 - 1e-6
    # effect in the big pool is no larger than in the tight pool
    assert (big_30 - big_10) <= (tight_30 - tight_10) + 30.0


@pytest.mark.slow
def test_capacity_saturates_by_plus_32():
    t128 = cell(4128)
    t160 = cell(4160)
    t192 = cell(4192)
    assert abs(t192 - t160) / t160 < 0.01
    assert t128 >= t160 - 0.01 * t160


@pytest.mark.slow
def test_flat_knobs_in_overprovisioned_regime():
    base = cell(4160)
    variants = {
        "auto_repair_time": [(("auto_repair_time", v),) for v in (60., 180.)],
        "manual_repair_failure": [(("manual_repair_failure_probability", v),)
                                  for v in (0.1, 0.3)],
        "diagnosis": [(("diagnosis_probability", v),) for v in (0.6, 1.0)],
    }
    for name, settings_list in variants.items():
        for settings in settings_list:
            t = cell(4160, **dict(settings))
            assert abs(t - base) / base < 0.05, (name, settings, t, base)
