"""Engine dispatch layer (core.backend) + batched sweep execution."""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import (OneWaySweep, Params, resolve_engine,
                        run_replications, run_replications_batch)
from repro.core.vectorized import simulate_ctmc, simulate_ctmc_sweep

BASE = Params(job_size=48, working_pool_size=56, spare_pool_size=8,
              warm_standbys=4, job_length=2 * DAY,
              random_failure_rate=1.0 / DAY, seed=3)


# ---------------------------------------------------------------------------
# engine resolution
# ---------------------------------------------------------------------------

def test_auto_resolves_ctmc_for_default_model():
    assert resolve_engine(BASE, "auto") == "ctmc"


@pytest.mark.parametrize("params", [
    BASE.replace(retirement_threshold=3),
    # weibull/bathtub/lognormal failures, weibull/lognormal/deterministic
    # repairs, and checkpoint rollback run on the CTMC fast path now
    # (tests/test_nonexp.py, tests/test_repair_dist.py,
    # tests/test_checkpoint_opt.py); deterministic failures and
    # user-registered families still fall back
    BASE.replace(failure_distribution="deterministic"),
    BASE.replace(bad_set_regeneration_period=1440.0),
    BASE.replace(standbys_can_fail=True),
])
def test_auto_falls_back_to_event(params):
    assert resolve_engine(params, "auto") == "event"
    rep = run_replications(params, 2, engine="auto")
    assert rep.engine == "event"
    assert len(rep.results) == 2
    assert rep.stats["total_time"].mean > 0


def test_explicit_ctmc_raises_outside_envelope():
    with pytest.raises(ValueError, match="outside the CTMC envelope"):
        run_replications(BASE.replace(retirement_threshold=3), 2,
                         engine="ctmc")


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        run_replications(BASE, 2, engine="warp")


def test_ctmc_replications_carry_arrays_not_results():
    rep = run_replications(BASE, 16, engine="ctmc")
    assert rep.engine == "ctmc"
    assert rep.results == []
    assert rep.arrays["total_time"].shape == (16,)
    assert rep.n == 16
    # exact per-run records ride along with the scalar metrics
    assert rep.arrays["run_durations"].shape == (16, BASE.max_run_records)
    assert rep.arrays["n_runs"].shape == (16,)
    # n_retired is exactly zero inside the CTMC envelope; modeled
    # metrics like silent repair failures must be real counts
    assert rep.stats["n_retired"].mean == 0.0
    assert rep.stats["n_failed_repairs"].mean > 0.0
    assert rep.stats["overhead_fraction"].mean > 0.0


def test_batch_routes_mixed_grids_in_order():
    grid = [BASE, BASE.replace(failure_distribution="deterministic"),
            BASE.replace(recovery_time=40.0)]
    reps = run_replications_batch(grid, 4, engine="auto")
    assert [r.engine for r in reps] == ["ctmc", "event", "ctmc"]
    assert all(r.n == 4 for r in reps)


# ---------------------------------------------------------------------------
# batched sweep vs event engine: statistical agreement
# ---------------------------------------------------------------------------

def test_sweep_ctmc_agrees_with_event_engine():
    values = [10.0, 20.0, 40.0]
    ct = OneWaySweep("b", "recovery_time", values, n_replications=512,
                     base_params=BASE, engine="ctmc").run()
    ev = OneWaySweep("b", "recovery_time", values, n_replications=32,
                     base_params=BASE, engine="event").run()
    for pc, pe in zip(ct.points, ev.points):
        assert pc.engine == "ctmc" and pe.engine == "event"
        sc, se_ = pc.stats["total_time"], pe.stats["total_time"]
        pooled = np.sqrt(sc.std ** 2 / pc.n_replications
                         + se_.std ** 2 / pe.n_replications)
        z = (sc.mean - se_.mean) / max(pooled, 1e-9)
        assert abs(z) < 3.5, (pc.values, sc.mean, se_.mean, z)


def test_sweep_points_match_single_point_runs():
    """The batched grid must equal per-point simulate_ctmc statistically
    (same model, independent draws)."""
    pts = [BASE.replace(recovery_time=v) for v in (10.0, 30.0)]
    batched = simulate_ctmc_sweep(pts, n_replicas=256, seed=0)
    for p, out in zip(pts, batched):
        single = simulate_ctmc(p, n_replicas=256, seed=1)
        for m in ("total_time", "n_failures"):
            a, b = out[m], single[m]
            se = np.sqrt(a.std() ** 2 / len(a) + b.std() ** 2 / len(b))
            assert abs(a.mean() - b.mean()) < 3.5 * max(se, 1e-9), m
        assert out["completed"].mean() > 0.99


def test_sweep_monotone_in_recovery_time():
    """Common random numbers across points -> monotone even at tiny n."""
    values = [5.0, 20.0, 40.0]
    res = OneWaySweep("m", "recovery_time", values, n_replications=8,
                      base_params=BASE, engine="ctmc").run()
    ts = res.column("total_time")
    assert ts[0] < ts[1] < ts[2], ts


# ---------------------------------------------------------------------------
# structure padding (deterministic pins; hypothesis sweeps the structure
# space in tests/test_property.py where available)
# ---------------------------------------------------------------------------

STRUCT_GRID = [BASE,
               BASE.replace(job_size=40),
               BASE.replace(spare_pool_size=16, warm_standbys=8),
               BASE.replace(job_length=1 * DAY)]


def test_padded_sweep_bit_identical_to_per_structure():
    pad = simulate_ctmc_sweep(STRUCT_GRID, n_replicas=32, seed=5,
                              max_steps=512, padded=True)
    ref = simulate_ctmc_sweep(STRUCT_GRID, n_replicas=32, seed=5,
                              max_steps=512, padded=False)
    for i, (a, b) in enumerate(zip(pad, ref)):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"point {i} metric {k}")


def test_mixed_structure_grid_compiles_once():
    """The whole point of structure padding: a structural grid is one
    flat batch behind a single jit cache entry."""
    from repro.core import vectorized

    before = vectorized.compile_cache_size()
    if before is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    reps = run_replications_batch(STRUCT_GRID, 8, engine="ctmc",
                                  max_steps=448)
    after = vectorized.compile_cache_size()
    # <= 1: another test may already have populated this exact signature
    assert after - before <= 1
    assert [r.engine for r in reps] == ["ctmc"] * len(STRUCT_GRID)
    assert all(r.n == 8 for r in reps)


def test_structural_sweep_agrees_with_event_engine():
    """job_size is a structural knob; the padded CTMC path must stay
    statistically indistinguishable from the event oracle."""
    values = [24, 48]
    ct = OneWaySweep("s", "job_size", values, n_replications=512,
                     base_params=BASE.replace(working_pool_size=64),
                     engine="ctmc").run()
    ev = OneWaySweep("s", "job_size", values, n_replications=32,
                     base_params=BASE.replace(working_pool_size=64),
                     engine="event").run()
    for pc, pe in zip(ct.points, ev.points):
        sc, se_ = pc.stats["total_time"], pe.stats["total_time"]
        pooled = np.sqrt(sc.std ** 2 / pc.n_replications
                         + se_.std ** 2 / pe.n_replications)
        z = (sc.mean - se_.mean) / max(pooled, 1e-9)
        assert abs(z) < 3.5, (pc.values, sc.mean, se_.mean, z)


# ---------------------------------------------------------------------------
# early exit
# ---------------------------------------------------------------------------

def test_early_exit_identical_to_full_scan():
    """Finished replicas are inert, so stopping at the first all-DONE
    chunk boundary must be bit-identical to burning the whole budget."""
    a = simulate_ctmc(BASE, n_replicas=64, seed=11, early_exit=True)
    b = simulate_ctmc(BASE, n_replicas=64, seed=11, early_exit=False)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_early_exit_identical_for_sweep():
    pts = [BASE.replace(recovery_time=v) for v in (10.0, 30.0)]
    a = simulate_ctmc_sweep(pts, n_replicas=32, seed=7, early_exit=True)
    b = simulate_ctmc_sweep(pts, n_replicas=32, seed=7, early_exit=False)
    for oa, ob in zip(a, b):
        for k in oa:
            np.testing.assert_array_equal(oa[k], ob[k], err_msg=k)


# ---------------------------------------------------------------------------
# empty-sweep CSV (regression: rows[0] IndexError)
# ---------------------------------------------------------------------------

def test_write_csv_empty_sweep(tmp_path):
    res = OneWaySweep("empty", "recovery_time", [], n_replications=2,
                      base_params=BASE).run()
    assert res.points == []
    path = str(tmp_path / "empty.csv")
    res.write_csv(path)
    with open(path) as f:
        header = f.read().strip()
    assert header.startswith("recovery_time,")
    assert "total_time_ci95" in header
