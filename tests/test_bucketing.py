"""Power-of-two shape bucketing of the padded CTMC sweep.

Two invariants: (1) sweeps whose (points, replicas, step-budget)
signatures fall in the same power-of-two bucket share exactly one
compiled XLA program (the compile-count regression guard, also run by
``scripts/ci.sh`` via ``benchmarks/engine_perf.py --smoke``); (2) the
inert phase-DONE padding rows never leak — real rows are bit-identical
to the unbucketed path.
"""

import numpy as np
import pytest

from repro.core import MINUTES_PER_DAY as DAY
from repro.core import OneWaySweep, Params, run_replications_batch
from repro.core import vectorized
from repro.core.vectorized import (_next_pow2, simulate_ctmc,
                                   simulate_ctmc_sweep)

BASE = Params(job_size=16, working_pool_size=32, spare_pool_size=4,
              warm_standbys=2, job_length=0.1 * DAY,
              random_failure_rate=2.0 / DAY, recovery_time=5.0,
              auto_repair_time=30.0, manual_repair_time=60.0, seed=0)


def test_next_pow2():
    assert [_next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 33, 64)] \
        == [1, 1, 2, 4, 4, 8, 64, 64]


# ---------------------------------------------------------------------------
# compile-count regression (acceptance criterion)
# ---------------------------------------------------------------------------

#: a Params base no other test uses (distinct ring-buffer and histogram
#: shapes), so the compile-count assertions below measure cache entries
#: that only this module can create
def _unique_base():
    from repro.core import HistogramSpec
    return BASE.replace(max_run_records=7,
                        histogram=HistogramSpec(n_bins=40))


def test_same_bucket_sweeps_compile_exactly_one_program():
    """Different (P, R, step-budget), same power-of-two bucket -> the
    second sweep must not add a jit cache entry."""
    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    base = _unique_base()

    c0 = vectorized.compile_cache_size()
    grid_a = [base.replace(recovery_time=v) for v in (5.0, 10.0, 15.0)]
    run_replications_batch(grid_a, 12, engine="ctmc", max_steps=192)
    c1 = vectorized.compile_cache_size()

    # P: 3 -> bucket 4 vs 4 -> 4; R: 12 -> bucket 16 vs 9 -> 16; budget
    # 192 vs 256 (explicit budgets are honored exactly, so same-program
    # sharing needs whole chunks: both are multiples of 64, and the
    # chunk *count* is traced)
    grid_b = [base.replace(recovery_time=v) for v in (5.0, 10.0, 15.0, 20.0)]
    run_replications_batch(grid_b, 9, engine="ctmc", max_steps=256)
    c2 = vectorized.compile_cache_size()

    assert c1 - c0 == 1, "first sweep in a fresh bucket compiles once"
    assert c2 - c1 == 0, "same-bucket sweep must reuse the program"

    # and a sweep in a *different* R bucket compiles exactly one more
    run_replications_batch(grid_a, 20, engine="ctmc", max_steps=192)
    assert vectorized.compile_cache_size() - c2 == 1


def test_unbucketed_sweeps_recompile_per_shape():
    """The A/B control: bucketed=False keeps one program per exact
    (P, R) shape."""
    if vectorized.compile_cache_size() is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    base = _unique_base()
    grid = [base.replace(recovery_time=v) for v in (5.0, 10.0, 15.0)]
    c0 = vectorized.compile_cache_size()
    simulate_ctmc_sweep(grid, n_replicas=11, seed=0, max_steps=192,
                        bucketed=False)
    simulate_ctmc_sweep(grid, n_replicas=13, seed=0, max_steps=192,
                        bucketed=False)
    assert vectorized.compile_cache_size() - c0 == 2


# ---------------------------------------------------------------------------
# padding rows are inert
# ---------------------------------------------------------------------------

def test_bucketed_bit_identical_to_unbucketed_on_real_rows():
    """Deterministic pin with non-power-of-two P and R: padding points,
    padding replicas, and the rounded budget must not change a single
    bit of any real row."""
    grid = [BASE.replace(recovery_time=v) for v in (5.0, 10.0, 15.0)]
    a = simulate_ctmc_sweep(grid, n_replicas=21, seed=4, max_steps=256,
                            bucketed=True)
    b = simulate_ctmc_sweep(grid, n_replicas=21, seed=4, max_steps=256,
                            bucketed=False)
    for i, (x, y) in enumerate(zip(a, b)):
        assert set(x) == set(y)
        for k in x:
            np.testing.assert_array_equal(x[k], y[k],
                                          err_msg=f"point {i} metric {k}")


@pytest.mark.parametrize("max_steps", [256, 100])
def test_bucketed_sweep_matches_single_point_run(max_steps):
    """A one-point bucketed sweep equals simulate_ctmc bit for bit: the
    pow2-width uniform draw guarantees the same stream for real
    replicas regardless of replica padding, and an explicit max_steps —
    chunk multiple or not (100 leaves a 36-step remainder) — is honored
    exactly rather than rounded up."""
    p = BASE.replace(recovery_time=7.0)
    sweep = simulate_ctmc_sweep([p], n_replicas=21, seed=9,
                                max_steps=max_steps, bucketed=True)[0]
    single = simulate_ctmc(p, n_replicas=21, seed=9, max_steps=max_steps)
    assert set(sweep) == set(single)
    for k in sweep:
        np.testing.assert_array_equal(sweep[k], single[k], err_msg=k)


def test_bucketed_early_exit_still_bit_identical():
    grid = [BASE.replace(recovery_time=v) for v in (5.0, 15.0)]
    a = simulate_ctmc_sweep(grid, n_replicas=12, seed=2, early_exit=True)
    b = simulate_ctmc_sweep(grid, n_replicas=12, seed=2, early_exit=False)
    for x, y in zip(a, b):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k], err_msg=k)


def test_bucketed_sweep_through_sweep_classes():
    """End to end through OneWaySweep: bucketing is on by default and
    changes no reported statistic vs bucketed=False."""
    kw = dict(n_replications=10, base_params=BASE, engine="ctmc")
    on = OneWaySweep("b", "recovery_time", [5.0, 10.0, 15.0], **kw).run()
    off = OneWaySweep("b", "recovery_time", [5.0, 10.0, 15.0],
                      bucketed=False, **kw).run()
    for po, pf in zip(on.points, off.points):
        assert po.stats["total_time"].mean == pf.stats["total_time"].mean
        assert po.stats["run_duration_pooled"].mean \
            == pf.stats["run_duration_pooled"].mean
