"""Checkpoint rollback + goodput optimization: cross-engine parity and
analytical cross-checks (PR 9 acceptance suite).

Covers the close-the-loop layer end to end:

  * event vs CTMC parity on a rollback-heavy config — mean total_time /
    lost_work / goodput agree within z < 3.5, checkpoint_overhead within
    rtol (its variance is near zero: the write count is deterministic),
    and the goodput histograms agree within one bin;
  * ``checkpoint_interval=0`` is pinned bit-identical — the rollback
    lanes must compile to dead code, so results cannot depend on the
    (traced) ``checkpoint_cost`` and lost_work/checkpoint_overhead are
    exactly zero;
  * a traced (checkpoint_interval x warm_standbys) grid compiles ONE
    XLA program;
  * :func:`repro.core.optimize.optimize_checkpoint_interval` lands
    within one grid notch of the Young/Daly interval in the
    low-overhead exponential regime, and its golden-section bracket
    history contracts geometrically;
  * hypothesis properties: goodput in [0, 1], monotone non-increasing
    in checkpoint_cost under common random numbers, lost_work == 0 at
    interval 0, and work conservation (sum of run records ~= useful +
    lost) on both engines.

The parity config uses interval=113.0 (non-commensurate with
job_length) deliberately: a job_length that is an exact multiple of the
interval makes the final write tie with completion, and fp drift breaks
the tie differently per engine.
"""

import math

import numpy as np
import pytest

from repro.core import (HistogramSpec, Params, run_replications,
                        run_replications_batch, simulate, young_daly_interval)
from repro.core.analytical import cluster_failure_rate
from repro.core.optimize import (default_interval_bounds,
                                 optimize_checkpoint_interval,
                                 optimize_knobs)
from repro.core.vectorized import simulate_ctmc, supports

DAY = 24 * 60.0

# Rollback-heavy but completing: fleet MTBF ~434 min >> interval, so
# jobs bank work steadily while still paying dozens of rollbacks.
BASE = Params(
    job_size=16,
    working_pool_size=20,
    spare_pool_size=4,
    warm_standbys=2,
    job_length=4 * DAY,
    random_failure_rate=0.2 / DAY,
    seed=3,
    checkpoint_interval=113.0,
    checkpoint_cost=5.0,
)


def _z(a_mean, a_std, a_n, b_mean, b_std, b_n):
    se = math.sqrt(a_std ** 2 / a_n + b_std ** 2 / b_n)
    return (a_mean - b_mean) / max(se, 1e-12)


# ---------------------------------------------------------------------------
# cross-engine parity (acceptance criterion)
# ---------------------------------------------------------------------------

def test_ctmc_accepts_checkpoint_rollback():
    assert supports(BASE)
    assert supports(Params(checkpoint_interval=60.0, checkpoint_cost=2.0))


def test_cross_engine_parity_rollback_heavy():
    """Mean total_time / lost_work / goodput: event vs CTMC within
    z < 3.5 on the rollback-heavy config; checkpoint_overhead within
    rtol (deterministic write count -> near-zero variance makes z
    meaningless)."""
    n_c, n_e = 512, 48
    rc = run_replications(BASE, n_c, engine="ctmc")
    re_ = run_replications(BASE, n_e, engine="event")
    for stat in ("total_time", "lost_work", "goodput"):
        sc, se = rc.stats[stat], re_.stats[stat]
        z = _z(sc.mean, sc.std, n_c, se.mean, se.std, n_e)
        assert abs(z) < 3.5, (stat, z, sc.mean, se.mean)
    oc = rc.stats["checkpoint_overhead"].mean
    oe = re_.stats["checkpoint_overhead"].mean
    assert oc == pytest.approx(oe, rel=0.02), (oc, oe)
    # both engines actually rolled back and wrote checkpoints
    assert rc.stats["lost_work"].mean > 0 and re_.stats["lost_work"].mean > 0
    assert oc > 0
    # goodput is a genuine fraction strictly inside (0, 1) here
    for rep in (rc, re_):
        assert 0.0 < rep.stats["goodput"].mean < 1.0


def test_goodput_histograms_agree_within_one_bin():
    """Pooled goodput histogram (one sample per completed job): p50 from
    the CTMC accumulator matches the event engine's empirical median
    within one bin width on the shared layout."""
    spec = HistogramSpec(low=0.01, high=1.0, n_bins=64,
                         channels=("run_duration", "recovery", "waiting",
                                   "goodput"))
    p = BASE.replace(histogram=spec)
    rc = run_replications(p, 256, engine="ctmc")
    h = rc.histograms["goodput"]
    assert h.total >= 250  # nearly every replica completes
    pool = np.array([r.goodput for r in simulate(p, 32) if not r.timed_out])
    assert len(pool) >= 30
    emp = float(np.percentile(pool, 50))
    assert abs(h.percentile(50) - emp) <= h.bin_width_at(emp)


# ---------------------------------------------------------------------------
# checkpoint_interval = 0: the rollback lanes must be dead code
# ---------------------------------------------------------------------------

def test_interval_zero_is_exactly_rollback_free():
    p = BASE.replace(checkpoint_interval=0.0)
    out = simulate_ctmc(p, n_replicas=32, seed=7)
    assert float(np.abs(out["lost_work"]).max()) == 0.0
    assert float(np.abs(out["checkpoint_overhead"]).max()) == 0.0
    # goodput still populated: useful == banked == all progressed work
    assert float(out["useful_work"].min()) > 0.0


def test_interval_zero_bit_identical_across_traced_cost():
    """With interval=0 the write cost is unreachable: trajectories must
    be bit-for-bit identical for any checkpoint_cost, proving the
    rollback machinery adds zero behavioural footprint when off."""
    p0 = BASE.replace(checkpoint_interval=0.0, checkpoint_cost=0.0)
    p1 = BASE.replace(checkpoint_interval=0.0, checkpoint_cost=50.0)
    o0 = simulate_ctmc(p0, n_replicas=16, seed=11)
    o1 = simulate_ctmc(p1, n_replicas=16, seed=11)
    for k in ("total_time", "useful_work", "n_failures", "completed",
              "lost_work", "checkpoint_overhead"):
        np.testing.assert_array_equal(np.asarray(o0[k]), np.asarray(o1[k]), k)


def test_interval_zero_identical_inside_mixed_grid():
    """An interval=0 row embedded in a grid next to rollback rows equals
    a standalone interval=0 run — the traced axis cannot leak across
    rows."""
    p0 = BASE.replace(checkpoint_interval=0.0, checkpoint_cost=0.0)
    grid = [p0, BASE, BASE.replace(checkpoint_interval=40.0)]
    reps = run_replications_batch(grid, 32, engine="ctmc")
    solo = run_replications(p0, 32, engine="ctmc")
    for stat in ("total_time", "overhead_fraction", "goodput", "lost_work"):
        assert reps[0].stats[stat].mean == solo.stats[stat].mean, stat
    assert reps[0].stats["lost_work"].mean == 0.0
    assert reps[1].stats["lost_work"].mean > 0.0


# ---------------------------------------------------------------------------
# one XLA program across the traced (interval x warm_standbys) grid
# ---------------------------------------------------------------------------

def test_checkpoint_grid_compiles_one_program():
    from repro.core import vectorized

    before = vectorized.compile_cache_size()
    if before is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    grid = [BASE.replace(checkpoint_interval=iv, checkpoint_cost=c,
                         warm_standbys=w)
            for iv in (0.0, 60.0, 113.0, 240.0)
            for c, w in ((0.0, 0), (5.0, 2))]
    reps = run_replications_batch(grid, 16, engine="ctmc")
    assert len(reps) == 8
    after = vectorized.compile_cache_size()
    assert after - before <= 1, (before, after)


# ---------------------------------------------------------------------------
# analytical cross-check: Young/Daly pins the optimizer
# ---------------------------------------------------------------------------

def test_optimizer_lands_within_one_notch_of_young_daly():
    """Low-overhead exponential regime: the simulated goodput-optimal
    interval must fall inside the one-grid-notch bracket around the
    Young/Daly point (the acceptance criterion)."""
    lam = cluster_failure_rate(BASE)
    yd = young_daly_interval(BASE.checkpoint_cost, 1.0 / lam)
    res = optimize_checkpoint_interval(BASE, n_replicas=256, n_grid=12,
                                       refine_iters=8)
    assert res.young_daly == pytest.approx(yd)
    # locate yd's grid notch and assert the optimum is within one notch
    grid = np.array(res.grid)
    ratio = grid[1] / grid[0]
    notch = ratio ** 1.5  # one grid notch + golden-section slack
    assert yd / notch <= res.interval <= yd * notch, (res.interval, yd)
    # the coarse response is genuinely unimodal-ish: the argmax is
    # interior and beats both bracket endpoints
    best = int(np.argmax(res.grid_objective))
    assert 0 < best < len(grid) - 1
    assert res.objective >= max(res.grid_objective)


def test_golden_section_bracket_contracts():
    res = optimize_checkpoint_interval(BASE, n_replicas=64, n_grid=8,
                                       refine_iters=6)
    assert res.history, "refinement must record its bracket"
    widths = [b - a for a, b in res.history]
    for w0, w1 in zip(widths, widths[1:]):
        assert w1 < w0
        # golden-section contracts by exactly invphi per iteration
        assert w1 == pytest.approx(w0 * (math.sqrt(5) - 1) / 2, rel=1e-6)
    assert res.n_evals == 8 + 2 * len(res.history)
    # CRN makes the whole search deterministic in the seed
    res2 = optimize_checkpoint_interval(BASE, n_replicas=64, n_grid=8,
                                        refine_iters=6)
    assert res2.interval == res.interval
    assert res2.objective == res.objective


def test_default_interval_bounds_bracket_young_daly():
    lo, hi = default_interval_bounds(BASE)
    lam = cluster_failure_rate(BASE)
    yd = young_daly_interval(BASE.checkpoint_cost, 1.0 / lam)
    assert lo < yd < hi
    assert lo >= BASE.checkpoint_cost
    # failure-free fleet: no interior optimum, job-length-scaled fallback
    lo0, hi0 = default_interval_bounds(
        BASE.replace(random_failure_rate=0.0))
    assert 0 < lo0 < hi0 <= BASE.job_length


def test_optimize_knobs_coordinate_descent():
    axes = {"checkpoint_interval": (40.0, 80.0, 160.0),
            "warm_standbys": (0, 2)}
    res = optimize_knobs(BASE, axes, n_replicas=64, engine="ctmc",
                         max_sweeps=3)
    assert set(res.values) == set(axes)
    assert res.values["checkpoint_interval"] in (40.0, 80.0, 160.0, 113.0)
    assert res.n_evals >= sum(len(v) for v in axes.values())
    assert res.history and res.objective > 0
    # the reported optimum is axis-optimal in its final visit per knob
    last = {}
    for name, cand, vals in res.history:
        last[name] = (cand, vals)
    for name, (cand, vals) in last.items():
        assert res.values[name] == cand[int(np.argmax(vals))]
    with pytest.raises(ValueError):
        optimize_knobs(BASE, {})
    with pytest.raises(ValueError):
        optimize_knobs(BASE, {"not_a_field": (1, 2)})


# ---------------------------------------------------------------------------
# deterministic invariant pins (the hypothesis twins live in
# tests/test_checkpoint_property.py and skip when hypothesis is absent)
# ---------------------------------------------------------------------------

SHORT = BASE.replace(job_length=1 * DAY)


@pytest.mark.parametrize("seed", [0, 7, 101])
def test_goodput_is_a_fraction(seed):
    p = SHORT.replace(seed=seed)
    out = simulate_ctmc(p, n_replicas=8, seed=seed)
    g = np.asarray(out["useful_work"]) / np.maximum(
        np.asarray(out["total_time"]), 1e-9)
    assert (g >= 0.0).all() and (g <= 1.0 + 1e-9).all()
    rep = run_replications(p, 8, engine="ctmc")
    assert 0.0 <= rep.stats["goodput"].mean <= 1.0 + 1e-9


@pytest.mark.parametrize("seed", [0, 42])
def test_goodput_monotone_nonincreasing_in_cost(seed):
    """Under common random numbers a dearer write can only hurt: mean
    goodput is non-increasing in checkpoint_cost (same seed, same
    interval, CRN across the traced-cost grid)."""
    costs = (0.0, 2.0, 8.0, 20.0)
    grid = [SHORT.replace(checkpoint_cost=c, seed=seed) for c in costs]
    reps = run_replications_batch(grid, 32, engine="ctmc")
    g = [r.stats["goodput"].mean for r in reps]
    for a, b in zip(g, g[1:]):
        assert b <= a + 1e-9, g


@pytest.mark.parametrize("seed", [0, 5])
def test_work_conservation_both_engines(seed):
    """Every compute minute is either banked (useful) or rolled back
    (lost): the run records satisfy sum(records) = useful_work +
    lost_work - cur_run.  Run records exclude checkpoint-write wall
    time by construction, so the identity is exact up to ring-buffer
    truncation (avoided here: records fit)."""
    p = SHORT.replace(seed=seed, max_run_records=4096)
    for r in simulate(p, 2):
        if r.timed_out:
            continue
        assert sum(r.run_durations) == pytest.approx(
            r.useful_work + r.lost_work, rel=1e-6)
    out = simulate_ctmc(p, n_replicas=4, seed=seed)
    buf = np.asarray(out["run_durations"], np.float64)
    n_runs = np.asarray(out["n_runs"], np.int64)
    assert (n_runs <= buf.shape[1]).all(), "records must fit the buffer"
    valid = np.arange(buf.shape[1])[None, :] < n_runs[:, None]
    recorded = np.where(valid, buf, 0.0).sum(axis=1)
    expect = (np.asarray(out["useful_work"], np.float64)
              + np.asarray(out["lost_work"], np.float64)
              - np.asarray(out["cur_run"], np.float64))
    np.testing.assert_allclose(recorded, expect, rtol=1e-5, atol=1e-6)
