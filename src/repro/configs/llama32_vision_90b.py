"""llama-3.2-vision-90b [vlm]: 100L, d_model=8192, 64H (GQA kv=8),
d_ff=28672, vocab=128256.  Cross-attention image layers every 5th layer
(80 self + 20 cross); the vision patch frontend is a STUB — the model
consumes precomputed (B, 1600, 1280) patch embeddings projected into
d_model.  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, cross_attn_period=5, cross_attn_offset=3,
    n_image_tokens=1600, d_image=1280, rope_theta=5e5,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_image_tokens=16, d_image=32)
