"""arctic-480b [moe]: 35L, d_model=7168, 56H (GQA kv=8), d_ff=4864,
vocab=32000.  128 experts top-2 with a DENSE RESIDUAL MLP in parallel
(Snowflake Arctic dense-MoE hybrid).  [hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000,
    n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True,
    moe_period=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    d_ff_expert=64, vocab_size=256, n_experts=4, top_k=2)
