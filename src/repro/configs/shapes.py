"""Input-shape registry for the assigned (architecture x shape) grid.

Four LM-family shapes; ``train_*`` lowers train_step, ``prefill_*`` lowers
serve_prefill, ``decode_*``/``long_*`` lower serve_decode (one new token
against a KV cache of seq_len).  ``long_500k`` requires sub-quadratic
sequence mixing and is skipped (with a recorded reason) for pure
full-attention architectures, per the assignment rules.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: O(L^2) attention at 524k "
                       "context; long_500k reserved for SSM/hybrid/linear "
                       "mixers (DESIGN.md §Arch-applicability)")
    return True, ""
