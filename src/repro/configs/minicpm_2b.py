"""minicpm-2b [dense]: 40L, d_model=2304, 36H (MHA kv=36), d_ff=5760,
vocab=122753.  WSD schedule; arch is llama-like MHA.
[arXiv:2404.06395; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256)
