"""jamba-1.5-large-398b [hybrid]: 72L, d_model=8192, 64H (GQA kv=8),
d_ff=24576, vocab=65536.  Mamba+attention 1:7 interleave (attention at
layer 4 of each 8-layer block), MoE 16e top-2 on every other layer.
[arXiv:2403.19887; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_period=8, attn_offset=4,       # 1 attention per 8 layers
    n_experts=16, top_k=2, moe_period=2, moe_offset=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, ssm_state=8, n_experts=4, top_k=2)
