"""kimi-k2-1t-a32b [moe]: 61L, d_model=7168, 64H (GQA kv=8, head_dim=128),
d_ff_expert=2048, vocab=163840.  MoE 384 experts top-8 + 1 shared expert
on every layer — trillion-param MoE, ~32B active.
[arXiv:2501.kimi2; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1,
    moe_period=1, rope_theta=5e4,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, d_ff_expert=32, vocab_size=256, n_experts=8, top_k=2,
    n_shared_experts=1)
