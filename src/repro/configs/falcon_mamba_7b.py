"""falcon-mamba-7b [ssm]: 64L, d_model=4096, attention-free Mamba-1,
ssm_state=16, vocab=65024.  No MLP sublayer (pure Mamba blocks).
[arXiv:2410.05355; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=65024, ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_period=0,  # attention-free
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, vocab_size=256, ssm_state=8)
