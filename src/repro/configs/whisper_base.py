"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865.  Encoder-decoder; the audio conv frontend is a STUB — the
model consumes precomputed (B, 1500, 512) frame embeddings.
[arXiv:2212.04356; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, encoder_layers=6, encoder_seq=1500,
    cross_attn_period=1, cross_attn_offset=0,  # every decoder layer
    act="gelu",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, encoder_layers=2, encoder_seq=32)
