"""Architecture config registry: ``--arch <id>`` resolution.

Ten assigned architectures (each with a reduced SMOKE_CONFIG of the same
family) plus the paper's own cluster config for the reliability simulator.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeSpec, applicable

#: arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "whisper-base": "whisper_base",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-34b": "granite_34b",
    "yi-9b": "yi_9b",
    "minicpm-2b": "minicpm_2b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "arctic-480b": "arctic_480b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "all_configs", "applicable",
           "get_config"]
