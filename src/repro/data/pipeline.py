"""Deterministic, seekable synthetic token pipeline.

Restart semantics are the point (the paper's recovery model): the stream
is a pure function of (seed, step, shard), so after a failure the loop
resumes at the exact step from the checkpointed cursor with no data loss
or duplication — the property AIReSim's recovery-time input assumes.

The generator is a counter-based PRF (threefry via jax.random under the
hood would be heavier than needed here; we use a splitmix64-style mix on
(seed, step, shard, position)), cheap enough to regenerate any batch at
any time on any host.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):  # wraparound is the point
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
        return x ^ (x >> np.uint64(31))


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1      # data-parallel shards
    shard_id: int = 0


class SyntheticTokenPipeline:
    """Yields {"tokens", "labels"} batches; O(1) seek to any step."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide into shards")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        self._step = 0

    # -- seeking (restart support) ------------------------------------------
    @property
    def step(self) -> int:
        return self._step

    def seek(self, step: int) -> None:
        if step < 0:
            raise ValueError("negative step")
        self._step = step

    def state_dict(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed,
                "n_shards": self.cfg.n_shards, "shard_id": self.cfg.shard_id}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        if state["seed"] != self.cfg.seed:
            raise ValueError("seed mismatch on restore")
        self.seek(state["step"])

    # -- batch generation -----------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = self.local_batch, cfg.seq_len
        with np.errstate(over="ignore"):
            rows = (np.uint64(cfg.shard_id) * np.uint64(self.local_batch)
                    + np.arange(B, dtype=np.uint64))
            base = (np.uint64(cfg.seed) * np.uint64(0x5851F42D4C957F2D)
                    + np.uint64(step) * np.uint64(0x14057B7EF767814F))
            # one u64 stream per (row, position)
            pos = np.arange(S + 1, dtype=np.uint64)
            mix = _splitmix64(base + (rows[:, None] << np.uint64(20))
                              + pos[None, :])
        toks = (mix % np.uint64(cfg.vocab_size)).astype(np.int32)
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.batch_at(self._step)
        self._step += 1
        return batch

    # -- frontend stubs ---------------------------------------------------------
    def with_frontend_stubs(self, batch: Dict[str, np.ndarray],
                            model_cfg) -> Dict[str, np.ndarray]:
        """Attach precomputed frame/patch embeddings for audio/vlm archs."""
        B = batch["tokens"].shape[0]
        step_seed = int(_splitmix64(np.uint64(self._step * 977 + 13)))
        rng = np.random.default_rng(step_seed % (2 ** 32))
        if model_cfg.is_encdec:
            batch["frames"] = rng.standard_normal(
                (B, model_cfg.encoder_seq, model_cfg.d_model),
                dtype=np.float32) * 0.1
        elif model_cfg.cross_attn_period > 0:
            batch["image_embeds"] = rng.standard_normal(
                (B, model_cfg.n_image_tokens, model_cfg.d_image),
                dtype=np.float32) * 0.1
        return batch
