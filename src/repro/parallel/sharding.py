"""Sharding rules: PartitionSpecs by path, plus the simulator replica mesh.

Two independent surfaces live here:

* **Model-training rules** (the original contents): parameter /
  activation / cache PartitionSpecs as pure functions of
  (path, shape, mesh) — see the scheme below.
* **Simulator replica mesh** (:func:`replica_mesh`, :func:`shard_keys`,
  :func:`replica_state_specs`): the 1-D ``("r",)`` device mesh the CTMC
  engine's ``shard_map`` path uses to split the flat ``(P*R,)`` batch
  axis by replica, and the per-shard PRNG-key splitting contract.  See
  docs/scaling.md for the end-to-end recipe.

Scheme (FSDP x TP x EP, with an outer pod axis for multi-pod):

  * mesh axes: ("data", "model") single-pod, ("pod", "data", "model")
    multi-pod.  FSDP shards parameters over (pod, data); TP shards heads /
    ffn / experts over "model".
  * stacked superblock parameters carry a leading n_superblocks axis that
    is never sharded.
  * every rule checks divisibility — a dimension that does not divide the
    axis size is left unsharded (e.g. kv_heads=8 on a 16-way model axis is
    replicated 2x, the standard GQA trick).
  * activations: batch over (pod, data); optional sequence sharding over
    "model" between superblocks (Megatron-style SP) — a TrainStepConfig
    knob and a §Perf hillclimb lever.
  * KV caches: batch over (pod, data), kv-heads over "model".

The rules are pure functions of (path, shape, mesh) so tests can assert
them without devices.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Dict[str, Any]


@dataclass(frozen=True)
class ParallelConfig:
    """Knobs for the distribution strategy (hillclimb levers in §Perf)."""
    shard_sequence: bool = True          # Megatron-style SP between blocks
    shard_embed_vocab: bool = True       # vocab dim of embed/head over TP
    fsdp_params: bool = True             # shard params over (pod, data)
    cache_seq_axis: Optional[str] = None # shard cache seq (long-context decode)
    moe_buffer_mode: str = "ep"          # ep | dp | none (see parallel.context)


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """-> (fsdp_axes, tp_axis) present in this mesh."""
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    return fsdp, "model"


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _shard_if(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Return ``axes`` if dim divides the axis-product size, else None."""
    if axes is None:
        return None
    size = _axis_size(mesh, axes)
    return axes if (size > 1 and dim % size == 0) else None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               pcfg: ParallelConfig = ParallelConfig()) -> P:
    """PartitionSpec for one parameter leaf, by its tree path."""
    fsdp, tp = mesh_axes(mesh)
    if not pcfg.fsdp_params:
        fsdp = ()
    fsdp = fsdp or None
    name = path.split("/")[-1]
    stacked = path.split("/")[0] in ("stack",) or "/stack/" in path \
        or path.startswith("encoder/stack")
    lead = (None,) if stacked else ()

    def spec(*trailing):
        parts = lead + trailing
        assert len(parts) == len(shape), (path, shape, parts)
        return P(*parts)

    dims = shape[1:] if stacked else shape

    # -- embeddings / head -------------------------------------------------
    if path == "embed":
        v_ax = _shard_if(mesh, shape[0], tp) if pcfg.shard_embed_vocab else None
        return P(v_ax, _shard_if(mesh, shape[1], fsdp))
    if path == "head":
        v_ax = _shard_if(mesh, shape[1], tp) if pcfg.shard_embed_vocab else None
        return P(_shard_if(mesh, shape[0], fsdp), v_ax)
    if path == "img_proj":
        return P(None, _shard_if(mesh, shape[1], tp))

    # -- norms / scalars ---------------------------------------------------
    if name in ("scale", "step") or name.startswith("norm"):
        return P(*([None] * len(shape)))

    # -- attention -----------------------------------------------------------
    if name == "wq":
        return spec(_shard_if(mesh, dims[0], fsdp),
                    _shard_if(mesh, dims[1], tp), None)
    if name in ("wk", "wv"):
        return spec(_shard_if(mesh, dims[0], fsdp),
                    _shard_if(mesh, dims[1], tp), None)
    if name == "wo":
        return spec(_shard_if(mesh, dims[0], tp), None,
                    _shard_if(mesh, dims[2], fsdp))
    if name == "bq":
        return spec(_shard_if(mesh, dims[0], tp), None)
    if name in ("bk", "bv"):
        return spec(_shard_if(mesh, dims[0], tp), None)

    # -- dense MLP -------------------------------------------------------------
    if name in ("wg", "wu", "wi"):
        if len(dims) == 3:  # MoE expert weights (E, D, F)
            return spec(_shard_if(mesh, dims[0], tp),
                        _shard_if(mesh, dims[1], fsdp), None)
        return spec(_shard_if(mesh, dims[0], fsdp),
                    _shard_if(mesh, dims[1], tp))
    if name in ("wd", "wo_mlp"):
        if len(dims) == 3:  # MoE expert down (E, F, D)
            return spec(_shard_if(mesh, dims[0], tp), None,
                        _shard_if(mesh, dims[2], fsdp))
        return spec(_shard_if(mesh, dims[0], tp),
                    _shard_if(mesh, dims[1], fsdp))
    if name in ("bi", "bo"):
        return spec(_shard_if(mesh, dims[0], tp))
    if name == "router":
        return spec(_shard_if(mesh, dims[0], fsdp), None)

    # -- mamba -------------------------------------------------------------------
    if name == "in_proj":
        return spec(_shard_if(mesh, dims[0], fsdp),
                    _shard_if(mesh, dims[1], tp))
    if name == "out_proj":
        return spec(_shard_if(mesh, dims[0], tp),
                    _shard_if(mesh, dims[1], fsdp))
    if name == "conv_w":
        return spec(None, _shard_if(mesh, dims[1], tp))
    if name in ("conv_b", "dt_b", "D"):
        return spec(_shard_if(mesh, dims[0], tp))
    if name == "x_proj":
        return spec(_shard_if(mesh, dims[0], tp), None)
    if name == "dt_w":
        return spec(None, _shard_if(mesh, dims[1], tp))
    if name == "A_log":
        return spec(_shard_if(mesh, dims[0], tp), None)

    # default: replicate
    return P(*([None] * len(shape)))


def params_shardings(params_spec_tree: Params, mesh: Mesh,
                     pcfg: ParallelConfig = ParallelConfig()) -> Params:
    """NamedShardings mirroring a params (or ShapeDtypeStruct) tree."""
    from repro.models.module import tree_paths

    flat = {p: leaf for p, leaf in tree_paths(params_spec_tree)}
    out: Dict[str, NamedSharding] = {
        p: NamedSharding(mesh, param_spec(p, tuple(leaf.shape), mesh, pcfg))
        for p, leaf in flat.items()
    }

    def rebuild(tree: Params, prefix: str = "") -> Params:
        res: Params = {}
        for key, value in tree.items():
            path = f"{prefix}/{key}" if prefix else key
            if isinstance(value, dict):
                res[key] = rebuild(value, path)
            else:
                res[key] = out[path]
        return res

    return rebuild(params_spec_tree)


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, global_batch: int,
               pcfg: ParallelConfig = ParallelConfig()) -> P:
    fsdp, _ = mesh_axes(mesh)
    return P(_shard_if(mesh, global_batch, fsdp), None)


def batch_shardings(batch_tree: Params, mesh: Mesh,
                    pcfg: ParallelConfig = ParallelConfig()) -> Params:
    """Shard every batch input on its leading (batch) dim."""
    fsdp, _ = mesh_axes(mesh)

    def leaf(sds):
        if sds.ndim == 0:
            return NamedSharding(mesh, P())
        ax = _shard_if(mesh, sds.shape[0], fsdp)
        return NamedSharding(mesh, P(*((ax,) + (None,) * (sds.ndim - 1))))

    return jax.tree.map(leaf, batch_tree)


def activation_spec(mesh: Mesh, batch: int, seq: int,
                    pcfg: ParallelConfig = ParallelConfig()) -> P:
    """(B, S, D) boundary-activation spec: batch over FSDP, seq over TP."""
    fsdp, tp = mesh_axes(mesh)
    b_ax = _shard_if(mesh, batch, fsdp)
    s_ax = _shard_if(mesh, seq, tp) if pcfg.shard_sequence else None
    return P(b_ax, s_ax, None)


def cache_shardings(cache_spec_tree: Params, mesh: Mesh,
                    pcfg: ParallelConfig = ParallelConfig()) -> Params:
    """KV/SSM cache shardings.

    Attention k/v: (n_sb, B, S_max, Hkv, hd) -> (None, fsdp, [seq], tp, None)
    Mamba conv:    (n_sb, B, W-1, di)        -> (None, fsdp, None, tp)
    Mamba ssm:     (n_sb, B, di, N)          -> (None, fsdp, tp, None)
    When batch == 1 (long-context decode) the batch axis is unsharded and
    the sequence axis picks up pcfg.cache_seq_axis if set.
    """
    fsdp, tp = mesh_axes(mesh)

    def leaf(sds):
        shape = sds.shape
        if len(shape) == 5:  # attention cache
            b_ax = _shard_if(mesh, shape[1], fsdp)
            s_ax = (_shard_if(mesh, shape[2], pcfg.cache_seq_axis)
                    if (b_ax is None and pcfg.cache_seq_axis) else None)
            h_ax = _shard_if(mesh, shape[3], tp)
            return NamedSharding(mesh, P(None, b_ax, s_ax, h_ax, None))
        if len(shape) == 4:  # mamba conv window (n_sb, B, W-1, di)
            b_ax = _shard_if(mesh, shape[1], fsdp)
            d_ax = _shard_if(mesh, shape[3], tp)
            return NamedSharding(mesh, P(None, b_ax, None, d_ax))
        if len(shape) == 3:
            b_ax = _shard_if(mesh, shape[0], fsdp)
            return NamedSharding(mesh, P(b_ax, None, None))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    def walk(tree):
        out = {}
        for key, value in tree.items():
            if isinstance(value, dict):
                out[key] = walk(value)
            elif key == "ssm" and len(value.shape) == 4:
                # mamba state (n_sb, B, di, N): di over tp
                b_ax = _shard_if(mesh, value.shape[1], fsdp)
                d_ax = _shard_if(mesh, value.shape[2], tp)
                out[key] = NamedSharding(mesh, P(None, b_ax, d_ax, None))
            elif key == "conv" and len(value.shape) == 4:
                # mamba conv window (n_sb, B, W-1, di): di over tp
                b_ax = _shard_if(mesh, value.shape[1], fsdp)
                d_ax = _shard_if(mesh, value.shape[3], tp)
                out[key] = NamedSharding(mesh, P(None, b_ax, None, d_ax))
            else:
                out[key] = leaf(value)
        return out

    return walk(cache_spec_tree)


def opt_state_shardings(opt_spec_tree: Params, param_shardings: Params,
                        mesh: Mesh) -> Params:
    """Adam m/v mirror the parameter shardings; step is replicated."""
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# simulator replica mesh (the CTMC engine's shard_map axis)
# ---------------------------------------------------------------------------

#: the replica-axis name of the simulator mesh; every sharded state leaf
#: of the CTMC engine partitions its replica dimension over this axis.
REPLICA_AXIS = "r"


def replica_mesh(n_shards: int) -> Mesh:
    """Build the 1-D ``(REPLICA_AXIS,)`` device mesh for a sharded run.

    Takes the first ``n_shards`` local devices.  Raises (rather than
    silently de-sharding) when fewer devices are visible — on CPU, force
    local devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    imports (see docs/scaling.md).

    >>> m = replica_mesh(1)
    >>> m.axis_names, m.shape["r"]
    (('r',), 1)
    >>> replica_mesh(10**6)          # doctest: +IGNORE_EXCEPTION_DETAIL
    Traceback (most recent call last):
    ValueError: ...
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    devices = jax.devices()
    if len(devices) < n_shards:
        raise ValueError(
            f"replica mesh needs {n_shards} devices but only "
            f"{len(devices)} are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} before importing jax (docs/scaling.md)")
    return Mesh(np.asarray(devices[:n_shards]), (REPLICA_AXIS,))


def shard_keys(key: "jax.Array", n_shards: int) -> "jax.Array":
    """Split a base PRNG key into ``n_shards`` per-shard keys, stacked
    ``(n_shards, 2)`` for a ``P('r')``-sharded shard_map input.

    The contract (pinned by tests/test_replica_sharding.py):

    * ``n_shards == 1`` returns the base key itself — a one-device mesh
      draws the *identical* uniform stream, making the sharded engine
      bit-identical to the unsharded one at mesh size 1;
    * ``n_shards > 1`` derives shard ``s``'s key as
      ``fold_in(key, s)``, so shard streams never overlap (threefry
      fold_in is injective per index) and shard ``s`` of a sharded run
      is bit-identical to an *independent unsharded* run over that
      shard's replicas seeded with the same folded key.

    >>> import jax
    >>> base = jax.random.PRNGKey(0)
    >>> bool((shard_keys(base, 1)[0] == base).all())
    True
    >>> ks = shard_keys(base, 4)
    >>> ks.shape
    (4, 2)
    >>> len({tuple(np.asarray(k)) for k in ks})   # pairwise distinct
    4
    """
    if n_shards == 1:
        return key[None]
    return jax.vmap(lambda s: jax.random.fold_in(key, s))(
        np.arange(n_shards, dtype=np.uint32))


def replica_state_specs(state: Dict[str, Any],
                        unbatched: Tuple[str, ...] = ()) -> Dict[str, P]:
    """PartitionSpec tree for a CTMC state dict reshaped to (P, R, ...).

    Every batched leaf shards its replica axis (dim 1) over
    ``REPLICA_AXIS``; leaves named in ``unbatched`` (shared bin-edge
    tables and the like) are replicated.

    >>> specs = replica_state_specs({"t": np.zeros((2, 8)),
    ...                              "hist_edges": np.zeros(130)},
    ...                             unbatched=("hist_edges",))
    >>> specs["t"], specs["hist_edges"]
    (PartitionSpec(None, 'r'), PartitionSpec())
    """
    return {k: (P() if k in unbatched else P(None, REPLICA_AXIS))
            for k in state}
