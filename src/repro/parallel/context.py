"""Trace-time sharding context.

Model code (transformer.apply_stack) is mesh-agnostic; the step builders
install an activation sharding here before tracing, and apply_stack
constrains the residual stream between superblocks accordingly
(Megatron-style sequence parallelism when ParallelConfig.shard_sequence).
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax

_ACTIVATION_SHARDING: Optional[jax.sharding.NamedSharding] = None
_MOE_SHARDING: Optional[tuple] = None   # (mesh, fsdp_axes, tp_axis)


@contextlib.contextmanager
def activation_sharding_scope(sharding, moe_axes: Optional[tuple] = None,
                              ) -> Iterator[None]:
    global _ACTIVATION_SHARDING, _MOE_SHARDING
    prev, prev_moe = _ACTIVATION_SHARDING, _MOE_SHARDING
    _ACTIVATION_SHARDING = sharding
    if moe_axes is not None:
        _MOE_SHARDING = moe_axes
    try:
        yield
    finally:
        _ACTIVATION_SHARDING = prev
        _MOE_SHARDING = prev_moe


def constrain_activations(x: jax.Array) -> jax.Array:
    """Apply the installed boundary-activation constraint, if any."""
    if _ACTIVATION_SHARDING is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, _ACTIVATION_SHARDING)
    return x


def moe_shard_map_config():
    """(mesh, fsdp_axes, tp_axis) when explicit-EP shard_map MoE is on."""
    if _MOE_SHARDING is None:
        return None
    mesh, fsdp, tp, mode = _MOE_SHARDING
    if mode != "shard_map":
        return None
    return mesh, fsdp, tp


def constrain_moe_tokens(x: jax.Array) -> jax.Array:
    """Constrain the MoE layer input (B, S, D) to be group-local: batch
    over FSDP, sequence UNSHARDED.  Under SP the residual stream is
    S-sharded over TP; without this constraint GSPMD partitions the
    dispatch gather over the sharded S axis and emits full-size masked
    all-reduces (measured 4x917GB/step on kimi-k2).  One cheap bf16
    all-gather here makes every dispatch gather/scatter device-local.
    Active in 'ep_local' mode."""
    if _MOE_SHARDING is None or x.ndim != 3:
        return x
    mesh, fsdp, tp, mode = _MOE_SHARDING
    if mode != "ep_local":
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    b_ax = fsdp if (fsdp and x.shape[0] % _axes_size(mesh, fsdp) == 0) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, None, None)))


def constrain_moe_buffer(buf: jax.Array) -> jax.Array:
    """Shard the (B, E, C, D) expert dispatch buffer.

    Modes (ParallelConfig.moe_buffer_mode):
      "ep"   — batch-groups over FSDP, experts over TP (buffer resharded
               to expert shards; GSPMD moves the buffer);
      "dp"   — batch-groups over FSDP only: every device holds all expert
               slots of ITS groups; the E-sharded expert weights make
               GSPMD compute only the local expert shard and the combine
               reduces (S, D) partials — tokens never recross the mesh;
      "none" — leave GSPMD to propagate.
    """
    if _MOE_SHARDING is None or buf.ndim != 4:
        return buf
    mesh, fsdp, tp, mode = _MOE_SHARDING
    if mode == "none":
        return buf
    from jax.sharding import NamedSharding, PartitionSpec as P
    b_ax = fsdp if (fsdp and buf.shape[0] % _axes_size(mesh, fsdp) == 0) else None
    e_ax = None
    if mode in ("ep", "ep_local") and buf.shape[1] % mesh.shape[tp] == 0:
        e_ax = tp
    return jax.lax.with_sharding_constraint(
        buf, NamedSharding(mesh, P(b_ax, e_ax, None, None)))


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size
