"""Distributed step builders: train_step / prefill_step / decode_step.

Each builder returns (jitted_fn, in_shardings, input ShapeDtypeStructs) so
callers can either execute (examples, smoke runs) or ``.lower().compile()``
against placeholder inputs (the multi-pod dry-run).

GSPMD does the collective planning: parameters carry FSDP x TP shardings
(sharding.py), batches are data-sharded, boundary activations are
sequence-sharded inside the layer scan, and gradients/optimizer updates
inherit parameter shardings (Adam state mirrors them exactly).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.model_zoo import ModelBundle
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)

from .context import activation_sharding_scope
from .sharding import (ParallelConfig, batch_shardings, cache_shardings,
                       mesh_axes, activation_spec, params_shardings)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for every model input)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Placeholder inputs for an (arch, shape) cell — no allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if shape.kind in ("train", "prefill"):
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), bf16)
        elif cfg.cross_attn_period > 0:
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_image), bf16)
    return specs


def state_specs(bundle: ModelBundle, opt_cfg: OptimizerConfig) -> Params:
    """ShapeDtypeStructs of the train state (params + Adam moments)."""
    def make(key):
        params = bundle.init(key)
        return {"params": params, "opt": init_opt_state(params, opt_cfg)}

    return jax.eval_shape(make, jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_specs(bundle: ModelBundle) -> Params:
    return jax.eval_shape(bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BuiltStep:
    fn: Callable                    # the jit-wrapped step
    in_specs: Tuple[Any, ...]       # ShapeDtypeStructs for .lower()
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]


def make_train_step(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                    opt_cfg: OptimizerConfig = OptimizerConfig(),
                    pcfg: ParallelConfig = ParallelConfig(),
                    impl: Optional[str] = None) -> BuiltStep:
    cfg = bundle.cfg
    act_sharding = NamedSharding(
        mesh, activation_spec(mesh, shape.global_batch, shape.seq_len, pcfg))
    fsdp, tp = mesh_axes(mesh)
    moe_axes = (mesh, fsdp, tp, pcfg.moe_buffer_mode)

    def train_step(state: Params, batch: Params):
        def loss_of(p):
            with activation_sharding_scope(
                    act_sharding if pcfg.shard_sequence else None,
                    moe_axes=moe_axes):
                return bundle.loss(p, batch, impl=impl)

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(state["params"])
        new_params, new_opt, stats = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = dict(metrics)
        metrics.update(stats)
        return {"params": new_params, "opt": new_opt}, metrics

    st_specs = state_specs(bundle, opt_cfg)
    p_sh = params_shardings(st_specs["params"], mesh, pcfg)
    state_sh = {"params": p_sh,
                "opt": {"m": p_sh, "v": p_sh,
                        "step": NamedSharding(mesh, P())}}
    b_specs = input_specs(cfg, shape)
    b_sh = batch_shardings(b_specs, mesh, pcfg)

    fn = jax.jit(train_step,
                 in_shardings=(state_sh, b_sh),
                 out_shardings=(state_sh, None),
                 donate_argnums=(0,))
    return BuiltStep(fn=fn, in_specs=(st_specs, b_specs),
                     in_shardings=(state_sh, b_sh), donate_argnums=(0,))


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def _cross_len(cfg: ModelConfig) -> int:
    return (cfg.encoder_seq if cfg.is_encdec
            else cfg.n_image_tokens if cfg.cross_attn_period else 0)


def make_prefill_step(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                      pcfg: ParallelConfig = ParallelConfig(),
                      impl: Optional[str] = None) -> BuiltStep:
    cfg = bundle.cfg
    act_sharding = NamedSharding(
        mesh, activation_spec(mesh, shape.global_batch, shape.seq_len, pcfg))
    fsdp, tp = mesh_axes(mesh)
    moe_axes = (mesh, fsdp, tp, pcfg.moe_buffer_mode)

    def prefill_step(params, batch, cache):
        with activation_sharding_scope(
                act_sharding if pcfg.shard_sequence else None,
                moe_axes=moe_axes):
            return bundle.prefill(params, batch, cache, impl=impl)

    p_specs = param_specs(bundle)
    p_sh = params_shardings(p_specs, mesh, pcfg)
    b_specs = input_specs(cfg, shape)
    b_sh = batch_shardings(b_specs, mesh, pcfg)
    c_specs = bundle.cache_spec(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(c_specs, mesh, pcfg)

    fn = jax.jit(prefill_step,
                 in_shardings=(p_sh, b_sh, c_sh),
                 out_shardings=(None, c_sh),
                 donate_argnums=(2,))
    return BuiltStep(fn=fn, in_specs=(p_specs, b_specs, c_specs),
                     in_shardings=(p_sh, b_sh, c_sh), donate_argnums=(2,))


def make_decode_step(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
                     pcfg: Optional[ParallelConfig] = None,
                     impl: Optional[str] = None) -> BuiltStep:
    cfg = bundle.cfg
    if pcfg is None:
        # long-context single-request decode: shard the KV cache sequence
        # axis over the data axes (batch cannot be sharded at B == 1)
        pcfg = ParallelConfig(
            cache_seq_axis=("data",) if shape.global_batch == 1 else None)

    def decode_fn(params, token, cache, pos):
        logits, new_cache = bundle.decode(params, token, cache, pos,
                                          impl=impl)
        return logits, new_cache

    p_specs = param_specs(bundle)
    p_sh = params_shardings(p_specs, mesh, pcfg)
    t_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = batch_shardings(t_spec, mesh, pcfg)
    c_specs = bundle.cache_spec(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(c_specs, mesh, pcfg)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    fn = jax.jit(decode_fn,
                 in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                 out_shardings=(None, c_sh),
                 donate_argnums=(2,))
    return BuiltStep(fn=fn,
                     in_specs=(p_specs, t_spec, c_specs, pos_spec),
                     in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                     donate_argnums=(2,))


def build_step(bundle: ModelBundle, mesh: Mesh, shape: ShapeSpec,
               opt_cfg: OptimizerConfig = OptimizerConfig(),
               pcfg: Optional[ParallelConfig] = None,
               impl: Optional[str] = None) -> BuiltStep:
    """Dispatch on the shape kind (train / prefill / decode)."""
    if shape.kind == "train":
        return make_train_step(bundle, mesh, shape, opt_cfg,
                               pcfg or ParallelConfig(), impl)
    if shape.kind == "prefill":
        return make_prefill_step(bundle, mesh, shape,
                                 pcfg or ParallelConfig(), impl)
    return make_decode_step(bundle, mesh, shape, pcfg, impl)
