"""Distribution layer: sharding rules, sharded step builders."""

from .sharding import (ParallelConfig, batch_shardings, cache_shardings,
                       mesh_axes, param_spec, params_shardings)
from .steps import (BuiltStep, build_step, input_specs, make_decode_step,
                    make_prefill_step, make_train_step, param_specs,
                    state_specs)

__all__ = ["BuiltStep", "ParallelConfig", "batch_shardings",
           "cache_shardings", "build_step", "input_specs", "make_decode_step",
           "make_prefill_step", "make_train_step", "mesh_axes", "param_spec",
           "param_specs", "params_shardings", "state_specs"]
