"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
before the first jax initialization.

Production target: TPU v5e pods, 256 chips each.
  * single-pod: (16, 16) -> ("data", "model")
  * multi-pod:  (2, 16, 16) -> ("pod", "data", "model"); the "pod" axis
    carries FSDP/DP traffic over DCI, "model" stays intra-pod ICI.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (examples/smoke runs)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    assert n % mp == 0, (n, mp)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def elastic_mesh(n_failed_replicas: int = 0, *, multi_pod: bool = False) -> Mesh:
    """Re-mesh after losing data-parallel replicas (elastic scaling).

    Drops ``n_failed_replicas`` rows from the data axis and rebuilds — the
    training loop re-lowers on the reduced mesh and continues at a smaller
    global batch (fault_tolerance.py drives this).
    """
    base_data = 16
    data = base_data - n_failed_replicas
    if data < 1:
        raise ValueError("no data-parallel replicas left")
    if multi_pod:
        return jax.make_mesh((2, data, 16), ("pod", "data", "model"))
    return jax.make_mesh((data, 16), ("data", "model"))
