"""Training launcher CLI: arch + shape -> fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --smoke --steps 50 --inject-failures

Full-size runs use the production mesh on real hardware; --smoke runs the
reduced same-family config on the host devices (this container).
"""

from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import ShapeSpec
from repro.core.params import Params as ClusterParams
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.train.loop import TrainLoopConfig, train
from repro.train.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="default: Young/Daly cadence from --cluster-* rates")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="write run summary JSON")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        mesh = make_host_mesh()
        shape = ShapeSpec("cli", args.seq_len or 64, args.global_batch or 4,
                          "train")
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = ShapeSpec("cli", args.seq_len or 4096,
                          args.global_batch or 256, "train")

    bundle = build_model(cfg)
    out = train(
        bundle, mesh, shape,
        TrainLoopConfig(total_steps=args.steps,
                        log_every=max(args.steps // 10, 1),
                        checkpoint_dir=args.ckpt_dir,
                        checkpoint_every=args.ckpt_every,
                        inject_failures=args.inject_failures,
                        cluster=ClusterParams()),
        OptimizerConfig(learning_rate=args.lr,
                        warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
    )
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:8.4f}  "
              f"{h['step_time_s'] * 1e3:8.1f} ms")
    print(f"done: {out['steps']} steps, final loss {out['final_loss']:.4f}, "
          f"recoveries {out['recovery']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, default=float)


if __name__ == "__main__":
    main()
