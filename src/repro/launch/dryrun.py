import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, without allocating a single model byte.

For each cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO
  * the three roofline terms + bottleneck + useful-compute ratio

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh single,multi --out results/dryrun.json

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first initialization.  Do not set this flag globally:
smoke tests and benchmarks expect 1 device.
"""

import argparse
import json
import time
import traceback
from typing import Dict, List, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.parallel import ParallelConfig, build_step
from repro.roofline.analysis import analyze
from repro.train.optimizer import OptimizerConfig


def opt_config_for(cfg) -> OptimizerConfig:
    """fp32 Adam moments by default; bf16 for the >=100B monsters
    (16 GB/chip HBM budget — recorded in the fits-HBM column)."""
    big = cfg.param_count() > 100e9
    return OptimizerConfig(state_dtype="bfloat16" if big else "float32")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pcfg: Optional[ParallelConfig] = None,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = applicable(cfg, shape)
    record: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        record["status"] = "SKIP"
        record["reason"] = reason
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP "
                  f"({reason.split(';')[0]})")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    bundle = build_model(cfg)
    t0 = time.time()
    try:
        with mesh:
            step = build_step(bundle, mesh, shape,
                              opt_cfg=opt_config_for(cfg), pcfg=pcfg)
            lowered = step.fn.lower(*step.in_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as exc:  # a failure here is a bug in the system
        record["status"] = "FAIL"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL "
                  f"{record['error']}")
        return record

    # memory_analysis reports the per-partition module, i.e. per device;
    # donated state aliases outputs, so args+temp is the resident footprint
    resident = float(mem.argument_size_in_bytes + mem.temp_size_in_bytes)
    roof = analyze(arch, shape_name, mesh_name, n_chips, cfg, shape,
                   hlo, cost, resident)
    record.update({
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "per_device_resident_gb": round(resident / 1e9, 3),
        "cost_flops": float(cost.get("flops", 0.0)),
        "cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "roofline": roof.to_dict(),
    })
    if verbose:
        r = record["roofline"]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"compile={t_compile:.0f}s resident/dev="
              f"{record['per_device_resident_gb']:.2f}GB "
              f"bottleneck={r['bottleneck']} "
              f"terms(c/m/x)={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
              f"{r['collective_s']:.4f}s frac={r['roofline_fraction']:.2f}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--shape", default="all", help="comma list or 'all'")
    ap.add_argument("--mesh", default="single,multi",
                    help="single | multi | single,multi")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true",
                    help="merge with existing --out file")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [m.strip() for m in args.mesh.split(",")]

    records: List[Dict] = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records
            if r.get("status") == "OK"}

    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                multi = mesh_kind == "multi"
                key = (arch, shape_name, "2x16x16" if multi else "16x16")
                if key in done:
                    continue
                rec = run_cell(arch, shape_name, multi)
                records = [r for r in records
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                records.append(rec)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    n_ok = sum(1 for r in records if r["status"] == "OK")
    n_skip = sum(1 for r in records if r["status"] == "SKIP")
    n_fail = sum(1 for r in records if r["status"] == "FAIL")
    print(f"[dryrun] done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"-> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
