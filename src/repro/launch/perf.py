import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: named variants over the dry-run cells.

Each variant re-lowers a cell with a configuration change (sharding knob,
remat policy, CE chunk, MoE buffer layout, optimizer dtype) and reports
the three roofline terms next to the baseline, appending to
results/perf.json.  The 'kernelized' pseudo-variant applies the analytic
Pallas-kernel substitution (roofline/kernel_adjust.py) on top of a
measured variant.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch falcon-mamba-7b \
      --shape train_4k --variant baseline,remat_dots,kernelized
"""

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import opt_config_for
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.parallel import ParallelConfig, build_step
from repro.roofline.analysis import analyze
from repro.roofline.kernel_adjust import kernelized_roofline

#: variant name -> dict of overrides:
#:   pcfg: ParallelConfig field overrides
#:   model: ModelConfig field overrides (remat policy, capacity factor...)
#:   opt_state_dtype: Adam moment dtype
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "no_sp": {"pcfg": {"shard_sequence": False}},
    "remat_dots": {"model": {"remat_policy": "dots"}},
    "no_remat": {"model": {"remat_policy": "full"}},
    "moe_dp_buffer": {"pcfg": {"moe_buffer_mode": "dp"}},
    "moe_ep_buffer": {"pcfg": {"moe_buffer_mode": "ep"}},
    "moe_token_local": {"pcfg": {"moe_buffer_mode": "ep_local"}},
    "moe_token_local_cap1": {"pcfg": {"moe_buffer_mode": "ep_local"},
                             "model": {"capacity_factor": 1.0}},
    "moe_none_buffer": {"pcfg": {"moe_buffer_mode": "none"}},
    "moe_shard_map": {"pcfg": {"moe_buffer_mode": "shard_map"}},
    "moe_shard_map_cap1": {"pcfg": {"moe_buffer_mode": "shard_map"},
                           "model": {"capacity_factor": 1.0}},
    "no_vocab_shard": {"pcfg": {"shard_embed_vocab": False}},
    "opt_bf16": {"opt_state_dtype": "bfloat16"},
    "capacity_1_0": {"model": {"capacity_factor": 1.0}},
}


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False) -> Dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    spec = VARIANTS.get(variant, {})
    if spec.get("model"):
        cfg = cfg.replace(**spec["model"])
    pcfg = ParallelConfig(**spec.get("pcfg", {}))
    opt_cfg = opt_config_for(cfg)
    if spec.get("opt_state_dtype"):
        opt_cfg = dataclasses.replace(opt_cfg,
                                      state_dtype=spec["opt_state_dtype"])

    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_model(cfg)
    t0 = time.time()
    with mesh:
        step = build_step(bundle, mesh, shape, opt_cfg=opt_cfg, pcfg=pcfg)
        compiled = step.fn.lower(*step.in_specs).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    resident = float(mem.argument_size_in_bytes + mem.temp_size_in_bytes)
    roof = analyze(arch, shape_name, "2x16x16" if multi_pod else "16x16",
                   mesh.devices.size, cfg, shape, hlo, cost, resident)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(time.time() - t0, 1),
        "per_device_resident_gb": round(resident / 1e9, 3),
        "roofline": roof.to_dict(),
    }
    rec["kernelized"] = kernelized_roofline(roof, cfg, shape)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()

    records = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)

    for variant in args.variant.split(","):
        rec = run_variant(args.arch, args.shape, variant, args.multi_pod)
        r = rec["roofline"]
        k = rec["kernelized"]
        print(f"[perf] {args.arch} x {args.shape} [{variant}]: "
              f"c/m/x = {r['compute_s']:.3f}/{r['memory_s']:.3f}/"
              f"{r['collective_s']:.3f}s frac={r['roofline_fraction']:.3f} "
              f"resident={rec['per_device_resident_gb']:.1f}GB | kernelized "
              f"m={k['memory_s']:.3f}s frac={k['roofline_fraction']:.3f}")
        records = [x for x in records if not (
            x["arch"] == args.arch and x["shape"] == args.shape
            and x["variant"] == variant and x["mesh"] == rec["mesh"])]
        records.append(rec)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
