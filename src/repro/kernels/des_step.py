"""Pallas TPU kernel for the vectorized DES next-event race.

The hot inner step of the JAX CTMC engine (core/vectorized.py): for R
independent replicas, race K_exp exponential clock families (propensities
``rates``) against K_det deterministic timers (``residuals``):

    dt    = min( Exp(sum rates),  min residual )
    event = categorical(rates)  if the exponential wins,
            K_exp + argmin residual otherwise

This is pure VPU work — log, cumsum over a tiny K axis, compares — tiled
over the replica axis in VMEM blocks of ``block_r``.  K_exp/K_det are
padded to the lane width by ops.py.

Validated in interpret mode against ref.event_race_ref.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _event_race_kernel(rates_ref, residuals_ref, u_time_ref, u_pick_ref,
                       dt_ref, event_ref, *, k_exp: int, k_det: int):
    rates = rates_ref[...].astype(jnp.float32)          # (bR, Kexp)
    residuals = residuals_ref[...].astype(jnp.float32)  # (bR, Kdet)
    u_time = u_time_ref[...].astype(jnp.float32)        # (bR,)
    u_pick = u_pick_ref[...].astype(jnp.float32)

    total = jnp.sum(rates, axis=-1)                     # (bR,)
    safe = jnp.maximum(total, 1e-30)
    t_exp = -jnp.log(jnp.maximum(u_time, 1e-38)) / safe
    t_exp = jnp.where(total > 0.0, t_exp, jnp.float32(jnp.inf))

    cdf = jnp.cumsum(rates, axis=-1) / safe[:, None]    # (bR, Kexp)
    pick_exp = jnp.sum((u_pick[:, None] >= cdf).astype(jnp.int32), axis=-1)
    pick_exp = jnp.minimum(pick_exp, k_exp - 1)

    t_det = jnp.min(residuals, axis=-1)
    pick_det = jnp.argmin(residuals, axis=-1).astype(jnp.int32) + k_exp

    exp_wins = t_exp <= t_det
    dt_ref[...] = jnp.minimum(t_exp, t_det)
    event_ref[...] = jnp.where(exp_wins, pick_exp, pick_det)


def event_race_fwd(rates: jax.Array, residuals: jax.Array,
                   u_time: jax.Array, u_pick: jax.Array, *,
                   block_r: int = 1024, interpret: bool = False,
                   ) -> Tuple[jax.Array, jax.Array]:
    """rates (R, K_exp), residuals (R, K_det), uniforms (R,) -> (dt, event)."""
    R, k_exp = rates.shape
    _, k_det = residuals.shape
    block_r = min(block_r, R)
    assert R % block_r == 0, (R, block_r)
    grid = (R // block_r,)

    kernel = functools.partial(_event_race_kernel, k_exp=k_exp, k_det=k_det)
    dt, event = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, k_exp), lambda r: (r, 0)),
            pl.BlockSpec((block_r, k_det), lambda r: (r, 0)),
            pl.BlockSpec((block_r,), lambda r: (r,)),
            pl.BlockSpec((block_r,), lambda r: (r,)),
        ],
        out_specs=[
            pl.BlockSpec((block_r,), lambda r: (r,)),
            pl.BlockSpec((block_r,), lambda r: (r,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.float32),
            jax.ShapeDtypeStruct((R,), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(rates, residuals, u_time, u_pick)
    return dt, event
