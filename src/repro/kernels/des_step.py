"""Pallas TPU kernel for the vectorized DES next-event race.

The hot inner step of the JAX CTMC engine (core/vectorized.py): for R
independent replicas, race K_exp exponential clock families (propensities
``rates``) against K_det deterministic timers (``residuals``):

    dt    = min( Exp(sum rates),  min residual )
    event = categorical(rates)  if the exponential wins,
            K_exp + argmin residual otherwise

This is pure VPU work — log, cumsum over a tiny K axis, compares — tiled
over the replica axis in VMEM blocks of ``block_r`` (grid = replica
blocks, all parallel).  The caller (ops.event_race) pads:

* the replica axis up to a whole number of sublane-aligned blocks with
  inert rows (zero rates, +inf residuals) that are sliced off after;
* the K lanes up to multiples of 8 — padded *rate* lanes carry 0 and
  padded *residual* lanes carry +inf, both provably inert (a zero rate
  leaves the total and the pick-CDF unchanged; +inf never argmin-wins
  against any finite residual, and an all-+inf tie resolves to lane 0
  exactly like the unpadded argmin);
* the two per-replica uniforms into one stacked (R, 2) ref, and the two
  scalar outputs into (R, 1) refs — TPU-friendly 2-D layouts.

The *real* lane counts enter as static kernel parameters so the
categorical pick clips to the real exponential lanes and the
deterministic winner index is remapped to ``k_exp_real + argmin``,
keeping the event numbering identical to ref.event_race_ref.

Validated in interpret mode against ref.event_race_ref on CPU CI
(tests/test_kernels.py sweeps padded and unpadded K-lane shapes).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams


def _event_race_kernel(rates_ref, residuals_ref, u_ref, dt_ref, event_ref,
                       *, k_exp: int, k_det: int):
    """One replica block.  ``k_exp``/``k_det`` are the REAL lane counts;
    the refs may carry padded lanes (zero rates / +inf residuals)."""
    rates = rates_ref[...].astype(jnp.float32)          # (bR, Kexp_pad)
    residuals = residuals_ref[...].astype(jnp.float32)  # (bR, Kdet_pad)
    u = u_ref[...].astype(jnp.float32)                  # (bR, 2)
    u_time, u_pick = u[:, 0], u[:, 1]

    total = jnp.sum(rates, axis=-1)                     # (bR,)
    safe = jnp.maximum(total, 1e-30)
    t_exp = -jnp.log(jnp.maximum(u_time, 1e-38)) / safe
    t_exp = jnp.where(total > 0.0, t_exp, jnp.float32(jnp.inf))

    # padded rate lanes are zero, so their cdf entries saturate at 1.0
    # and u_pick < 1 never counts them; clip to the real lanes anyway
    cdf = jnp.cumsum(rates, axis=-1) / safe[:, None]    # (bR, Kexp_pad)
    pick_exp = jnp.sum((u_pick[:, None] >= cdf).astype(jnp.int32), axis=-1)
    pick_exp = jnp.minimum(pick_exp, k_exp - 1)

    # padded residual lanes are +inf: never the strict minimum, and an
    # all-+inf row argmins to 0 — identical to the unpadded reference
    t_det = jnp.min(residuals, axis=-1)
    pick_det = jnp.argmin(residuals, axis=-1).astype(jnp.int32) + k_exp

    exp_wins = t_exp <= t_det
    dt_ref[...] = jnp.minimum(t_exp, t_det)[:, None]
    event_ref[...] = jnp.where(exp_wins, pick_exp, pick_det)[:, None]


def event_race_fwd(rates: jax.Array, residuals: jax.Array,
                   u2: jax.Array, *, k_exp: int, k_det: int,
                   block_r: int = 1024, interpret: bool = False,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Blocked kernel dispatch over pre-padded inputs.

    rates (R_pad, Kexp_pad), residuals (R_pad, Kdet_pad), u2 (R_pad, 2)
    -> (dt (R_pad,), event (R_pad,)).  ``R_pad`` must be a multiple of
    ``block_r``; ``k_exp``/``k_det`` are the real lane counts (see
    module docstring).  ops.event_race does all the padding/slicing —
    call that, not this.
    """
    R, ke_pad = rates.shape
    _, kd_pad = residuals.shape
    assert R % block_r == 0, (R, block_r)
    grid = (R // block_r,)

    kernel = functools.partial(_event_race_kernel, k_exp=k_exp, k_det=k_det)
    dt, event = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, ke_pad), lambda r: (r, 0)),
            pl.BlockSpec((block_r, kd_pad), lambda r: (r, 0)),
            pl.BlockSpec((block_r, 2), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, 1), lambda r: (r, 0)),
            pl.BlockSpec((block_r, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
            jax.ShapeDtypeStruct((R, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(rates, residuals, u2)
    return dt[:, 0], event[:, 0]
