"""Pure-jnp reference oracles for every Pallas kernel.

These are the semantics of record: each kernel's tests sweep shapes/dtypes
and assert allclose against these functions.  They are also the execution
path on non-TPU backends (the dry-run compiles on CPU), so they are written
to be memory-bounded at production shapes:

  * attention_ref supports a scan-over-query-blocks mode (online softmax in
    fp32) so 32k-context lowering never materializes an (S, S) score matrix
    bigger than (block_q, S);
  * selective_scan_ref carries only the (B, d_inner, N) state through a
    lax.scan over time.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention (GQA, causal/full, optional kv-length mask)
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, *, causal: bool, q_pos, k_pos,
                kv_len: Optional[jax.Array]) -> jax.Array:
    """Full-materialization attention for one query block.

    q: (B, Sq, Hkv, G, d)  k/v: (B, Sk, Hkv, d)
    returns (B, Sq, Hkv, G, d); math in fp32.
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = None
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]          # (Sq, Sk)
    if kv_len is not None:
        len_mask = k_pos[None, :] < kv_len                # (1, Sk)
        mask = len_mask if mask is None else (mask & len_mask)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int | jax.Array = 0,
                  kv_len: Optional[jax.Array] = None,
                  q_block: Optional[int] = None) -> jax.Array:
    """Grouped-query attention.

    q: (B, Sq, Hq, d); k/v: (B, Sk, Hkv, d); Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode with a cache).
    ``kv_len``: if given, keys at positions >= kv_len are masked out.
    ``q_block``: if set and Sq > q_block, runs the online-softmax block
    scan (memory O(block * Sk) instead of O(Sq * Sk)).
    """
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, d)
    k_pos = jnp.arange(Sk)

    if q_block is None or Sq <= q_block or Sq % q_block != 0:
        # direct path (also the fallback for non-divisible lengths, e.g.
        # whisper's 1500-frame encoder)
        q_pos = q_offset + jnp.arange(Sq)
        out = _attn_block(qg, k, v, causal=causal, q_pos=q_pos, k_pos=k_pos,
                          kv_len=kv_len)
        return out.reshape(B, Sq, Hq, d).astype(q.dtype)

    n_blocks = Sq // q_block
    qb = qg.reshape(B, n_blocks, q_block, Hkv, G, d)

    def body(_, args):
        qi, q_pos = args
        out = _attn_block(qi, k, v, causal=causal, q_pos=q_pos, k_pos=k_pos,
                          kv_len=kv_len)
        return None, out

    pos = (q_offset + jnp.arange(Sq)).reshape(n_blocks, q_block)
    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qb, 1, 0), pos))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

def selective_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bmat: jax.Array, Cmat: jax.Array,
                       h0: Optional[jax.Array] = None,
                       chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Mamba-1 selective state-space scan.

    x, dt: (B, S, di);  A: (di, N);  Bmat, Cmat: (B, S, N).
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = (h_t * C_t).sum(N)
    Returns (y (B, S, di), h_final (B, di, N)); math in fp32.

    Two-level structure: an outer scan over ``chunk``-sized pieces whose
    body is jax.checkpoint'ed, so the backward pass saves only chunk
    boundary states (S/chunk x (B, di, N)) instead of one (B, di, N)
    residual per time step — this mirrors the Pallas kernel's chunking
    and keeps 100k+-step training scans memory-sane.
    """
    Bsz, S, di = x.shape
    N = A.shape[-1]
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, N), jnp.float32)

    def step(h, args):
        x_t, dt_t, B_t, C_t = args          # (B,di) (B,di) (B,N) (B,N)
        dtf = dt_t.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * Af[None])        # (B,di,N)
        drive = (dtf * x_t.astype(jnp.float32))[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        h = decay * h + drive
        y_t = jnp.sum(h * C_t.astype(jnp.float32)[:, None, :], axis=-1)
        return h, y_t

    def scan_chunk(h, args):
        xc, dtc, Bc, Cc = args              # (c, B, ...) time-major
        return jax.lax.scan(step, h, (xc, dtc, Bc, Cc))

    if S % chunk or S <= chunk:
        h_final, ys = scan_chunk(
            h0.astype(jnp.float32),
            (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
             jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
        return y, h_final

    n_chunks = S // chunk

    def outer(h, args):
        return jax.checkpoint(scan_chunk)(h, args)

    def to_chunks(a):
        # (B, S, F) -> (n_chunks, chunk, B, F)
        t = jnp.moveaxis(a, 1, 0)
        return t.reshape(n_chunks, chunk, t.shape[1], t.shape[2])

    xs = (to_chunks(x), to_chunks(dt), to_chunks(Bmat), to_chunks(Cmat))
    h_final, ys = jax.lax.scan(outer, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys.reshape(S, Bsz, di), 0, 1).astype(x.dtype)
    return y, h_final


def selective_scan_step_ref(x_t: jax.Array, dt_t: jax.Array, A: jax.Array,
                            B_t: jax.Array, C_t: jax.Array, h: jax.Array,
                            ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step: x_t/dt_t (B, di); B_t/C_t (B, N); h (B, di, N)."""
    Af = A.astype(jnp.float32)
    decay = jnp.exp(dt_t.astype(jnp.float32)[..., None] * Af[None])
    drive = (dt_t.astype(jnp.float32) * x_t.astype(jnp.float32))[..., None] \
        * B_t.astype(jnp.float32)[:, None, :]
    h_new = decay * h + drive
    y = jnp.sum(h_new * C_t.astype(jnp.float32)[:, None, :], axis=-1)
    return y.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# DES next-event race (vectorized CTMC inner step)
# ---------------------------------------------------------------------------

def event_race_ref(rates: jax.Array, residuals: jax.Array,
                   u_time: jax.Array, u_pick: jax.Array,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Race K_exp exponential clocks against K_det deterministic timers.

    rates:     (R, K_exp) propensities (0 = clock off)
    residuals: (R, K_det) remaining deterministic times (+inf = off)
    u_time, u_pick: (R,) uniforms in (0, 1)

    Returns (dt (R,), event (R,) int32) where event < K_exp indexes the
    winning exponential family and event >= K_exp indexes K_exp + argmin
    residual.  The minimum of the exponential clocks is Exp(sum rates) and
    the winner is categorical(rates) — sampled by inverse-CDF on u_pick.
    """
    total = rates.sum(-1)                                   # (R,)
    safe_total = jnp.maximum(total, 1e-30)
    t_exp = -jnp.log(u_time) / safe_total
    t_exp = jnp.where(total > 0, t_exp, jnp.inf)

    cdf = jnp.cumsum(rates, axis=-1) / safe_total[:, None]
    pick_exp = jnp.sum(u_pick[:, None] >= cdf, axis=-1)     # (R,)
    pick_exp = jnp.minimum(pick_exp, rates.shape[-1] - 1).astype(jnp.int32)

    t_det = residuals.min(-1)
    pick_det = residuals.argmin(-1).astype(jnp.int32) + rates.shape[-1]

    dt = jnp.minimum(t_exp, t_det)
    event = jnp.where(t_exp <= t_det, pick_exp, pick_det)
    return dt, event
