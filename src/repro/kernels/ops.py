"""Public kernel entry points with backend dispatch.

Selection policy (per call, overridable with ``impl=``):

  * ``tpu`` backend          -> Pallas kernel (compiled)
  * anything else            -> pure-jnp reference (ref.py)
  * ``impl='pallas_interpret'`` -> Pallas kernel in interpret mode
    (Python emulation on CPU; used by the kernel test suite)

Differentiability: Pallas forward kernels are wrapped in jax.custom_vjp
with the backward pass taken from the reference implementation (recompute
with jax.vjp).  On CPU everything routes through ref and is natively
differentiable, so training in this container and kernel-accelerated
training on TPU share one API.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .des_step import event_race_fwd
from .flash_attention import flash_attention_fwd
from .mamba_scan import selective_scan_fwd


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _attn_pallas(q, k, v, *, causal, q_offset, kv_len, block_q, block_k,
                 interpret):
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, d)
    out = flash_attention_fwd(
        qr, kr, vr, n_q_heads=Hq, n_kv_heads=Hkv, causal=causal,
        q_offset=q_offset, kv_len=kv_len, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _attention_custom(q, k, v, causal, q_offset, kv_len, block_q, block_k,
                      interpret, q_block):
    return _attn_pallas(q, k, v, causal=causal, q_offset=q_offset,
                        kv_len=kv_len, block_q=block_q, block_k=block_k,
                        interpret=interpret)


def _attention_fwd(q, k, v, causal, q_offset, kv_len, block_q, block_k,
                   interpret, q_block):
    out = _attention_custom(q, k, v, causal, q_offset, kv_len, block_q,
                            block_k, interpret, q_block)
    return out, (q, k, v)


def _attention_bwd(causal, q_offset, kv_len, block_q, block_k, interpret,
                   q_block, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=causal, q_offset=q_offset, kv_len=kv_len,
            q_block=q_block), q, k, v)
    return vjp(g)


_attention_custom.defvjp(_attention_fwd, _attention_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset=0,
                    kv_len: Optional[jax.Array] = None,
                    impl: Optional[str] = None,
                    block_q: int = 128, block_k: int = 128,
                    q_block: Optional[int] = 1024) -> jax.Array:
    """GQA attention. q (B,Sq,Hq,d), k/v (B,Sk,Hkv,d) -> (B,Sq,Hq,d)."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                                 kv_len=kv_len, q_block=q_block)
    interpret = impl == "pallas_interpret"
    # Pallas path requires static offsets/lengths and aligned shapes;
    # fall back to ref otherwise (e.g. decode with traced positions).
    static_ok = isinstance(q_offset, int) and (
        kv_len is None or isinstance(kv_len, int))
    Sq, Sk = q.shape[1], k.shape[1]
    if not static_ok or Sq % min(block_q, Sq) or Sk % min(block_k, Sk):
        return ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                                 kv_len=kv_len, q_block=q_block)
    return _attention_custom(q, k, v, causal, q_offset, kv_len, block_q,
                             block_k, interpret, q_block)


# ---------------------------------------------------------------------------
# selective scan (mamba)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _scan_custom(x, dt, A, Bmat, Cmat, h0, chunk, block_d, interpret):
    # pallas uses (B, N, di) state layout; ref uses (B, di, N)
    y, hf = selective_scan_fwd(x, dt, A, Bmat, Cmat,
                               jnp.swapaxes(h0, 1, 2), chunk=chunk,
                               block_d=block_d, interpret=interpret)
    return y, jnp.swapaxes(hf, 1, 2)


def _scan_fwd(x, dt, A, Bmat, Cmat, h0, chunk, block_d, interpret):
    out = _scan_custom(x, dt, A, Bmat, Cmat, h0, chunk, block_d, interpret)
    return out, (x, dt, A, Bmat, Cmat, h0)


def _scan_bwd(chunk, block_d, interpret, res, g):
    x, dt, A, Bmat, Cmat, h0 = res
    _, vjp = jax.vjp(
        lambda *args: ref.selective_scan_ref(*args), x, dt, A, Bmat, Cmat, h0)
    return vjp(g)


_scan_custom.defvjp(_scan_fwd, _scan_bwd)


def selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
                   Bmat: jax.Array, Cmat: jax.Array,
                   h0: Optional[jax.Array] = None, *,
                   impl: Optional[str] = None, chunk: int = 256,
                   block_d: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Mamba scan. x/dt (B,S,di), A (di,N), B/C (B,S,N), h0 (B,di,N)."""
    impl = impl or _default_impl()
    Bsz, S, di = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, N), jnp.float32)
    if impl == "ref":
        return ref.selective_scan_ref(x, dt, A, Bmat, Cmat, h0)
    interpret = impl == "pallas_interpret"
    if S % min(chunk, S) or di % min(block_d, di):
        return ref.selective_scan_ref(x, dt, A, Bmat, Cmat, h0)
    return _scan_custom(x, dt, A, Bmat, Cmat, h0, min(chunk, S),
                        min(block_d, di), interpret)


def selective_scan_step(x_t, dt_t, A, B_t, C_t, h):
    """Single decode step (always jnp; trivially memory-bound)."""
    return ref.selective_scan_step_ref(x_t, dt_t, A, B_t, C_t, h)


# ---------------------------------------------------------------------------
# DES event race
# ---------------------------------------------------------------------------

def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def event_race(rates: jax.Array, residuals: jax.Array, u_time: jax.Array,
               u_pick: jax.Array, *, impl: Optional[str] = None,
               block_r: int = 1024) -> Tuple[jax.Array, jax.Array]:
    """Next-event race; see des_step.py. No gradients (simulation only).

    ``impl``: None auto-selects (``"pallas"`` on TPU, ``"ref"``
    elsewhere); ``"ref"`` is the always-available pure-jnp path;
    ``"pallas"`` requires a TPU backend and raises otherwise (use
    ``"pallas_interpret"`` — the kernel body executed op-by-op on CPU —
    for validation).  The kernel path pads the replica axis to whole
    sublane-aligned blocks and the K lanes to multiples of 8 with inert
    values (see des_step.py); padding is sliced off before returning,
    so every (R, K_exp, K_det) shape runs the kernel — there is no
    silent shape fallback.  Zero-width lane blocks are invalid on every
    backend (the reference cannot reduce them either) and raise.

    With all rates zero the deterministic side wins and the event index
    is ``K_exp + argmin(residuals)`` — identical across backends:

    >>> rates = jnp.zeros((1, 2))
    >>> resid = jnp.asarray([[3.0, 1.5]])
    >>> u = jnp.asarray([0.5])
    >>> dt, ev = event_race(rates, resid, u, u, impl="ref")
    >>> float(dt[0]), int(ev[0])
    (1.5, 3)
    >>> dt, ev = event_race(rates, resid, u, u, impl="pallas_interpret")
    >>> float(dt[0]), int(ev[0])
    (1.5, 3)
    """
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.event_race_ref(rates, residuals, u_time, u_pick)
    if impl == "pallas" and jax.default_backend() != "tpu":
        raise ValueError(
            f"event_race impl='pallas' requires a TPU backend (default "
            f"backend here is {jax.default_backend()!r}); use "
            f"impl='pallas_interpret' for CPU validation or impl='ref' "
            f"for the pure-jnp path (docs/scaling.md)")
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(
            f"event_race impl={impl!r} must be None, 'ref', 'pallas', "
            f"or 'pallas_interpret'")
    R, k_exp = rates.shape
    k_det = residuals.shape[1]
    if k_exp == 0 or k_det == 0:
        raise ValueError(
            f"event_race needs at least one exponential and one "
            f"deterministic lane (got K_exp={k_exp}, K_det={k_det}); a "
            f"zero-width lane block has no next event to race — disable "
            f"the empty side with zero rates / +inf residuals instead")
    # pad K lanes to sublane multiples with inert values, the replica
    # axis to a whole number of blocks with inert rows (see des_step.py)
    ke_pad, kd_pad = _round_up(k_exp, 8), _round_up(k_det, 8)
    block = min(block_r, _round_up(R, 8))
    r_pad = _round_up(R, block)
    rates_p = jnp.pad(rates, ((0, r_pad - R), (0, ke_pad - k_exp)))
    resid_p = jnp.pad(residuals, ((0, r_pad - R), (0, kd_pad - k_det)),
                      constant_values=jnp.inf)
    u2 = jnp.stack([u_time, u_pick], axis=-1)           # (R, 2)
    u2 = jnp.pad(u2, ((0, r_pad - R), (0, 0)), constant_values=0.5)
    dt, event = event_race_fwd(rates_p, resid_p, u2, k_exp=k_exp,
                               k_det=k_det, block_r=block,
                               interpret=impl == "pallas_interpret")
    return dt[:R], event[:R]
