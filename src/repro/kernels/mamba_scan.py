"""Pallas TPU selective state-space scan (Mamba-1).

TPU adaptation (see DESIGN.md): the CUDA mamba kernel is a warp-parallel
chunked scan; on TPU we tile (d_inner) across the grid and keep the
recurrent state h resident in VMEM across *sequence chunks* (innermost
grid dimension, "arbitrary" semantics).  Inside a chunk the recurrence is
a fori_loop over time steps operating on (N, block_d) vectors — N on
sublanes, d_inner on lanes, so the elementwise decay/drive math runs at
full VPU width.

Grid: (B, d_inner/block_d, S/chunk).  The state scratch (N, block_d) is
initialized from h0 at chunk 0 and written to h_final at the last chunk.

Validated in interpret mode against ref.selective_scan_ref.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _mamba_kernel(x_ref, dt_ref, At_ref, B_ref, C_ref, h0_ref,
                  y_ref, hf_ref, h_scratch, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = h0_ref[0].astype(jnp.float32)      # (N, bd)

    At = At_ref[...].astype(jnp.float32)                    # (N, bd)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)          # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)            # (bd,)
        B_t = B_ref[0, t, :].astype(jnp.float32)            # (N,)
        C_t = C_ref[0, t, :].astype(jnp.float32)            # (N,)
        decay = jnp.exp(At * dt_t[None, :])                 # (N, bd)
        drive = (dt_t * x_t)[None, :] * B_t[:, None]        # (N, bd)
        h = decay * h + drive
        y_t = jnp.sum(h * C_t[:, None], axis=0)             # (bd,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hf_ref[0] = h.astype(hf_ref.dtype)


def selective_scan_fwd(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bmat: jax.Array, Cmat: jax.Array, h0: jax.Array, *,
                       chunk: int = 256, block_d: int = 512,
                       interpret: bool = False,
                       ) -> Tuple[jax.Array, jax.Array]:
    """x, dt: (B, S, di); A: (di, N); Bmat/Cmat: (B, S, N); h0: (B, N, di).

    Returns (y (B, S, di), h_final (B, N, di)).  Note h uses the TPU-native
    (N, di) layout (N on sublanes); ops.py adapts to/from the reference
    (B, di, N) layout.
    """
    Bsz, S, di = x.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0 and di % block_d == 0, (S, chunk, di, block_d)
    n_chunks = S // chunk
    n_dblocks = di // block_d
    At = A.T  # (N, di)

    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (Bsz, n_dblocks, n_chunks)

    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((N, block_d), lambda b, d, c: (0, d)),            # A^T
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # C
            pl.BlockSpec((1, N, block_d), lambda b, d, c: (b, 0, d)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # y
            pl.BlockSpec((1, N, block_d), lambda b, d, c: (b, 0, d)),      # hf
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, di), x.dtype),
            jax.ShapeDtypeStruct((Bsz, N, di), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, block_d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, At, Bmat, Cmat, h0)
    return y, h_final
