"""Version shims for the pinned jax/pallas toolchain."""

from jax.experimental.pallas import tpu as pltpu

#: jax renamed TPUCompilerParams -> CompilerParams after 0.4.x; accept both
#: so the kernels work against the pinned toolchain and future upgrades.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
