"""Pallas TPU flash attention (forward) with GQA.

Online-softmax attention tiled for VMEM: the grid is
(batch*q_heads, Sq/block_q, Sk/block_k) with the key dimension innermost
("arbitrary" semantics) so the fp32 accumulators (acc, m, l) persist in
VMEM scratch across key blocks.  Causal blocks strictly above the diagonal
are skipped.  MXU dims: block_q x d and block_k x d matmuls with
preferred_element_type=float32.

Layout notes (TPU adaptation, see DESIGN.md):
  * q is reshaped to (B*Hq, Sq, d), k/v to (B*Hkv, Sk, d) by ops.py; the
    kv program index is derived as b*Hkv + (h // group) inside the
    BlockSpec index maps, so GQA costs no extra copies;
  * block_q/block_k default to 128 (MXU-aligned); d pads to lane width.

Validated in interpret mode against ref.attention_ref (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, block_q: int, block_k: int,
                  n_kv_blocks: int, causal: bool, q_offset: int,
                  kv_len: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this block's queries / keys
    q_first = q_offset + qi * block_q
    k_first = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)

        q_pos = q_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = None
        if causal:
            mask = q_pos >= k_pos
        if kv_len is not None:
            lm = k_pos < kv_len
            mask = lm if mask is None else (mask & lm)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq,)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                      # (bq, bk)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, d)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    if causal:
        # skip key blocks entirely above the causal diagonal
        q_last = q_first + block_q - 1
        pl.when(k_first <= q_last)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        n_q_heads: int, n_kv_heads: int,
                        causal: bool = True, q_offset: int = 0,
                        kv_len: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        sm_scale: Optional[float] = None,
                        interpret: bool = False) -> jax.Array:
    """q: (B*Hq, Sq, d); k/v: (B*Hkv, Sk, d) -> (B*Hq, Sq, d)."""
    BH, Sq, d = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % n_q_heads == 0 and BHkv % n_kv_heads == 0
    B = BH // n_q_heads
    group = n_q_heads // n_kv_heads
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q_blocks = Sq // block_q
    n_kv_blocks = Sk // block_k
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    def kv_index(bh, qi, ki):
        b = bh // n_q_heads
        h = bh % n_q_heads
        return (b * n_kv_heads + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_kv_blocks, causal=causal, q_offset=q_offset,
        kv_len=kv_len)

    grid = (BH, n_q_blocks, n_kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),     # l (running denom)
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
