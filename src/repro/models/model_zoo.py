"""Model construction: config -> (init, loss, forward, prefill, decode).

Handles all assigned families:
  * decoder-only LMs (dense / MoE / SSM / hybrid) — tokens in, logits out;
  * encoder-decoder (whisper backbone) — the audio conv frontend is a STUB:
    ``frames`` arrive as precomputed (B, encoder_seq, d_model) embeddings;
  * VLM (llama-3.2-vision backbone) — patch frontend is a STUB:
    ``image_embeds`` arrive as (B, n_image_tokens, d_image) and are
    projected into d_model for the cross-attention layers.

The cross-entropy loss is computed in fp32 with a chunked scan over the
sequence axis so the fp32 logit tensor never fully materializes (vocab
sizes here reach 163k).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import embed, init_embedding, init_head, init_rmsnorm, rmsnorm
from .module import dense_init, key_for
from .transformer import apply_stack, init_cache, init_stack, stack_cache_spec

Params = Dict[str, Any]

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3
CE_CHUNK = 512


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """Whisper encoder: uniform bidirectional attention + dense MLP."""
    return cfg.replace(n_layers=cfg.encoder_layers, encoder_layers=0,
                       cross_attn_period=0, ssm_state=0, attn_period=1,
                       n_experts=0, top_k=0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    p: Params = {
        "embed": init_embedding(key, cfg, dt),
        "stack": init_stack(key, cfg, dt),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = init_head(key, cfg, dt)
    if cfg.is_encdec:
        enc_cfg = encoder_config(cfg)
        p["encoder"] = {
            "stack": init_stack(key_for(key, "enc"), enc_cfg, dt,
                                prefix="enc_stack"),
            "final_norm": init_rmsnorm(cfg.d_model, dt),
        }
    if cfg.cross_attn_period > 0 and cfg.d_image not in (0, cfg.d_model):
        p["img_proj"] = dense_init(key_for(key, "img_proj"),
                                   (cfg.d_image, cfg.d_model), dt)
    return p


# ---------------------------------------------------------------------------
# cross-attention source
# ---------------------------------------------------------------------------

def _cross_source(params: Params, cfg: ModelConfig,
                  batch: Dict[str, jax.Array],
                  impl: Optional[str]) -> Optional[jax.Array]:
    if cfg.is_encdec:
        frames = batch["frames"]                 # (B, enc_seq, D) stub
        enc_cfg = encoder_config(cfg)
        h, _, _ = apply_stack(params["encoder"]["stack"], enc_cfg, frames,
                              causal=False, impl=impl)
        return rmsnorm(params["encoder"]["final_norm"], h, cfg.norm_eps)
    if cfg.cross_attn_period > 0:
        img = batch["image_embeds"]              # (B, n_img, d_image) stub
        if "img_proj" in params:
            img = jnp.einsum("bnd,de->bne", img, params["img_proj"])
        return img.astype(_dtype(cfg))
    return None


def _logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward_train(params: Params, cfg: ModelConfig,
                  batch: Dict[str, jax.Array],
                  impl: Optional[str] = None,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(params["embed"], batch["tokens"])
    cross = _cross_source(params, cfg, batch, impl)
    x, _, aux = apply_stack(params["stack"], cfg, x, cross_src=cross,
                            causal=True, impl=impl)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), aux


def _chunked_ce(logits_fn: Callable[[jax.Array], jax.Array], x: jax.Array,
                labels: jax.Array, chunk: int) -> Tuple[jax.Array, jax.Array]:
    """sum CE and token count, scanning S in chunks of ``chunk``."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fall back to single chunk for odd lengths
    n = S // chunk
    xs = (x.reshape(B, n, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n, chunk).swapaxes(0, 1))

    @jax.checkpoint
    def body(carry, args):
        xc, yc = args                                   # (B, c, D), (B, c)
        logits = logits_fn(xc).astype(jnp.float32)      # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        mask = (yc >= 0).astype(jnp.float32)
        ce_sum, n_tok = carry
        return (ce_sum + jnp.sum((lse - lab) * mask),
                n_tok + jnp.sum(mask)), None

    (ce_sum, n_tok), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return ce_sum, n_tok


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            impl: Optional[str] = None,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(params["embed"], batch["tokens"])
    cross = _cross_source(params, cfg, batch, impl)
    x, _, aux = apply_stack(params["stack"], cfg, x, cross_src=cross,
                            causal=True, impl=impl)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    ce_sum, n_tok = _chunked_ce(
        lambda xc: jnp.einsum("bsd,dv->bsv", xc, head), x, batch["labels"],
        CE_CHUNK)
    loss = ce_sum / jnp.maximum(n_tok, 1.0)
    metrics = {"ce_loss": loss, **aux}
    if "moe_load_balance" in aux:
        loss = (loss + MOE_LB_WEIGHT * aux["moe_load_balance"]
                + MOE_Z_WEIGHT * aux["moe_z_loss"])
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Params, impl: Optional[str] = None,
            ) -> Tuple[jax.Array, Params]:
    """Process the prompt, writing KV/SSM caches. Returns last-pos logits."""
    x = embed(params["embed"], batch["tokens"])
    cross = _cross_source(params, cfg, batch, impl)
    x, cache, _ = apply_stack(params["stack"], cfg, x, cross_src=cross,
                              caches=cache, pos=0, causal=True, impl=impl)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return _logits(params, cfg, x), cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Params, pos: jax.Array,
                impl: Optional[str] = None) -> Tuple[jax.Array, Params]:
    """One decode step. token: (B, 1) int32; pos: scalar int32."""
    x = embed(params["embed"], token)
    x, cache, _ = apply_stack(params["stack"], cfg, x, cross_src=None,
                              caches=cache, pos=pos, causal=True, impl=impl)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), cache


# ---------------------------------------------------------------------------
# bundle
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    forward: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., Tuple[jax.Array, Params]]
    decode: Callable[..., Tuple[jax.Array, Params]]
    make_cache: Callable[[int, int], Params]
    cache_spec: Callable[[int, int], Params]


def build_model(cfg: ModelConfig) -> ModelBundle:
    cross_len = (cfg.encoder_seq if cfg.is_encdec
                 else cfg.n_image_tokens if cfg.cross_attn_period else 0)
    return ModelBundle(
        cfg=cfg,
        init=functools.partial(lambda key, c=cfg: init_params(c, key)),
        loss=functools.partial(lambda p, b, c=cfg, **kw: loss_fn(p, c, b, **kw)),
        forward=functools.partial(
            lambda p, b, c=cfg, **kw: forward_train(p, c, b, **kw)),
        prefill=functools.partial(
            lambda p, b, cache, c=cfg, **kw: prefill(p, c, b, cache, **kw)),
        decode=functools.partial(
            lambda p, t, cache, pos, c=cfg, **kw: decode_step(
                p, c, t, cache, pos, **kw)),
        make_cache=lambda batch, s_max, c=cfg: init_cache(
            c, batch, s_max, cross_len),
        cache_spec=lambda batch, s_max, c=cfg: stack_cache_spec(
            c, batch, s_max, cross_len),
    )
