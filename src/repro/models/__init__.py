"""Model zoo: composable JAX definitions of the assigned architectures."""

from .config import ModelConfig
from .model_zoo import (ModelBundle, build_model, decode_step, forward_train,
                        init_params, loss_fn, prefill)

__all__ = ["ModelBundle", "ModelConfig", "build_model", "decode_step",
           "forward_train", "init_params", "loss_fn", "prefill"]
