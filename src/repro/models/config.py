"""Unified architecture configuration for the assigned model families.

One frozen dataclass describes every architecture in the pool: dense GQA
transformers, MoE (token-choice top-k, optional shared experts / dense
residual), Mamba-1 SSM, hybrid attention/SSM interleaves, encoder-decoder
(whisper backbone), and VLM cross-attention layers.

Layer patterns are expressed as a repeating *super-block* so that
scan-over-layers works for heterogeneous stacks (jamba: 1 attention + 7
mamba per period of 8; llama-vision: 1 cross-attention per period of 5).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int                    # dense-MLP width (and expert width unless set)
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # ---- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0         # 0 -> d_ff
    n_shared_experts: int = 0    # always-on experts (kimi)
    dense_residual: bool = False # dense MLP in parallel with MoE (arctic)
    moe_period: int = 1          # MoE on layers with i % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # ---- SSM (mamba-1) -------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 -> d_model // 16
    # hybrid interleave: attention on layers with i % attn_period == attn_offset;
    # attn_period == 1 means all-attention, 0 means attention-free.
    attn_period: int = 1
    attn_offset: int = 0

    # ---- encoder-decoder (whisper backbone; audio frontend stubbed) ---------
    encoder_layers: int = 0
    encoder_seq: int = 1500      # frames after the (stubbed) conv frontend

    # ---- VLM cross-attention (llama-3.2-vision backbone; frontend stubbed) --
    cross_attn_period: int = 0   # cross-attn on layers i % period == offset
    cross_attn_offset: int = 0
    n_image_tokens: int = 0
    d_image: int = 0             # stub patch-embedding dim (0 -> d_model)

    # ---- misc ------------------------------------------------------------------
    qkv_bias: bool = False
    act: str = "silu"            # silu (SwiGLU) | gelu (2-matmul MLP)
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # remat policy for scan-over-layers: nothing | dots | full
    remat_policy: str = "nothing"

    # -------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.d_ff_expert == 0 and self.n_experts > 0:
            object.__setattr__(self, "d_ff_expert", self.d_ff)
        if self.dt_rank == 0 and self.ssm_state > 0:
            object.__setattr__(self, "dt_rank", max(1, self.d_model // 16))

    # ---- layer pattern ---------------------------------------------------------
    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for the mixer of layer i."""
        if self.ssm_state > 0:
            if self.attn_period == 0:
                return "ssm"
            return "attn" if i % self.attn_period == self.attn_offset else "ssm"
        return "attn"

    def layer_has_cross_attn(self, i: int) -> bool:
        return (self.cross_attn_period > 0
                and i % self.cross_attn_period == self.cross_attn_offset)

    def layer_is_moe(self, i: int) -> bool:
        return (self.n_experts > 0
                and i % self.moe_period == self.moe_offset)

    @property
    def superblock_size(self) -> int:
        """Smallest repeating period of the layer pattern."""
        period = 1
        if self.ssm_state > 0 and self.attn_period > 1:
            period = _lcm(period, self.attn_period)
        if self.cross_attn_period > 0:
            period = _lcm(period, self.cross_attn_period)
        if self.n_experts > 0 and self.moe_period > 1:
            period = _lcm(period, self.moe_period)
        return period

    @property
    def n_superblocks(self) -> int:
        if self.n_layers % self.superblock_size:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"superblock={self.superblock_size}")
        return self.n_layers // self.superblock_size

    def superblock_pattern(self) -> List[Dict[str, object]]:
        """Per-layer spec of one super-block."""
        return [
            {
                "kind": self.layer_kind(i),
                "cross_attn": self.layer_has_cross_attn(i),
                "moe": self.layer_is_moe(i),
                # pure-SSM archs (falcon-mamba) have no MLP sublayer
                "mlp": (not self.layer_is_moe(i)) and self.d_ff > 0,
            }
            for i in range(self.superblock_size)
        ]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM / hybrid) -> long_500k runs."""
        return self.ssm_state > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (roofline + checkpoint sizing) ---------------------
    def param_count(self) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # input embedding
        if not self.tie_embeddings:
            total += V * D  # output head
        total += D  # final norm

        def attn_params() -> int:
            qk = D * self.n_heads * self.head_dim
            kv = D * self.n_kv_heads * self.head_dim
            n = 2 * qk + 2 * kv  # wq, wo, wk, wv
            if self.qkv_bias:
                n += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            return n

        def mlp_params(width: int) -> int:
            if self.act == "gelu":
                return 2 * D * width + width + D  # 2 matmuls + biases
            return 3 * D * width  # SwiGLU

        def ssm_params() -> int:
            di, N, R = self.d_inner, self.ssm_state, self.dt_rank
            n = D * 2 * di            # in_proj (x and z branches)
            n += di * self.ssm_conv + di  # depthwise conv + bias
            n += di * (R + 2 * N)     # x -> (dt_rank, B, C)
            n += R * di + di          # dt proj + bias
            n += di * N + di          # A_log, D
            n += di * D               # out_proj
            return n

        for i in range(self.n_layers):
            total += D  # pre-mixer norm
            if self.layer_is_moe(i) or self.d_ff > 0:
                total += D  # pre-mlp/moe norm
            if self.layer_kind(i) == "attn":
                total += attn_params()
            else:
                total += ssm_params()
            if self.layer_has_cross_attn(i):
                total += attn_params() + D  # extra norm
            if self.layer_is_moe(i):
                total += self.n_experts * 3 * D * self.d_ff_expert
                total += D * self.n_experts  # router
                total += self.n_shared_experts * 3 * D * self.d_ff_expert
                if self.dense_residual:
                    total += mlp_params(F)
            else:
                total += mlp_params(F)

        for i in range(self.encoder_layers):
            total += 2 * D + attn_params() + mlp_params(F)
        if self.encoder_layers:
            total += D  # encoder final norm
        if self.cross_attn_period > 0 and self.d_image not in (0, D):
            total += self.d_image * D  # patch-embedding projector (stub)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared instead of all)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model \
            * self.d_ff_expert * n_moe_layers
        return full - inactive


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)
