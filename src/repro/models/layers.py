"""Core transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

Functional style: ``init_*`` builds a param dict, ``apply``-style functions
are pure.  Weights keep explicit head axes — wq (D, Hq, hd), wo (Hq, hd, D)
— so TP sharding rules can target the head dimension by name.

Attention routes through kernels.ops.flash_attention (Pallas on TPU, jnp
reference elsewhere); KV caches are written in-place with
dynamic_update_slice so decode steps lower to a single cache update.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .module import dense_init, embed_init, key_for, ones_init, zeros_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, d) with even d; positions: (S,) or scalar-broadcast."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (S, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (self / cross, with optional KV cache)
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig, path: str,
                   dtype) -> Params:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p: Params = {
        "wq": dense_init(key_for(key, path + "/wq"), (D, Hq, hd), dtype),
        "wk": dense_init(key_for(key, path + "/wk"), (D, Hkv, hd), dtype),
        "wv": dense_init(key_for(key, path + "/wv"), (D, Hkv, hd), dtype),
        "wo": dense_init(key_for(key, path + "/wo"), (Hq, hd, D), dtype,
                         scale=1.0 / (Hq * hd) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    return p


def attention(p: Params, cfg: ModelConfig, x: jax.Array, *,
              kv_src: Optional[jax.Array] = None, cross: bool = False,
              cache: Optional[Params] = None,
              pos=0, causal: bool = True, use_rope: bool = True,
              impl: Optional[str] = None,
              ) -> Tuple[jax.Array, Optional[Params]]:
    """Self- or cross-attention.

    x: (B, S, D).  cross=True: keys/values come from ``kv_src``
    (encoder/image states) when given, else from the cross KV cache.
    cache: {"k","v"}: (B, S_max, Hkv, hd); ``pos`` is the absolute position
    of x[0] (0 for train/prefill, traced scalar for decode).
    Returns (out (B, S, D), updated cache or None).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]

    if cross:
        # ---- cross-attention: static KV from encoder/image states -------
        if kv_src is not None:  # train / prefill: compute KV
            k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
            if "bk" in p:
                k, v = k + p["bk"], v + p["bv"]
            if cache is not None:
                k = k.astype(cache["k"].dtype)
                v = v.astype(cache["v"].dtype)
        else:  # decode: reuse cached KV
            assert cache is not None, "cross-attention decode needs a cache"
            k, v = cache["k"], cache["v"]
        new_cache = {"k": k, "v": v} if cache is not None else None
        out = ops.flash_attention(q, k, v, causal=False, impl=impl)
        return (jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"]),
                new_cache)

    # ---- self-attention ---------------------------------------------------
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]

    if use_rope:
        positions = pos + jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # write k/v into the cache at ``pos``
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        if S == 1:
            # decode: attend over the cache up to pos+1
            out = ops.flash_attention(q, ck, cv, causal=False,
                                      kv_len=pos + 1, impl=impl)
        else:
            # prefill: attend over freshly computed keys only
            out = ops.flash_attention(q, k, v, causal=causal, q_offset=0,
                                      impl=impl)
    else:
        out = ops.flash_attention(q, k, v, causal=causal, impl=impl)

    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"]), new_cache


def attn_cache_spec(cfg: ModelConfig, batch: int, s_max: int,
                    dtype=None) -> Dict[str, jax.ShapeDtypeStruct]:
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    shape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, width: int, path: str,
             dtype) -> Params:
    D = cfg.d_model
    if cfg.act == "gelu":
        return {
            "wi": dense_init(key_for(key, path + "/wi"), (D, width), dtype),
            "bi": jnp.zeros((width,), dtype),
            "wo_mlp": dense_init(key_for(key, path + "/wo"), (width, D), dtype),
            "bo": jnp.zeros((D,), dtype),
        }
    return {
        "wg": dense_init(key_for(key, path + "/wg"), (D, width), dtype),
        "wu": dense_init(key_for(key, path + "/wu"), (D, width), dtype),
        "wd": dense_init(key_for(key, path + "/wd"), (width, D), dtype),
    }


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if "wi" in p:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"])
        return jnp.einsum("bsf,fd->bsd", h, p["wo_mlp"]) + p["bo"]
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["wd"])


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    return embed_init(key_for(key, "embed"), (cfg.vocab_size, cfg.d_model),
                      dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def init_head(key: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    return dense_init(key_for(key, "head"), (cfg.d_model, cfg.vocab_size),
                      dtype)
