"""Model assembly: layer -> super-block -> scanned stack -> LM.

The layer stack is expressed as ``n_superblocks`` repetitions of a fixed
per-superblock pattern (see ModelConfig.superblock_pattern), with the
superblock parameters stacked on a leading axis and the stack applied via
``lax.scan`` (+ jax.checkpoint remat).  This keeps HLO size O(superblock)
for 100-layer models — essential for CPU-hosted 512-device dry-run
compiles — and makes activation-checkpoint policy a config knob.

Three entry points per model:
  * forward_train(params, tokens, extra)          -> logits
  * prefill(params, tokens, extra, cache, pos=0)  -> logits, cache
  * decode_step(params, token, cache, pos)        -> logits, cache
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attn_cache_spec, embed, init_attention,
                     init_embedding, init_head, init_mlp, init_rmsnorm, mlp,
                     rmsnorm)
from .moe import init_moe, moe
from .module import key_for
from .ssm import init_mamba, mamba, mamba_cache_spec

Params = Dict[str, Any]

REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def init_layer(key: jax.Array, cfg: ModelConfig, spec: Dict[str, Any],
               path: str, dtype) -> Params:
    p: Params = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if spec["kind"] == "attn":
        p["attn"] = init_attention(key, cfg, path + "/attn", dtype)
    else:
        p["ssm"] = init_mamba(key, cfg, path + "/ssm", dtype)
    if spec["cross_attn"]:
        p["norm_x"] = init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = init_attention(key, cfg, path + "/cross", dtype)
    if spec["moe"]:
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["moe"] = init_moe(key, cfg, path + "/moe", dtype)
    elif spec["mlp"]:
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["mlp"] = init_mlp(key, cfg, cfg.d_ff, path + "/mlp", dtype)
    return p


def apply_layer(p: Params, cfg: ModelConfig, spec: Dict[str, Any],
                x: jax.Array, *, cross_src: Optional[jax.Array],
                cache: Optional[Params], pos, causal: bool, impl,
                ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    new_cache: Params = {}
    aux: Dict[str, jax.Array] = {}

    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec["kind"] == "attn":
        self_cache = cache.get("self") if cache else None
        h, nc = attention(p["attn"], cfg, h, cache=self_cache, pos=pos,
                          causal=causal, impl=impl)
        if nc is not None:
            new_cache["self"] = nc
    else:
        ssm_cache = cache.get("ssm") if cache else None
        h, nc = mamba(p["ssm"], cfg, h, cache=ssm_cache, impl=impl)
        if nc is not None:
            new_cache["ssm"] = nc
    x = x + h

    if spec["cross_attn"]:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        cross_cache = cache.get("cross") if cache else None
        h, nc = attention(p["cross"], cfg, h, kv_src=cross_src, cross=True,
                          cache=cross_cache, causal=False, impl=impl)
        if nc is not None:
            new_cache["cross"] = nc
        x = x + h

    if "moe" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        h, aux = moe(p["moe"], cfg, h)
        x = x + h
    elif "mlp" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        h = mlp(p["mlp"], cfg, h)
        x = x + h
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def layer_cache_spec(cfg: ModelConfig, spec: Dict[str, Any], batch: int,
                     s_max: int, cross_len: int) -> Params:
    out: Params = {}
    if spec["kind"] == "attn":
        out["self"] = attn_cache_spec(cfg, batch, s_max)
    else:
        out["ssm"] = mamba_cache_spec(cfg, batch)
    if spec["cross_attn"]:
        out["cross"] = attn_cache_spec(cfg, batch, cross_len)
    return out


def stack_cache_spec(cfg: ModelConfig, batch: int, s_max: int,
                     cross_len: int = 0) -> Params:
    """ShapeDtypeStructs for the full decode cache (stacked superblocks)."""
    pattern = cfg.superblock_pattern()
    n_sb = cfg.n_superblocks

    def _stack(sds: jax.ShapeDtypeStruct) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((n_sb,) + sds.shape, sds.dtype)

    per_layer = {
        f"layer{j}": jax.tree.map(_stack,
                                  layer_cache_spec(cfg, spec, batch, s_max,
                                                   cross_len))
        for j, spec in enumerate(pattern)
    }
    return per_layer


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               cross_len: int = 0) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        stack_cache_spec(cfg, batch, s_max, cross_len))


# ---------------------------------------------------------------------------
# stacked superblocks
# ---------------------------------------------------------------------------

def init_stack(key: jax.Array, cfg: ModelConfig, dtype,
               prefix: str = "stack") -> Params:
    pattern = cfg.superblock_pattern()

    def init_one(sb_key: jax.Array) -> Params:
        return {
            f"layer{j}": init_layer(sb_key, cfg, spec,
                                    f"{prefix}/layer{j}", dtype)
            for j, spec in enumerate(pattern)
        }

    sb_keys = jax.random.split(key_for(key, prefix), cfg.n_superblocks)
    return jax.vmap(init_one)(sb_keys)


def apply_stack(stacked: Params, cfg: ModelConfig, x: jax.Array, *,
                cross_src: Optional[jax.Array] = None,
                caches: Optional[Params] = None, pos=0,
                causal: bool = True, impl: Optional[str] = None,
                ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    pattern = cfg.superblock_pattern()
    has_cache = caches is not None

    from repro.parallel.context import constrain_activations

    def body(carry, xs):
        x = carry
        sb_params = xs[0]
        sb_cache = xs[1] if has_cache else None
        new_cache: Params = {}
        aux_acc: Dict[str, jax.Array] = {}
        for j, spec in enumerate(pattern):
            lc = sb_cache.get(f"layer{j}") if sb_cache else None
            x, nc, aux = apply_layer(
                sb_params[f"layer{j}"], cfg, spec, x, cross_src=cross_src,
                cache=lc, pos=pos, causal=causal, impl=impl)
            if nc is not None:
                new_cache[f"layer{j}"] = nc
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v
        # boundary-activation sharding (SP) — no-op outside a step builder
        x = constrain_activations(x)
        outs = (new_cache, aux_acc) if has_cache else (aux_acc,)
        return x, outs

    policy = REMAT_POLICIES.get(cfg.remat_policy)
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)

    xs = (stacked, caches) if has_cache else (stacked,)
    x, outs = jax.lax.scan(body, x, xs)
    if has_cache:
        new_caches, aux_stack = outs
    else:
        new_caches = None
        (aux_stack,) = outs
    aux = {k: jnp.sum(v) / cfg.n_layers for k, v in aux_stack.items()}
    return x, new_caches, aux
