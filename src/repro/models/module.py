"""Minimal functional parameter-tree module system.

flax is not installed; models are pure functions over nested dicts of
jnp arrays.  Initializers split PRNG keys deterministically by path so that
parameter initialization is reproducible and shard-friendly (each init is
an independent jit-able computation).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def key_for(root: jax.Array, path: str) -> jax.Array:
    """Deterministic key derived from a string path (stable across runs)."""
    h = np.uint32(2166136261)
    for ch in path.encode():
        h = np.uint32((int(h) ^ ch) * 16777619 & 0xFFFFFFFF)
    return jax.random.fold_in(root, int(h))


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init (LLM standard)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def tree_paths(params: Params, prefix: str = "") -> Iterator[Tuple[str, Any]]:
    """Yield (path, leaf) with '/'-joined dict keys."""
    for k in sorted(params.keys()):
        v = params[k]
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            yield from tree_paths(v, p)
        else:
            yield p, v


def tree_size_bytes(params: Params) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for _, leaf in tree_paths(params))


def tree_param_count(params: Params) -> int:
    return sum(int(leaf.size) for _, leaf in tree_paths(params))


def tree_map_with_path(fn: Callable[[str, Any], Any], params: Params,
                       prefix: str = "") -> Params:
    out: Params = {}
    for k, v in params.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out[k] = tree_map_with_path(fn, v, p)
        else:
            out[k] = fn(p, v)
    return out
