"""Mamba-1 selective state-space block.

Structure (falcon-mamba / jamba SSM layers):

    x, z = in_proj(u)                   # (B, S, di) each, di = expand*D
    x    = silu(causal_conv1d(x))       # depthwise, width ssm_conv
    dt, B, C = x_proj(x)                # dt via low-rank + softplus
    y    = selective_scan(x, dt, A, B, C) + D * x
    out  = out_proj(y * silu(z))

The scan routes through kernels.ops.selective_scan (Pallas chunked scan on
TPU, lax.scan reference elsewhere).  Decode keeps a (conv window, ssm
state) cache and costs O(1) per token — this is why SSM/hybrid archs run
the long_500k shape.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .module import dense_init, key_for

Params = Dict[str, Any]


def init_mamba(key: jax.Array, cfg: ModelConfig, path: str, dtype) -> Params:
    D, di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    W = cfg.ssm_conv
    # S4D-real initialization for A; dt bias set for softplus(dt) ~ U[1e-3, 1e-1]
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": dense_init(key_for(key, path + "/in"), (D, 2 * di), dtype),
        "conv_w": dense_init(key_for(key, path + "/conv"), (W, di), dtype,
                             scale=1.0 / W ** 0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(key_for(key, path + "/xp"), (di, R + 2 * N), dtype),
        "dt_w": dense_init(key_for(key, path + "/dtw"), (R, di), dtype),
        "dt_b": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(key_for(key, path + "/out"), (di, D), dtype,
                               scale=1.0 / di ** 0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_window: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv via shifted adds. x (B,S,di), w (W,di)."""
    W = w.shape[0]
    if init_window is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = init_window.astype(x.dtype)                   # (B, W-1, di)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+W-1, di)
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(W):
        out = out + xp[:, i:i + S, :] * w[i]
    return out + b


def mamba_cache_spec(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (batch, cfg.d_inner, cfg.ssm_state), dtype),
    }


def mamba(p: Params, cfg: ModelConfig, u: jax.Array, *,
          cache: Optional[Params] = None, impl: Optional[str] = None,
          ) -> Tuple[jax.Array, Optional[Params]]:
    """u: (B, S, D) -> (out (B, S, D), updated cache or None)."""
    B, S, D = u.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)                        # (B, S, di)

    A = -jnp.exp(p["A_log"])                                # (di, N)

    if cache is not None and S == 1:
        # ---- decode step: conv from cached window, O(1) scan update ----
        window = jnp.concatenate([cache["conv"], x.astype(cache["conv"].dtype)],
                                 axis=1)                    # (B, W, di)
        xc = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
        xc = jax.nn.silu(xc).astype(u.dtype)                # (B, di)
        dbc = jnp.einsum("bd,de->be", xc, p["x_proj"])
        dt_low, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("br,rd->bd", dt_low, p["dt_w"])
            + p["dt_b"].astype(dt_low.dtype))
        y, h_new = ops.selective_scan_step(xc, dt, A, Bm, Cm, cache["ssm"])
        y = y + xc * p["D"].astype(y.dtype)
        new_cache = {"conv": window[:, 1:, :], "ssm": h_new}
        out = y[:, None, :]
    else:
        # ---- train / prefill ----
        init_window = cache["conv"] if cache is not None else None
        xc = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"],
                                      init_window))
        dbc = jnp.einsum("bsd,de->bse", xc, p["x_proj"])
        dt_low, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,rd->bsd", dt_low, p["dt_w"])
            + p["dt_b"].astype(dt_low.dtype))
        h0 = cache["ssm"] if cache is not None else None
        y, h_final = ops.selective_scan(xc, dt, A, Bm, Cm, h0, impl=impl)
        y = y + xc * p["D"].astype(y.dtype)
        new_cache = None
        if cache is not None:
            W = cfg.ssm_conv
            new_cache = {"conv": x[:, -(W - 1):, :].astype(cache["conv"].dtype),
                         "ssm": h_final}
        out = y

    out = out * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", out, p["out_proj"]), new_cache
