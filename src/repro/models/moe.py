"""Mixture-of-Experts layer: token-choice top-k routing, sort-based dispatch.

Dispatch strategy (TPU/GSPMD adaptation — see DESIGN.md §2.2): instead of
the classic one-hot dispatch einsum — whose FLOPs rival the expert matmuls
themselves at 384-expert scale — tokens are ranked into per-expert capacity
slots with an argsort over expert assignments, scattered into an
(E, C, D) buffer, processed by batched expert matmuls (the only O(T·D·F)
compute), and gathered back with combine weights.  Every step is
O(T·k·(log T + D)) memory; batch rows act as dispatch groups so the whole
layer is data-sharded, with experts sharded over the model axis.

Gradients: indices are integer (non-differentiable by construction);
gradients flow through the scatter/gather and the combine weights.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_mlp, mlp
from .module import dense_init, key_for

Params = Dict[str, Any]


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    """Per-group (batch-row) expert capacity, padded to a multiple of 8."""
    ideal = cfg.top_k * seq / cfg.n_experts * cfg.capacity_factor
    cap = max(cfg.top_k, int(-(-ideal // 1)))
    return min(-(-cap // 8) * 8, cfg.top_k * seq)


def init_moe(key: jax.Array, cfg: ModelConfig, path: str, dtype) -> Params:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p: Params = {
        "router": dense_init(key_for(key, path + "/router"), (D, E),
                             jnp.float32),
        "wg": dense_init(key_for(key, path + "/wg"), (E, D, F), dtype),
        "wu": dense_init(key_for(key, path + "/wu"), (E, D, F), dtype),
        "wd": dense_init(key_for(key, path + "/wd"), (E, F, D), dtype),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = init_mlp(key, cfg, cfg.n_shared_experts * F,
                               path + "/shared", dtype)
    if cfg.dense_residual:
        p["dense"] = init_mlp(key, cfg, cfg.d_ff, path + "/dense", dtype)
    return p


def _dispatch_one_group(x: jax.Array, top_idx: jax.Array, top_w: jax.Array,
                        n_experts: int, capacity: int,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based dispatch for one group (batch row).

    x: (S, D); top_idx/top_w: (S, k).
    Returns (buffer (E*C, D), tok_slot (E*C,), w_slot (E*C,)) where
    tok_slot[i] is the source token of slot i (== S for empty slots) and
    w_slot[i] its gate weight.  The combine is a slot->token scatter-add,
    which keeps the expert axis LOCAL under expert sharding (the only
    cross-device exchange is the (S, D) partial-sum — see moe()).
    """
    S, k = top_idx.shape
    eid = top_idx.reshape(-1)                                   # (S*k,)
    order = jnp.argsort(eid, stable=True)
    eid_sorted = eid[order]
    # rank of each slot within its expert
    starts = jnp.searchsorted(eid_sorted, jnp.arange(n_experts),
                              side="left")                       # (E,)
    rank_sorted = jnp.arange(S * k) - starts[eid_sorted]
    valid_sorted = rank_sorted < capacity
    slot_sorted = jnp.where(valid_sorted,
                            eid_sorted * capacity + rank_sorted,
                            n_experts * capacity)                # OOB -> drop
    tok_sorted = order // k
    buffer = jnp.zeros((n_experts * capacity, x.shape[-1]), x.dtype)
    buffer = buffer.at[slot_sorted].set(
        jnp.where(valid_sorted[:, None], x[tok_sorted], 0).astype(x.dtype),
        mode="drop")
    w_sorted = top_w.reshape(-1)[order]
    tok_slot = jnp.full((n_experts * capacity,), S, jnp.int32).at[
        slot_sorted].set(tok_sorted.astype(jnp.int32), mode="drop")
    w_slot = jnp.zeros((n_experts * capacity,), top_w.dtype).at[
        slot_sorted].set(jnp.where(valid_sorted, w_sorted, 0.0), mode="drop")
    return buffer, tok_slot, w_slot


def _dispatch_local_experts(x: jax.Array, top_idx: jax.Array,
                            top_w: jax.Array, e_lo: int, n_local: int,
                            capacity: int):
    """Dispatch one group's tokens to the LOCAL expert slice [e_lo,
    e_lo+n_local).  Assignments outside the slice are dropped on this
    device (they are handled by the device owning them)."""
    S, k = top_idx.shape
    in_range = (top_idx >= e_lo) & (top_idx < e_lo + n_local)
    remapped = jnp.where(in_range, top_idx - e_lo, n_local)  # OOB sentinel
    # reuse the sort-based ranking with n_local+1 virtual experts; slots of
    # the sentinel expert fall beyond n_local*capacity and are dropped
    eid = remapped.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_sorted = eid[order]
    starts = jnp.searchsorted(eid_sorted, jnp.arange(n_local), side="left")
    rank_sorted = jnp.arange(S * k) - starts[jnp.minimum(eid_sorted,
                                                         n_local - 1)]
    valid_sorted = (eid_sorted < n_local) & (rank_sorted < capacity)
    slot_sorted = jnp.where(valid_sorted,
                            eid_sorted * capacity + rank_sorted,
                            n_local * capacity)
    tok_sorted = order // k
    buffer = jnp.zeros((n_local * capacity, x.shape[-1]), x.dtype)
    buffer = buffer.at[slot_sorted].set(
        jnp.where(valid_sorted[:, None], x[tok_sorted], 0).astype(x.dtype),
        mode="drop")
    w_sorted = top_w.reshape(-1)[order]
    tok_slot = jnp.full((n_local * capacity,), S, jnp.int32).at[
        slot_sorted].set(tok_sorted.astype(jnp.int32), mode="drop")
    w_slot = jnp.zeros((n_local * capacity,), top_w.dtype).at[
        slot_sorted].set(jnp.where(valid_sorted, w_sorted, 0.0), mode="drop")
    return buffer, tok_slot, w_slot


def moe_shard_map(p: Params, cfg: ModelConfig, x: jax.Array,
                  top_idx: jax.Array, top_w: jax.Array,
                  mesh, fsdp_axes, tp_axis: str) -> jax.Array:
    """Explicit expert parallelism via shard_map.

    Every device holds E/tp experts and its batch-group shard of tokens
    (replicated over tp).  Dispatch/combine are device-local; the only
    collectives are the FSDP weight all-gather (params/tp per layer) and
    one psum of the (S, D) output partials — the hand-built EP schedule
    GSPMD's auto-partitioner could not find (§Perf kimi iteration 3).
    """
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, S)
    tp = mesh.shape[tp_axis]
    E_l = E // tp
    fsdp = tuple(a for a in (fsdp_axes or ()) if a in mesh.axis_names)
    b_shard = fsdp if fsdp and B % _axes_size(mesh, fsdp) == 0 else None

    def inner(x_l, ti_l, tw_l, wg, wu, wd):
        # x_l (B_l, S, D); wg/wu/wd local expert slices sharded on D/F
        if fsdp:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        e_lo = jax.lax.axis_index(tp_axis) * E_l
        buf, tok_slot, w_slot = jax.vmap(
            lambda xg, ig, wg_: _dispatch_local_experts(xg, ig, wg_, e_lo,
                                                        E_l, C)
        )(x_l, ti_l, tw_l)
        bufr = buf.reshape(x_l.shape[0], E_l, C, D)
        h_g = jax.nn.silu(jnp.einsum("becd,edf->becf", bufr, wg))
        h_u = jnp.einsum("becd,edf->becf", bufr, wu)
        y_buf = jnp.einsum("becf,efd->becd", h_g * h_u, wd)
        contrib = y_buf.reshape(x_l.shape[0], E_l * C, D) \
            * w_slot[..., None].astype(x_l.dtype)

        def combine(c, t):
            return jnp.zeros((S, D), x_l.dtype).at[t].add(c, mode="drop")

        y_partial = jax.vmap(combine)(contrib, tok_slot)
        return jax.lax.psum(y_partial, tp_axis)

    # wg (E, D, F) sharded (tp, fsdp, None); wd (E, F, D) -> (tp, None, fsdp)
    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(b_shard, None, None), P(b_shard, None, None),
                  P(b_shard, None, None),
                  P(tp_axis, fsdp or None, None),
                  P(tp_axis, fsdp or None, None),
                  P(tp_axis, None, fsdp or None)),
        out_specs=P(b_shard, None, None),
        check_vma=False,
    )(x, top_idx, top_w.astype(x.dtype), p["wg"], p["wu"], p["wd"])


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def moe(p: Params, cfg: ModelConfig, x: jax.Array,
        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (y (B, S, D), aux losses)."""
    from repro.parallel.context import constrain_moe_tokens
    x = constrain_moe_tokens(x)  # group-local tokens (see parallel.context)
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    top_w, top_idx = jax.lax.top_k(probs, k)                     # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux losses (shared by both dispatch paths)
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    one_hot = jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=(0, 1))
    aux = {"moe_load_balance": E * jnp.sum(me * ce),
           "moe_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)}

    # ---- explicit-EP path (shard_map): hand-scheduled collectives -------
    from repro.parallel.context import moe_shard_map_config
    sm = moe_shard_map_config()
    if sm is not None and E % sm[0].shape[sm[2]] == 0:
        mesh, fsdp, tp_axis = sm
        y = moe_shard_map(p, cfg, x, top_idx, top_w, mesh, fsdp, tp_axis)
        if "shared" in p:
            y = y + mlp(p["shared"], cfg, x)
        if "dense" in p:
            y = y + mlp(p["dense"], cfg, x)
        aux["moe_drop_fraction"] = jnp.float32(0.0)  # tracked on-device
        return y, aux

    buffer, tok_slot, w_slot = jax.vmap(
        lambda xg, ig, wg: _dispatch_one_group(xg, ig, wg, E, C)
    )(x, top_idx, top_w)
    # buffer: (B, E*C, D) -> expert batched matmuls, EP-sharded
    # (batch-groups over data, experts over model; see parallel.context)
    from repro.parallel.context import constrain_moe_buffer
    buf = constrain_moe_buffer(buffer.reshape(B, E, C, D))
    h_g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"]))
    h_u = jnp.einsum("becd,edf->becf", buf, p["wu"])
    y_buf = constrain_moe_buffer(
        jnp.einsum("becf,efd->becd", h_g * h_u, p["wd"]))

    # combine: weighted slot -> token scatter-add.  Expert-sharded devices
    # scatter their local slots into an (S, D) partial sum; GSPMD reduces
    # the partials over the expert axis (volume S*D, not E*C*D).
    contrib = y_buf.reshape(B, E * C, D) * w_slot[..., None].astype(x.dtype)

    def _combine_group(c, t):
        return jnp.zeros((S, D), x.dtype).at[t].add(c, mode="drop")

    y = jax.vmap(_combine_group)(contrib, tok_slot)
    valid = tok_slot < S                                        # (B, E*C)

    if "shared" in p:
        y = y + mlp(p["shared"], cfg, x)
    if "dense" in p:
        y = y + mlp(p["dense"], cfg, x)

    n_routed = jnp.sum(valid.astype(jnp.float32))
    aux["moe_drop_fraction"] = 1.0 - n_routed / (B * S * k)
    return y, aux
