"""Fault tolerance: failure injection, checkpoint restart, elastic re-mesh,
straggler policy.

This closes the loop with the paper: the *same* failure model AIReSim
sweeps (exponential per-server random + systematic rates) drives the
injector here, and the recovery path the trainer executes (restore +
seek + re-lower) is the recovery_time AIReSim charges.  Running the
trainer under injection produces an empirical overhead fraction that can
be validated against the simulator's prediction
(tests/test_fault_tolerance.py does exactly that).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.params import Params as ClusterParams


@dataclass
class FailureEvent:
    step: int
    kind: str          # "random" | "systematic" | "injected"
    wall_time: float


class FailureInjector:
    """Samples job-level failures from the cluster failure model.

    P(failure during a step) = 1 - exp(-lambda * step_minutes) with
    lambda = cluster-wide failure rate of the executing servers — the
    identical quantity core.analytical.cluster_failure_rate computes for
    the simulator.
    """

    def __init__(self, cluster: ClusterParams, step_minutes: float,
                 seed: int = 0, deterministic_steps: Optional[List[int]] = None):
        from repro.core.analytical import cluster_failure_rate
        self.rate_per_step = cluster_failure_rate(cluster) * step_minutes
        self.p_systematic = (
            cluster.systematic_failure_fraction * cluster.systematic_failure_rate
            / max(cluster.expected_failures_per_minute()
                  / max(cluster.job_size, 1), 1e-30)) if cluster.job_size else 0.0
        self.rng = np.random.default_rng(seed)
        self.deterministic_steps = set(deterministic_steps or [])
        self.events: List[FailureEvent] = []

    def check(self, step: int) -> Optional[FailureEvent]:
        if step in self.deterministic_steps:
            # one-shot: after the restart replays this step, don't re-fail
            self.deterministic_steps.discard(step)
            ev = FailureEvent(step, "injected", time.time())
            self.events.append(ev)
            return ev
        if self.rate_per_step > 0 and \
                self.rng.random() < 1.0 - math.exp(-self.rate_per_step):
            kind = "systematic" if self.rng.random() < 0.5 else "random"
            ev = FailureEvent(step, kind, time.time())
            self.events.append(ev)
            return ev
        return None


@dataclass
class StragglerPolicy:
    """Detect slow steps; the mitigation mirrors the DES scheduler's
    standby swap (evict slow host, swap warm standby, no host selection).

    threshold: step slower than ``threshold`` x running median counts as a
    straggler; ``patience`` consecutive stragglers trigger mitigation.
    """
    threshold: float = 2.0
    patience: int = 3
    window: int = 32
    _times: List[float] = field(default_factory=list)
    _strikes: int = 0
    n_stragglers: int = 0
    n_mitigations: int = 0

    def observe(self, step_time: float) -> bool:
        """Returns True when mitigation (host swap) should fire."""
        self._times.append(step_time)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 8:
            return False
        median = float(np.median(self._times[:-1]))
        if step_time > self.threshold * median:
            self.n_stragglers += 1
            self._strikes += 1
            if self._strikes >= self.patience:
                self._strikes = 0
                self.n_mitigations += 1
                return True
        else:
            self._strikes = 0
        return False


@dataclass
class ElasticState:
    """Tracks data-parallel capacity for elastic re-meshing."""
    n_replicas: int
    n_failed: int = 0
    relowered: int = 0

    def shrink(self) -> int:
        """Lose one data replica (node group); returns the new count."""
        if self.n_replicas <= 1:
            raise RuntimeError("cannot shrink below one replica")
        self.n_failed += 1
        self.n_replicas -= 1
        return self.n_replicas


class RecoveryStats:
    """Accounting mirroring RunResult for the live trainer."""

    def __init__(self):
        self.n_failures = 0
        self.n_restores = 0
        self.lost_steps = 0
        self.recovery_wall_s = 0.0
        self.straggler_mitigations = 0

    def overhead_fraction(self, useful_steps: int, step_time_s: float) -> float:
        total = useful_steps * step_time_s + self.recovery_wall_s \
            + self.lost_steps * step_time_s
        if total <= 0:
            return 0.0
        return 1.0 - useful_steps * step_time_s / total

    def to_dict(self) -> Dict[str, float]:
        return {"n_failures": self.n_failures, "n_restores": self.n_restores,
                "lost_steps": self.lost_steps,
                "recovery_wall_s": self.recovery_wall_s,
                "straggler_mitigations": self.straggler_mitigations}
