"""AdamW optimizer with warmup-cosine schedule and global-norm clipping.

optax is not installed; this is a from-scratch implementation designed for
sharded state: m/v mirror the parameter tree (and its shardings), and the
state dtype is configurable — fp32 by default, bf16 for the >=100B-param
architectures where fp32 moments alone would exceed v5e HBM (recorded in
the roofline table's fits-HBM column).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_fraction: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # bf16 for the 400B+ configs


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_fraction."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    frac = cfg.min_lr_fraction + (1 - cfg.min_lr_fraction) * cosine
    return cfg.learning_rate * warm * frac


def init_opt_state(params: Params, cfg: OptimizerConfig) -> Params:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Params, grads: Params, state: Params,
                 cfg: OptimizerConfig) -> Tuple[Params, Params, Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    state_dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(state_dt), v32.astype(state_dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
