"""Fault-tolerant training loop.

Wires together: sharded train_step (parallel.steps), the seekable data
pipeline, async checkpointing, failure injection + restart, straggler
policy, and the Young/Daly checkpoint cadence computed from the SAME
cluster parameters the AIReSim sweeps use (core.analytical).

This is the end-to-end driver behind examples/train_with_failures.py and
launch/train.py.
"""

from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.core.analytical import plan_checkpoints
from repro.core.params import Params as ClusterParams
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.models.model_zoo import ModelBundle
from repro.parallel import ParallelConfig, make_train_step
from repro.train.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.train.fault_tolerance import (FailureInjector, RecoveryStats,
                                         StragglerPolicy)
from repro.train.optimizer import OptimizerConfig, init_opt_state


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: Optional[int] = None   # None -> Young/Daly cadence
    checkpoint_cost_minutes: float = 1.0     # write cost fed to Young/Daly
    step_minutes: float = 1.0                # simulated minutes per step
    keep_checkpoints: int = 3
    seed: int = 0
    inject_failures: bool = False
    deterministic_failure_steps: Optional[List[int]] = None
    cluster: ClusterParams = field(default_factory=ClusterParams)


def checkpoint_cadence(cfg: TrainLoopConfig) -> int:
    """Steps between checkpoints (Young/Daly on the cluster params)."""
    if cfg.checkpoint_every is not None:
        return cfg.checkpoint_every
    plan = plan_checkpoints(cfg.cluster, cfg.checkpoint_cost_minutes)
    if math.isinf(plan.interval_minutes):
        return max(cfg.total_steps // 4, 1)
    return max(1, int(round(plan.interval_minutes / cfg.step_minutes)))


def train(bundle: ModelBundle, mesh, shape: ShapeSpec,
          loop_cfg: TrainLoopConfig,
          opt_cfg: OptimizerConfig = OptimizerConfig(),
          pcfg: ParallelConfig = ParallelConfig(),
          impl: Optional[str] = None) -> Dict[str, Any]:
    """Run the loop; returns history + recovery stats."""
    cfg = bundle.cfg
    built = make_train_step(bundle, mesh, shape, opt_cfg, pcfg, impl)

    pipeline = SyntheticTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len + 1,
        global_batch=shape.global_batch, seed=loop_cfg.seed))

    # ---- init or resume ---------------------------------------------------
    ckpt = AsyncCheckpointer(loop_cfg.checkpoint_dir,
                             keep=loop_cfg.keep_checkpoints)
    start_step = 0
    resume = latest_step(loop_cfg.checkpoint_dir)
    with mesh:
        if resume is not None:
            start_step, host_state, extra = restore_checkpoint(
                loop_cfg.checkpoint_dir)
            state = jax.tree.map(jax.numpy.asarray, host_state)
            pipeline.seek(extra.get("data_step", start_step))
        else:
            params = bundle.init(jax.random.PRNGKey(loop_cfg.seed))
            state = {"params": params,
                     "opt": init_opt_state(params, opt_cfg)}
            pipeline.seek(0)

    injector = FailureInjector(
        loop_cfg.cluster, loop_cfg.step_minutes, seed=loop_cfg.seed + 1,
        deterministic_steps=loop_cfg.deterministic_failure_steps
    ) if loop_cfg.inject_failures else None
    stragglers = StragglerPolicy()
    stats = RecoveryStats()
    cadence = checkpoint_cadence(loop_cfg)

    history: List[Dict[str, float]] = []
    last_ckpt_step = start_step
    step = start_step
    t_loop = time.time()

    while step < loop_cfg.total_steps:
        batch_np = pipeline.with_frontend_stubs(pipeline.batch_at(step), cfg)
        batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
        # truncate tokens/labels to seq_len (pipeline emits seq_len+1 grid)
        batch["tokens"] = batch["tokens"][:, :shape.seq_len]
        batch["labels"] = batch["labels"][:, :shape.seq_len]

        # ---- simulated failure? restore-from-checkpoint restart ----------
        if injector is not None and injector.check(step) is not None:
            stats.n_failures += 1
            t0 = time.time()
            ckpt.wait()
            resume_step = latest_step(loop_cfg.checkpoint_dir)
            if resume_step is not None:
                _, host_state, extra = restore_checkpoint(
                    loop_cfg.checkpoint_dir)
                with mesh:
                    state = jax.tree.map(jax.numpy.asarray, host_state)
                stats.lost_steps += step - resume_step
                step = resume_step
                pipeline.seek(extra.get("data_step", resume_step))
            else:  # no checkpoint yet: restart from scratch
                with mesh:
                    params = bundle.init(jax.random.PRNGKey(loop_cfg.seed))
                    state = {"params": params,
                             "opt": init_opt_state(params, opt_cfg)}
                stats.lost_steps += step
                step = 0
                pipeline.seek(0)
            stats.n_restores += 1
            stats.recovery_wall_s += time.time() - t0
            continue

        t0 = time.time()
        with mesh:
            state, metrics = built.fn(state, batch)
        loss = float(metrics["loss"])
        step_time = time.time() - t0
        if stragglers.observe(step_time):
            stats.straggler_mitigations += 1  # real fleet: evict + standby

        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}: {loss}")
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            history.append({"step": step, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "lr": float(metrics["lr"]),
                            "step_time_s": step_time})
        step += 1

        if step - last_ckpt_step >= cadence:
            ckpt.save(step, state, extra={"data_step": step})
            last_ckpt_step = step

    ckpt.save(step, state, extra={"data_step": step})
    ckpt.close()
    return {
        "history": history,
        "final_loss": history[-1]["loss"] if history else float("nan"),
        "steps": step - start_step,
        "wall_s": time.time() - t_loop,
        "checkpoint_cadence": cadence,
        "recovery": stats.to_dict(),
        "stragglers": {"n": stragglers.n_stragglers,
                       "mitigations": stragglers.n_mitigations},
    }
