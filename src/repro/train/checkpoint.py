"""Sharded checkpointing: per-host npz shards + manifest, async writes.

The mechanism AIReSim models (assumption: asynchronous checkpoints with
cheap steady-state overhead, expensive restart): the training loop hands a
state pytree to ``AsyncCheckpointer.save`` and keeps stepping while a
worker thread serializes.  Restores are synchronous (they gate the
restart, i.e. the paper's recovery_time).

Layout:
    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, step
        shard_00000.npz        # flat {path: array} for this host's slice
Multi-host: each host writes the leaves it owns (addressable shards);
in this single-process container there is one shard file.  Integrity: the
manifest carries per-leaf checksums (crc32 of a strided sample) verified
on load.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

Params = Dict[str, Any]


def _flatten(tree: Params, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for k in sorted(tree.keys()):
        v = tree[k]
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = np.asarray(v)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Params:
    tree: Params = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def _checksum(a: np.ndarray) -> int:
    sample = a.reshape(-1)[:: max(1, a.size // 4096)]
    return zlib.crc32(np.ascontiguousarray(sample).tobytes()) & 0xFFFFFFFF


#: dtypes numpy's npz round-trips as raw void — store bit-cast instead
_ENCODED_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                   "float8_e5m2": np.uint8}


def _encode(a: np.ndarray) -> Tuple[np.ndarray, str]:
    name = str(a.dtype)
    if name in _ENCODED_DTYPES:
        return a.view(_ENCODED_DTYPES[name]), name
    return a, name


def _decode(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _ENCODED_DTYPES:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return a


def save_checkpoint(directory: str, step: int, state: Params,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous sharded save; returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    encoded = {p: _encode(a) for p, a in flat.items()}
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {p: {"shape": list(a.shape), "dtype": dtype_name,
                       "crc32": _checksum(enc)}
                   for p, a in flat.items()
                   for enc, dtype_name in [encoded[p]]},
        "format": 2,
    }
    np.savez(os.path.join(tmp, "shard_00000.npz"),
             **{p: enc for p, (enc, _) in encoded.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish
    return path


def restore_checkpoint(directory: str, step: Optional[int] = None,
                       ) -> Tuple[int, Params, Dict[str, Any]]:
    """Load the given (or latest) checkpoint; verifies checksums."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shard_00000.npz")) as z:
        raw = {p: z[p] for p in z.files}
    flat = {}
    for p, meta in manifest["leaves"].items():
        if p not in raw:
            raise IOError(f"checkpoint missing leaf {p}")
        if _checksum(raw[p]) != meta["crc32"]:
            raise IOError(f"checksum mismatch at {p} — corrupt checkpoint")
        flat[p] = _decode(raw[p], meta["dtype"])
    return manifest["step"], _unflatten(flat), manifest.get("extra", {})


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class AsyncCheckpointer:
    """Background-thread writer: training never blocks on serialization.

    ``save`` snapshots the (host-transferred) state and enqueues it; a
    single worker drains the queue.  ``wait`` barriers (used before exit
    and in tests).  Keeps the newest ``keep`` checkpoints.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._queue: "queue.Queue" = queue.Queue()
        self._error: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.save_count = 0

    def _run(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            step, state, extra = item
            try:
                save_checkpoint(self.directory, step, state, extra)
                self._gc()
            except BaseException as exc:  # surfaced on next save/wait
                self._error = exc
            finally:
                self._queue.task_done()

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:09d}"), ignore_errors=True)

    def save(self, step: int, state: Params,
             extra: Optional[Dict[str, Any]] = None) -> None:
        if self._error:
            raise self._error
        # snapshot to host memory so the device buffers can be donated
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._queue.put((step, host_state, extra))
        self.save_count += 1

    def wait(self) -> None:
        self._queue.join()
        if self._error:
            raise self._error

    def close(self) -> None:
        self.wait()
        self._queue.put(None)
        self._worker.join(timeout=10)
