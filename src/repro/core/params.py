"""Simulation parameters (the paper's `Params` data class).

All thirteen §III-B input parameters are present under the paper's own
names, with Table-I defaults. Time unit is MINUTES throughout (the paper's
rates are written per-minute, e.g. ``0.01/(24*60)``).

Extensions beyond the paper are grouped at the bottom and default to the
paper-faithful behavior (off / equivalent).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from .faultdomains import Campaign, FaultTopology
from .histograms import HistogramSpec

MINUTES_PER_DAY = 24 * 60


@dataclass
class Params:
    """Input parameters for one cluster-reliability simulation.

    All of the paper's §III-B inputs under their own names, with Table-I
    defaults; every time is in **minutes**.  Instances are plain
    dataclasses: build one, tweak copies with :meth:`replace`, and hand
    it to ``run_replications`` / the sweep classes (which route it to
    the right engine — see docs/engines.md).

    >>> p = Params(recovery_time=30.0, warm_standbys=32)
    >>> p.validate()                       # raises ValueError on bad input
    >>> p.replace(warm_standbys=8).warm_standbys   # copies, never mutates
    8
    >>> p.warm_standbys
    32
    >>> round(p.bad_failure_rate / p.random_failure_rate, 1)  # random + sys
    6.0

    Non-exponential failure processes are one switch (both engines
    understand them; Weibull and bathtub stay on the fast path):

    >>> bath = Params(failure_distribution="bathtub",
    ...               distribution_kwargs={"infant_factor": 20.0})
    >>> bath.validate()

    Trace-driven hazards fitted from real failure logs ride the same
    switch: the ``empirical`` family takes piecewise-constant segment
    ``edges``/``rates`` (typically a :class:`repro.core.empirical.
    PiecewiseFit`'s ``distribution_kwargs``) defining the hazard
    *shape*, rescaled so its mean matches the configured rate — pass
    ``random_failure_rate=fit.rate`` to reproduce a fit verbatim:

    >>> emp = Params(failure_distribution="empirical",
    ...              distribution_kwargs={"edges": [120.0],
    ...                                   "rates": [2.0, 0.5]})
    >>> emp.validate()

    Round trips for experiment files:

    >>> Params.from_dict(p.to_dict()) == p
    True
    """

    # ---- failure model (paper inputs 1-2) --------------------------------
    random_failure_rate: float = 0.01 / MINUTES_PER_DAY
    #: systematic rate is *additional* on top of random for bad servers
    systematic_failure_rate: float = 5 * 0.01 / MINUTES_PER_DAY
    systematic_failure_fraction: float = 0.15

    # ---- recovery / job (paper inputs 3-6) --------------------------------
    recovery_time: float = 20.0                 # minutes; checkpoint reload + restart
    job_size: int = 4096                        # servers needed to execute
    job_length: float = 64 * MINUTES_PER_DAY    # useful compute minutes (paper e.g. 256 days)
    warm_standbys: int = 16                     # allocated beyond job_size

    # ---- pools (paper inputs 7-8) ------------------------------------------
    working_pool_size: int = 4160
    spare_pool_size: int = 200

    # ---- host selection / preemption (Table I) -----------------------------
    host_selection_time: float = 3.0            # minutes
    waiting_time: float = 20.0                  # minutes to preempt a spare-pool job

    # ---- repair model (paper inputs 9-11) -----------------------------------
    auto_repair_time: float = 120.0             # minutes (mean)
    manual_repair_time: float = 2 * 1440.0      # minutes (mean)
    auto_repair_failure_probability: float = 0.4
    manual_repair_failure_probability: float = 0.2
    #: probability a failure is handled by automated repair (Table I
    #: "Automated repair probability"); 1-p escalates straight to manual.
    automated_repair_probability: float = 0.8

    # ---- diagnosis (paper inputs 12-13) -------------------------------------
    diagnosis_probability: float = 0.8          # failure diagnosed at all
    diagnosis_uncertainty: float = 0.0          # wrong server identified

    # ---- distributions (assumption 2) ---------------------------------------
    failure_distribution: str = "exponential"
    repair_distribution: str = "exponential"
    distribution_kwargs: Dict[str, Any] = field(default_factory=dict)

    # ---- extensions (default = paper-faithful) ------------------------------
    #: regenerate the bad-server set every N minutes (assumption 1 case 2);
    #: 0 disables (fixed bad set).
    bad_set_regeneration_period: float = 0.0
    #: retire a server after >= this many failures within retirement_window
    #: minutes; 0 disables retirement (paper §IV runs without it).
    retirement_threshold: int = 0
    retirement_window: float = 7 * MINUTES_PER_DAY
    #: if True, warm standbys also run failure processes while allocated
    #: (paper assumption 7 models failures only on executing servers).
    standbys_can_fail: bool = False
    #: explicit checkpoint model: if > 0, a failure additionally loses the
    #: work since the last checkpoint (interval in minutes). 0 = paper model
    #: (all failure cost folded into recovery_time).
    checkpoint_interval: float = 0.0
    #: wall-clock minutes each periodic checkpoint *write* costs (charged
    #: every ``checkpoint_interval`` minutes of useful compute; the
    #: failure clock is frozen while the write runs).  0 = free writes —
    #: the historical model, where only rollback is priced.  Both knobs
    #: are traced sweep axes on the CTMC fast path.
    checkpoint_cost: float = 0.0
    #: fixed preemption cost charged per spare-pool server drawn
    #: (assumption 7: "fixed cost per server ... that was preempted").
    preemption_cost: float = 0.0

    # ---- experiment control ---------------------------------------------------
    seed: int = 0
    max_sim_time: float = 10_000 * MINUTES_PER_DAY  # hard stop (deadlock guard)
    #: ring-buffer slots for exact per-run duration records in the
    #: vectorized CTMC engine (per replica).  Runs beyond the cap
    #: overwrite the oldest slot and surface as the
    #: ``run_duration_truncated`` statistic; per-replica means stay exact
    #: regardless.  The event engine keeps full Python lists and ignores
    #: this.
    max_run_records: int = 128
    #: streaming distribution outputs: log-spaced histograms of run
    #: durations (ETTF), recovery downtime (ETTR), and replacement
    #: waiting, accumulated with no run-count bound on both engines.
    #: Percentiles are exact to one bin width (see
    #: :class:`repro.core.histograms.HistogramSpec`); ``None`` compiles
    #: the accumulator out of the CTMC scan entirely.
    histogram: Optional[HistogramSpec] = field(default_factory=HistogramSpec)
    #: dtype of the CTMC engine's hazard-age arithmetic ("float32" |
    #: "float64").  The Weibull conditional inversion
    #: ``(a^k + E/C)^(1/k) - a`` and the repair-slot countdown cancel
    #: catastrophically at large ages in float32 (~1e-3 min absolute at
    #: age ~1e4); "float64" runs just those lanes in double precision
    #: (requires the ``jax_enable_x64`` flag) and rounds the sampled
    #: residuals back to float32 for the event race.
    age_dtype: str = "float32"
    #: repair-slot lane width of the CTMC engine under *non-exponential*
    #: repair distributions (each in-repair server occupies one slot
    #: carrying its class, stage, and remaining duration).  0 (default)
    #: auto-sizes from the expected shop occupancy (Little's law) with
    #: generous head-room, rounded to a power of two for program
    #: sharing.  A full lane surfaces as the ``n_repair_overflow``
    #: metric (the overflowing server stays in the shop forever) — raise
    #: this if that ever fires.  Exponential repairs ignore it.
    repair_slots: int = 0
    #: finite repair-shop capacity: at most this many servers are *in
    #: service* (automated or manual stage) at once; further failed
    #: servers queue inside the shop until a service slot frees up.  A
    #: freed slot admits a queued server chosen uniformly at random —
    #: which makes admission class- and owner-proportional over the
    #: queued counts, the property the CTMC engine's compartment model
    #: reproduces exactly in law.  0 (default) = unlimited servers (the
    #: paper's model: every repair starts immediately).
    repair_servers: int = 0
    #: correlated failure domains: a rack → pod topology with per-level
    #: exponential shock rates.  A shock atomically fails every server
    #: in the struck domain (running, spare, and in-repair alike).
    #: ``None`` (default) disables correlated failures entirely.  See
    #: :mod:`repro.core.faultdomains` and docs/scenarios.md.
    fault_domains: Optional[FaultTopology] = None
    #: scripted fault-injection campaign: a validated schedule of timed
    #: ``kill domain d at t`` and repair-shop maintenance windows,
    #: honored exactly by both engines.  ``None`` disables.
    campaign: Optional[Campaign] = None
    #: shard the CTMC engine's replica axis over this many local devices
    #: via ``shard_map`` (see :mod:`repro.parallel.sharding` and
    #: docs/scaling.md).  0 (default) = unsharded single-device dispatch;
    #: 1 = a one-device mesh (bit-identical to 0, guarded by tests);
    #: N > 1 splits each point's replicas into N independently-seeded
    #: streams (exact-in-law, not bit-identical to the unsharded run).
    #: Requires N visible devices and N | replica count — violations
    #: raise, never silently de-shard.
    engine_shards: int = 0
    #: event-race kernel dispatch of the CTMC engine: ``None`` (default)
    #: auto-selects — the Pallas kernel on TPU, the pure-jnp reference
    #: elsewhere.  ``"ref"`` forces the reference, ``"pallas"`` the TPU
    #: kernel (raises off-TPU), ``"pallas_interpret"`` the kernel body in
    #: interpret mode (CPU-runnable validation; slow).  See
    #: docs/scaling.md.
    event_race_impl: Optional[str] = None

    # -------------------------------------------------------------------------
    def validate(self) -> None:
        if self.job_size <= 0:
            raise ValueError("job_size must be positive")
        if self.working_pool_size < self.job_size:
            raise ValueError(
                f"working pool ({self.working_pool_size}) smaller than job "
                f"({self.job_size}); the job can never be scheduled")
        if self.warm_standbys < 0 or self.spare_pool_size < 0:
            raise ValueError("pool sizes must be non-negative")
        if not 0.0 <= self.systematic_failure_fraction <= 1.0:
            raise ValueError("systematic_failure_fraction must be in [0,1]")
        for name in ("auto_repair_failure_probability",
                     "manual_repair_failure_probability",
                     "automated_repair_probability",
                     "diagnosis_probability", "diagnosis_uncertainty"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must be a probability")
        for name in ("random_failure_rate", "systematic_failure_rate",
                     "recovery_time", "job_length", "host_selection_time",
                     "waiting_time", "auto_repair_time", "manual_repair_time",
                     "checkpoint_interval", "checkpoint_cost"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be non-negative")
        if self.max_run_records < 1:
            raise ValueError("max_run_records must be >= 1")
        if self.age_dtype not in ("float32", "float64"):
            raise ValueError(
                f"age_dtype={self.age_dtype!r} must be 'float32' or "
                "'float64'")
        if self.repair_slots < 0:
            raise ValueError("repair_slots must be non-negative")
        if self.repair_servers < 0:
            raise ValueError("repair_servers must be non-negative "
                             "(0 = unlimited)")
        if self.engine_shards < 0:
            raise ValueError("engine_shards must be non-negative "
                             "(0 = unsharded)")
        if self.event_race_impl not in (None, "ref", "pallas",
                                        "pallas_interpret"):
            raise ValueError(
                f"event_race_impl={self.event_race_impl!r} must be None, "
                "'ref', 'pallas', or 'pallas_interpret'")
        if self.histogram is not None:
            self.histogram.validate()
        if self.fault_domains is not None:
            self.fault_domains.validate(
                self.working_pool_size + self.spare_pool_size)
        if self.campaign is not None:
            self.campaign.validate(self.fault_domains)

    def replace(self, **kwargs) -> "Params":
        return dataclasses.replace(self, **kwargs)

    @property
    def bad_failure_rate(self) -> float:
        """Total failure rate of a bad server (random + systematic)."""
        return self.random_failure_rate + self.systematic_failure_rate

    @property
    def initial_standby_headroom(self) -> int:
        """Free working-pool servers beyond the job's allocation."""
        return self.working_pool_size - self.job_size - self.warm_standbys

    def expected_failures_per_minute(self) -> float:
        """Mean cluster-wide failure rate of the executing servers at t=0."""
        n_bad = self.systematic_failure_fraction * self.job_size
        n_good = self.job_size - n_bad
        return (n_good * self.random_failure_rate
                + n_bad * self.bad_failure_rate)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Params":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Params fields: {sorted(unknown)}")
        if isinstance(d.get("histogram"), dict):   # to_dict/yaml round trip
            d = dict(d, histogram=HistogramSpec.from_dict(d["histogram"]))
        if isinstance(d.get("fault_domains"), dict):
            d = dict(d, fault_domains=FaultTopology(**d["fault_domains"]))
        if isinstance(d.get("campaign"), dict):
            d = dict(d, campaign=Campaign(**d["campaign"]))
        return cls(**d)


def paper_table1_defaults() -> Params:
    """The exact Table-I default column (job_length set to 64 days; the
    paper's job length is illustrative — '(e.g., 256 days)' — and Table I
    does not pin it)."""
    return Params()


#: Table I "Value Range Considered" — used by the paper-reproduction sweeps.
PAPER_TABLE1_RANGES: Dict[str, list] = {
    "random_failure_rate": [0.005 / MINUTES_PER_DAY, 0.01 / MINUTES_PER_DAY,
                            0.025 / MINUTES_PER_DAY, 0.05 / MINUTES_PER_DAY],
    "systematic_failure_rate_multiplier": [3, 5, 10],   # x random rate
    "systematic_failure_fraction": [0.1, 0.15, 0.2],
    "recovery_time": [10.0, 20.0, 30.0],
    "warm_standbys": [4, 8, 16, 32],
    "host_selection_time": [1.0, 3.0, 5.0, 10.0],
    "waiting_time": [10.0, 20.0, 30.0],
    "automated_repair_probability": [0.70, 0.80, 0.90],
    "auto_repair_failure_probability": [0.2, 0.4, 0.6],
    "manual_repair_failure_probability": [0.1, 0.2, 0.3],
    "auto_repair_time": [60.0, 120.0, 180.0],
    "manual_repair_time": [1440.0, 2 * 1440.0, 3 * 1440.0],
    "working_pool_size": [4112, 4128, 4160, 4192],
    "spare_pool_size": [200, 300, 400],
    "diagnosis_probability": [0.6, 0.8, 1.0],
}
