"""Knob optimization on the batched fast path (close-the-loop layer).

The paper's stated purpose is *tuning* the failure/recovery knobs, not
just sweeping them.  This module turns the CTMC engine's one-XLA-
program-per-candidate-batch property into derivative-free optimizers:

  * :func:`optimize_checkpoint_interval` — coarse grid + golden-section
    refinement over ``Params.checkpoint_interval``, maximizing simulated
    goodput.  Every iteration evaluates its whole candidate set in ONE
    :func:`repro.core.backend.run_replications_batch` call (the interval
    is a *traced* sweep axis, so no candidate ever recompiles), and all
    candidates share common random numbers, which makes the sampled
    objective deterministic in the seed — golden-section on a unimodal
    response then converges like it would on a noiseless function.
  * :func:`optimize_knobs` — cyclic coordinate descent over any set of
    ``Params`` fields (e.g. warm_standbys x spare_pool_size x
    checkpoint_interval); each coordinate pass is again one batched
    call.  Structural fields ride the padded sweep path, so even mixed
    pool-size candidate rows stay inside a single compiled program.

Cross-check: in the low-overhead exponential regime the goodput-optimal
interval must land within one grid notch of
:func:`repro.core.analytical.young_daly_interval` — pinned in
tests/test_checkpoint_opt.py, plotted in docs/optimization.md.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .analytical import cluster_failure_rate, young_daly_interval
from .backend import run_replications_batch
from .params import Params

#: golden ratio conjugate: interior points of a golden-section bracket
_INVPHI = (math.sqrt(5.0) - 1.0) / 2.0


@dataclass(frozen=True)
class CheckpointOptResult:
    """Outcome of :func:`optimize_checkpoint_interval`."""

    interval: float                 #: argmax checkpoint interval (minutes)
    objective: float                #: its simulated objective value
    young_daly: float               #: sqrt(2*C*MTBF) reference interval
    grid: Tuple[float, ...]         #: coarse-stage candidate intervals
    grid_objective: Tuple[float, ...]  #: their simulated objectives
    #: (bracket_low, bracket_high) after each golden-section iteration —
    #: convergence is observable: widths shrink by invphi per iteration
    history: Tuple[Tuple[float, float], ...] = ()
    n_evals: int = 0                #: total simulated candidates


@dataclass(frozen=True)
class KnobOptResult:
    """Outcome of :func:`optimize_knobs`."""

    values: Dict[str, float]        #: best knob assignment
    objective: float                #: its simulated objective value
    #: one (knob, values-tried, objectives) triple per coordinate visit
    history: Tuple[Tuple[str, Tuple[float, ...], Tuple[float, ...]], ...] = ()
    n_evals: int = 0
    converged: bool = True          #: False = hit max_sweeps still moving


def _evaluate(grid: Sequence[Params], n_replicas: int, stat: str,
              engine: str, max_steps: Optional[int]) -> List[float]:
    """Mean ``stat`` per grid point — ONE batched call, CRN across points."""
    reps = run_replications_batch(list(grid), n_replicas, engine=engine,
                                  max_steps=max_steps)
    return [float(r.stats[stat].mean) for r in reps]


def default_interval_bounds(params: Params) -> Tuple[float, float]:
    """Bracket for the interval search: the Young/Daly point +- 8x, kept
    inside (0, job_length].  With a free write or a failure-free fleet
    there is no interior optimum; fall back to a job-length-scaled span.
    """
    lam = cluster_failure_rate(params)
    tau = young_daly_interval(max(params.checkpoint_cost, 0.0),
                              math.inf if lam <= 0 else 1.0 / lam)
    if not math.isfinite(tau) or tau <= 0:
        return params.job_length / 64.0, params.job_length
    lo = max(tau / 8.0, params.checkpoint_cost, 1e-3)
    hi = min(tau * 8.0, params.job_length)
    if lo >= hi:   # degenerate (huge cost or tiny job): widen downward
        lo = hi / 64.0
    return lo, hi


def optimize_checkpoint_interval(
        params: Params,
        n_replicas: int = 256,
        bounds: Optional[Tuple[float, float]] = None,
        n_grid: int = 12,
        refine_iters: int = 10,
        objective: str = "goodput",
        maximize: bool = True,
        engine: str = "ctmc",
        max_steps: Optional[int] = None) -> CheckpointOptResult:
    """Goodput-optimal ``checkpoint_interval`` for ``params``.

    Two stages, both exploiting the traced interval axis (each stage's
    candidate set is one XLA program, compiled once across ALL
    iterations because the batch shape is bucket-stable):

    1. a geometric ``n_grid``-point sweep over ``bounds`` (default:
       :func:`default_interval_bounds`, the Young/Daly point +- 8x);
    2. golden-section refinement of the bracket around the grid argmax —
       both interior probes of every iteration are evaluated together
       in one batched call.

    Common random numbers (``params.seed`` shared by every candidate)
    make the simulated objective a deterministic function of the
    interval, so the refinement is a real optimization, not a noisy
    race.  Returns a :class:`CheckpointOptResult`; ``history`` records
    the shrinking bracket for convergence tests.
    """
    if n_grid < 3:
        raise ValueError("n_grid must be >= 3 to bracket an optimum")
    lo, hi = bounds if bounds is not None else default_interval_bounds(params)
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    sign = 1.0 if maximize else -1.0
    lam = cluster_failure_rate(params)
    yd = young_daly_interval(max(params.checkpoint_cost, 0.0),
                             math.inf if lam <= 0 else 1.0 / lam)

    # stage 1: geometric coarse grid, one batched call
    ratio = (hi / lo) ** (1.0 / (n_grid - 1))
    grid = [lo * ratio ** i for i in range(n_grid)]
    vals = _evaluate([params.replace(checkpoint_interval=iv) for iv in grid],
                     n_replicas, objective, engine, max_steps)
    n_evals = len(grid)
    best = max(range(n_grid), key=lambda i: sign * vals[i])
    best_iv, best_val = grid[best], vals[best]

    # stage 2: golden-section inside the one-notch bracket around the
    # argmax (the cross-check contract: the true optimum of a unimodal
    # response through the argmax of its own grid lies in this bracket)
    a = grid[max(best - 1, 0)]
    b = grid[min(best + 1, n_grid - 1)]
    history: List[Tuple[float, float]] = []
    for _ in range(max(refine_iters, 0)):
        span = b - a
        if span <= max(1e-6, 1e-4 * best_iv):
            break
        x1 = b - _INVPHI * span
        x2 = a + _INVPHI * span
        v1, v2 = _evaluate(
            [params.replace(checkpoint_interval=x1),
             params.replace(checkpoint_interval=x2)],
            n_replicas, objective, engine, max_steps)
        n_evals += 2
        for x, v in ((x1, v1), (x2, v2)):
            if sign * v > sign * best_val:
                best_iv, best_val = x, v
        if sign * v1 < sign * v2:
            a = x1
        else:
            b = x2
        history.append((a, b))

    return CheckpointOptResult(
        interval=best_iv, objective=best_val, young_daly=yd,
        grid=tuple(grid), grid_objective=tuple(vals),
        history=tuple(history), n_evals=n_evals)


def optimize_knobs(params: Params,
                   axes: Dict[str, Sequence],
                   n_replicas: int = 256,
                   objective: str = "goodput",
                   maximize: bool = True,
                   engine: str = "auto",
                   max_sweeps: int = 4,
                   max_steps: Optional[int] = None) -> KnobOptResult:
    """Cyclic coordinate descent over discrete knob candidate sets.

    ``axes`` maps ``Params`` field names to their candidate values, e.g.
    ``{"warm_standbys": (0, 2, 4, 8), "spare_pool_size": (4, 8, 16),
    "checkpoint_interval": (60, 120, 240, 480)}``.  Each coordinate
    visit simulates every candidate row (with the other knobs held at
    their incumbents) in ONE batched call — structural knobs included,
    thanks to structure padding — and moves to the row argmax.  Sweeps
    repeat until a full cycle leaves every knob unchanged or
    ``max_sweeps`` is hit.

    Coordinate descent on a discrete grid converges to a point that is
    optimal along every axis (a Nash point of the grid); with common
    random numbers the trajectory is deterministic in ``params.seed``.
    """
    if not axes:
        raise ValueError("axes must name at least one Params field")
    for name, vals in axes.items():
        if not hasattr(params, name):
            raise ValueError(f"unknown Params field {name!r}")
        if len(list(vals)) == 0:
            raise ValueError(f"axis {name!r} has no candidate values")
    sign = 1.0 if maximize else -1.0
    current: Dict[str, float] = {n: getattr(params, n) for n in axes}
    best_val = -math.inf
    history: List[Tuple[str, Tuple[float, ...], Tuple[float, ...]]] = []
    n_evals = 0
    converged = False
    for _ in range(max(max_sweeps, 1)):
        moved = False
        for name, cand in axes.items():
            cand = list(cand)
            if current[name] not in cand:
                cand = [current[name]] + cand
            grid = [params.replace(**{**current, name: v}) for v in cand]
            vals = _evaluate(grid, n_replicas, objective, engine, max_steps)
            n_evals += len(grid)
            best = max(range(len(cand)), key=lambda i: sign * vals[i])
            history.append((name, tuple(float(c) for c in cand),
                            tuple(vals)))
            if cand[best] != current[name]:
                current[name] = cand[best]
                moved = True
            best_val = vals[best]
        if not moved:
            converged = True
            break
    return KnobOptResult(values=dict(current), objective=best_val,
                         history=tuple(history), n_evals=n_evals,
                         converged=converged)
