"""Trace-driven empirical hazards: piecewise-constant fitting + ingestion.

The paper's premise is tuning mitigation knobs against a cluster's
*measured* failure behavior, but measured MTTF / failure-category data
(Meta's "Revisiting Reliability", the Lablup 504-GPU report) matches no
closed-form family.  This module closes that gap with a generic
piecewise-constant hazard:

    h(t) = rates[i]   for  edges[i-1] <= t < edges[i]

with ``edges`` the interior breakpoints (length ``len(rates) - 1``; the
first segment starts at 0, the last extends to infinity).  Every
segment has a trivial *exact* majorant (its own rate), so the
vectorized engine's Ogata thinning needs no per-family math — see
:class:`repro.core.hazards.PiecewiseConstantSampler`.

Three layers live here:

* :class:`Empirical` — a mean-parameterized :class:`Distribution`
  (registered as ``"empirical"``) whose shape is the fitted segment
  profile and whose time axis is rescaled so the realized mean equals
  the configured one (``random_failure_rate`` / ``auto_repair_time``
  keep their usual meaning).  Pass the fitted profile verbatim by
  setting the rate to ``1 / fit.mean``.
* :func:`fit_piecewise_hazard` — Nelson–Aalen or binned-exposure rate
  estimation from raw duration samples, with quantile bin edges by
  default (equal event counts per segment).
* :func:`from_log` / :func:`from_mttf_table` — ingestion of simple
  timestamped CSV/JSONL event logs and published MTTF tables.

Example: fit an exponential-ish log and recover a flat hazard::

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> fit = fit_piecewise_hazard(rng.exponential(100.0, 4000), n_bins=4)
    >>> bool(np.all(np.abs(np.array(fit.rates) * 100.0 - 1.0) < 0.2))
    True
    >>> abs(fit.mean / 100.0 - 1.0) < 0.1
    True
"""

from __future__ import annotations

import csv
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .distributions import Distribution, register_distribution

__all__ = [
    "Empirical", "PiecewiseFit", "fit_piecewise_hazard", "from_log",
    "from_mttf_table", "segments_mean", "validate_segments",
    "pad_segments",
]


# ---------------------------------------------------------------------------
# segment math (numpy, host-side; the JAX mirror lives in hazards.py)
# ---------------------------------------------------------------------------

def _sanitize(edges: Sequence[float], rates: Sequence[float]):
    """Clamp a (possibly degenerate) segment spec into non-crashing form.

    Negative / non-finite rates clamp to 0 and out-of-order edges become
    zero-width segments — sampling from a degenerate spec must complete
    (the event engine runs it after ``hazard_kind`` refuses the fast
    path), it just is not fast-path eligible.
    """
    e = np.asarray(edges, dtype=float).ravel()
    r = np.asarray(rates, dtype=float).ravel()
    r = np.where(np.isfinite(r), r, 0.0)
    r = np.maximum(r, 0.0)
    lo = np.concatenate([[0.0], e])
    hi = np.concatenate([e, [np.inf]])
    w = np.maximum(hi - lo, 0.0)
    return lo, w, r


def segments_mean(edges: Sequence[float], rates: Sequence[float]) -> float:
    """Mean of the piecewise-constant-hazard distribution (∫ S(t) dt).

    Returns ``inf`` for defective specs (zero hazard on an infinite
    tail with survival mass left) and ``nan``-free output always.

    >>> segments_mean([], [0.01])            # one segment == exponential
    100.0
    >>> segments_mean([10.0], [0.0, 0.5])    # failure-free warmup
    12.0
    """
    lo, w, r = _sanitize(edges, rates)
    if r.size == 0:
        return math.inf
    mean, surv = 0.0, 1.0
    for w_i, r_i in zip(w, r):
        if surv <= 0.0:
            break
        if r_i > 0.0:
            if math.isinf(w_i):
                mean += surv / r_i
                surv = 0.0
            else:
                decay = math.exp(-r_i * w_i)
                mean += surv * (1.0 - decay) / r_i
                surv *= decay
        else:
            if math.isinf(w_i):
                return math.inf
            mean += surv * w_i
    return float(mean)


def validate_segments(edges: Sequence[float], rates: Sequence[float]) -> bool:
    """True iff (edges, rates) define a fast-path-eligible hazard.

    Requirements: at least one segment, ``len(edges) == len(rates) - 1``,
    strictly increasing positive finite edges, finite non-negative
    rates, and a *positive terminal rate* (defective hazards — survival
    plateaus — stay on the event engine so repair slots cannot wedge on
    an infinite quantile).

    >>> validate_segments([10.0, 20.0], [0.5, 0.1, 0.2])
    True
    >>> validate_segments([10.0, 10.0], [0.5, 0.1, 0.2])  # duplicate edge
    False
    >>> validate_segments([], [])                          # empty
    False
    """
    e = np.asarray(edges, dtype=float).ravel()
    r = np.asarray(rates, dtype=float).ravel()
    if r.size < 1 or e.size != r.size - 1:
        return False
    if not (np.all(np.isfinite(r)) and np.all(r >= 0.0)):
        return False
    if r[-1] <= 0.0:
        return False
    if e.size:
        if not np.all(np.isfinite(e)) or e[0] <= 0.0:
            return False
        if np.any(np.diff(e) <= 0.0):
            return False
    return True


def pad_segments(edges: np.ndarray, rates: np.ndarray, n_segments: int,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a valid segment spec to exactly ``n_segments`` segments.

    Padding repeats the terminal rate over synthetic trailing edges, so
    the hazard function is unchanged — this is how clocks with fewer
    fitted segments join a compiled group keyed on the (static) maximum
    segment count.

    >>> e, r = pad_segments(np.array([5.0]), np.array([2.0, 1.0]), 4)
    >>> e.tolist(), r.tolist()
    ([5.0, 6.0, 7.0], [2.0, 1.0, 1.0, 1.0])
    """
    e = np.asarray(edges, dtype=float).ravel()
    r = np.asarray(rates, dtype=float).ravel()
    if r.size >= n_segments:
        return e, r
    extra = n_segments - r.size
    last = e[-1] if e.size else 1.0
    new_edges = last + np.arange(1, extra + 1, dtype=float)
    tail = r[-1] if r.size else 0.0
    return (np.concatenate([e, new_edges]),
            np.concatenate([r, np.full(extra, tail)]))


def sample_piecewise(exp_draw: float, edges: Sequence[float],
                     rates: Sequence[float]) -> float:
    """Invert the cumulative hazard: smallest t with H(t) >= exp_draw.

    Exact (closed form per segment); tolerates degenerate specs by
    sanitizing first.  Returns ``inf`` when the total hazard is
    exhausted before the target (defective tail).

    >>> sample_piecewise(0.5, [], [0.01])    # exponential reduction
    50.0
    """
    lo, w, r = _sanitize(edges, rates)
    if r.size == 0:
        return math.inf
    seg_h = np.where(r > 0.0, r * w, 0.0)          # 0 * inf stays 0
    cs = np.cumsum(seg_h)
    c_prev = np.concatenate([[0.0], cs[:-1]])
    j = int(np.searchsorted(cs, exp_draw, side="right"))
    if j >= r.size:
        return math.inf
    return float(lo[j] + (exp_draw - c_prev[j]) / r[j])


# ---------------------------------------------------------------------------
# the registered distribution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Empirical(Distribution):
    """Piecewise-constant-hazard distribution fit from measured data.

    ``edges`` / ``rates`` describe the fitted *shape*; like every other
    registered family the instance is parameterized by its mean, so the
    time axis is rescaled by ``mean_value / shape_mean`` (a pure scale
    family: edges stretch, rates shrink).  Feeding the fit back
    verbatim therefore means setting the configured rate to
    ``1 / fit.mean``.

    Implements the ``hazard_segments()`` fast-path protocol: any
    registered distribution exposing it (returning absolute
    ``(edges, rates)`` arrays, or None for a never-firing clock) runs on
    the vectorized CTMC engine — this absorbs the old "user-registered
    distributions are event-engine-only" carve-out.

    >>> d = Empirical(mean_value=50.0, edges=(), rates=(0.02,))
    >>> d.mean, d.n_segments, d.shape_ok
    (50.0, 1, True)
    >>> e, r = d.hazard_segments()
    >>> r.tolist()                 # rescaled so the mean is 50, not 1/0.02
    [0.02]
    """

    mean_value: float
    edges: Tuple[float, ...] = ()
    rates: Tuple[float, ...] = ()

    @property
    def n_segments(self) -> int:
        return len(self.rates)

    @property
    def shape_mean(self) -> float:
        return segments_mean(self.edges, self.rates)

    @property
    def shape_ok(self) -> bool:
        """Structurally valid shape with a finite, positive mean."""
        if not validate_segments(self.edges, self.rates):
            return False
        m0 = self.shape_mean
        return math.isfinite(m0) and m0 > 0.0

    @property
    def _disabled(self) -> bool:
        return self.mean_value <= 0.0 or math.isinf(self.mean_value) \
            or math.isnan(self.mean_value)

    @property
    def time_scale(self) -> float:
        """Stretch factor mapping the fitted shape onto ``mean_value``."""
        if self._disabled:
            return 0.0
        m0 = self.shape_mean
        if not (math.isfinite(m0) and m0 > 0.0):
            return 1.0      # degenerate shape: use verbatim (event engine)
        return self.mean_value / m0

    def hazard_segments(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Absolute (edges, rates) after mean rescaling; None = disabled."""
        if self._disabled:
            return None
        s = self.time_scale
        return (np.asarray(self.edges, dtype=float) * s,
                np.asarray(self.rates, dtype=float) / s)

    def sample(self, rng: np.random.Generator) -> float:
        if self._disabled:
            return math.inf
        s = self.time_scale
        draw = rng.exponential()
        return s * sample_piecewise(draw, self.edges, self.rates)

    @property
    def mean(self) -> float:
        return float(self.mean_value)


def _make_empirical(mean, edges=(), rates=(), **_):
    return Empirical(
        mean_value=mean,
        edges=tuple(float(x) for x in np.asarray(edges, dtype=float).ravel()),
        rates=tuple(float(x) for x in np.asarray(rates, dtype=float).ravel()))


register_distribution("empirical", _make_empirical)


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PiecewiseFit:
    """A fitted piecewise-constant hazard, ready to drop into Params.

    ``Params(failure_distribution="empirical",
             random_failure_rate=fit.rate,
             distribution_kwargs=fit.distribution_kwargs)``
    reproduces the fitted hazard verbatim on either engine.
    """

    edges: Tuple[float, ...]
    rates: Tuple[float, ...]
    mean: float
    n_events: int
    method: str = "nelson-aalen"

    @property
    def rate(self) -> float:
        """Rate whose mean matches the fit (``1 / mean``)."""
        return 1.0 / self.mean if math.isfinite(self.mean) and self.mean > 0 \
            else 0.0

    @property
    def distribution_kwargs(self) -> Dict[str, List[float]]:
        return {"edges": list(self.edges), "rates": list(self.rates)}

    def to_json(self) -> Dict[str, object]:
        return {"edges": list(self.edges), "rates": list(self.rates),
                "mean": self.mean, "rate": self.rate,
                "n_events": self.n_events, "method": self.method}

    @classmethod
    def from_json(cls, blob: Dict[str, object]) -> "PiecewiseFit":
        return cls(edges=tuple(float(x) for x in blob["edges"]),
                   rates=tuple(float(x) for x in blob["rates"]),
                   mean=float(blob["mean"]),
                   n_events=int(blob.get("n_events", 0)),
                   method=str(blob.get("method", "nelson-aalen")))


def _auto_edges(durations: np.ndarray, n_bins: int) -> np.ndarray:
    """Quantile (equal-event-count) interior edges over the data range."""
    if n_bins < 2:
        return np.empty(0)
    qs = np.quantile(durations, np.linspace(0.0, 1.0, n_bins + 1)[1:-1])
    qs = qs[(qs > 0.0) & (qs < durations.max())]
    return np.unique(qs)


def fit_piecewise_hazard(durations: Sequence[float], n_bins: int = 8,
                         method: str = "nelson-aalen",
                         edges: Optional[Sequence[float]] = None,
                         censored: Sequence[float] = (),
                         ) -> PiecewiseFit:
    """Fit a piecewise-constant hazard to observed durations.

    ``method="nelson-aalen"``: the Nelson–Aalen cumulative-hazard
    estimator (jumps of 1/R(t) at each event, R the at-risk count,
    right-censored observations contributing risk only), binned into a
    rate per segment.  ``method="binned"``: events / total exposure per
    bin — the piecewise-exponential MLE.  Both agree on large samples.

    The terminal segment extends the last observed positive rate to
    infinity (standard practice; it also keeps the fitted mean finite,
    which the mean-parameterized :class:`Empirical` family requires).

    >>> fit = fit_piecewise_hazard([5.0, 7.0, 11.0, 23.0], n_bins=1)
    >>> len(fit.rates), len(fit.edges)
    (1, 0)
    """
    d = np.asarray(durations, dtype=float).ravel()
    d = d[np.isfinite(d) & (d > 0.0)]
    if d.size == 0:
        raise ValueError("fit_piecewise_hazard: no positive finite durations")
    c = np.asarray(censored, dtype=float).ravel()
    c = c[np.isfinite(c) & (c > 0.0)]
    if edges is None:
        e = _auto_edges(d, n_bins)
    else:
        e = np.unique(np.asarray(edges, dtype=float).ravel())
        e = e[(e > 0.0) & np.isfinite(e)]
    lo = np.concatenate([[0.0], e])
    hi = np.concatenate([e, [np.inf]])
    horizon = max(float(d.max()), float(c.max()) if c.size else 0.0)
    # effective width of the (half-open) terminal bin: up to the last
    # observation — beyond it there is no information, the terminal
    # rate is simply held constant
    width = np.minimum(hi, horizon) - lo
    width = np.maximum(width, 0.0)

    if method == "nelson-aalen":
        allobs = np.concatenate([d, c])
        # risk set at each event time (ties share the pre-tie risk set)
        risk = np.array([(allobs >= t).sum() for t in d], dtype=float)
        jumps = 1.0 / np.maximum(risk, 1.0)
        which = np.searchsorted(e, d, side="right")
        d_h = np.bincount(which, weights=jumps, minlength=lo.size)
        rates = np.divide(d_h, width, out=np.zeros_like(d_h),
                          where=width > 0.0)
    elif method == "binned":
        which = np.searchsorted(e, d, side="right")
        events = np.bincount(which, minlength=lo.size).astype(float)
        allobs = np.concatenate([d, c])
        exposure = np.maximum(
            np.minimum(allobs[:, None], hi[None, :]) - lo[None, :],
            0.0).sum(axis=0)
        rates = np.divide(events, exposure, out=np.zeros_like(events),
                          where=exposure > 0.0)
    else:
        raise ValueError(f"unknown fit method {method!r} "
                         "(known: nelson-aalen, binned)")

    # hold the last *positive* rate on the infinite tail so the fit is
    # non-defective (validate_segments requires a positive terminal rate)
    pos = np.nonzero(rates > 0.0)[0]
    tail = rates[pos[-1]] if pos.size else 1.0 / float(d.mean())
    if rates[-1] <= 0.0:
        rates[-1] = tail
    mean = segments_mean(e, rates)
    return PiecewiseFit(edges=tuple(float(x) for x in e),
                        rates=tuple(float(x) for x in rates),
                        mean=float(mean), n_events=int(d.size),
                        method=method)


# ---------------------------------------------------------------------------
# ingestion: timestamped event logs + published MTTF tables
# ---------------------------------------------------------------------------

_ENTITY_FIELDS = ("server", "host", "node", "entity", "id")


def _read_rows(path: str) -> List[Dict[str, object]]:
    ext = os.path.splitext(path)[1].lower()
    rows: List[Dict[str, object]] = []
    with open(path, "r", encoding="utf-8") as fh:
        if ext in (".jsonl", ".ndjson", ".json"):
            for line in fh:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
        else:
            rows.extend(dict(r) for r in csv.DictReader(fh))
    return rows


def from_log(path: str, event: Optional[str] = None,
             time_field: str = "time", duration_field: str = "duration",
             entity_field: Optional[str] = None) -> np.ndarray:
    """Read durations from a simple timestamped CSV or JSONL event log.

    Format: one record per event — a CSV with a header row, or one JSON
    object per line (``.jsonl`` / ``.ndjson``).  Recognized fields:

    * ``duration`` — used directly when present (e.g. repair times);
    * ``time`` — event timestamp in minutes; durations are the gaps
      between consecutive events, per entity when a ``server`` / ``host``
      / ``node`` / ``entity`` / ``id`` column exists (or pass
      ``entity_field``), otherwise over the merged stream;
    * ``event`` / ``kind`` — record type; pass ``event="failure"`` to
      keep only matching records before computing gaps.
    """
    rows = _read_rows(path)
    if event is not None:
        rows = [r for r in rows
                if str(r.get("event", r.get("kind", ""))) == event]
    if not rows:
        raise ValueError(f"from_log: no usable records in {path!r}"
                         + (f" for event={event!r}" if event else ""))

    def _num(value) -> Optional[float]:
        try:
            out = float(value)
        except (TypeError, ValueError):
            return None
        return out if math.isfinite(out) else None

    durs = [_num(r.get(duration_field)) for r in rows]
    durs = [x for x in durs if x is not None and x > 0.0]
    if durs:
        return np.asarray(durs, dtype=float)

    if entity_field is None:
        for cand in _ENTITY_FIELDS:
            if any(cand in r for r in rows):
                entity_field = cand
                break
    groups: Dict[object, List[float]] = {}
    for r in rows:
        t = _num(r.get(time_field))
        if t is None:
            continue
        key = r.get(entity_field) if entity_field else None
        groups.setdefault(key, []).append(t)
    gaps: List[float] = []
    for times in groups.values():
        times.sort()
        gaps.extend(b - a for a, b in zip(times, times[1:]) if b > a)
    if not gaps:
        raise ValueError(f"from_log: {path!r} yields no positive durations "
                         f"(need a {duration_field!r} column or >= 2 "
                         f"timestamps per entity)")
    return np.asarray(gaps, dtype=float)


def from_mttf_table(ages: Sequence[float], mttfs: Sequence[float],
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Convert a published age-bucketed MTTF table to (edges, rates).

    ``ages`` are bucket start times (first must be 0, strictly
    increasing); ``mttfs`` the per-bucket mean time to failure.  The
    hazard in each bucket is ``1 / mttf``.

    >>> e, r = from_mttf_table([0.0, 100.0], [50.0, 200.0])
    >>> e.tolist(), r.tolist()
    ([100.0], [0.02, 0.005])
    """
    a = np.asarray(ages, dtype=float).ravel()
    m = np.asarray(mttfs, dtype=float).ravel()
    if a.size != m.size or a.size == 0:
        raise ValueError("from_mttf_table: ages and mttfs must be equal, "
                         "non-empty lengths")
    if a[0] != 0.0 or (a.size > 1 and np.any(np.diff(a) <= 0.0)):
        raise ValueError("from_mttf_table: ages must start at 0 and be "
                         "strictly increasing")
    if np.any(~np.isfinite(m)) or np.any(m <= 0.0):
        raise ValueError("from_mttf_table: mttfs must be positive and finite")
    return a[1:], 1.0 / m
