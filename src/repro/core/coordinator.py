"""Coordinator module: the job-execution loop and failure broadcast.

Paper §III-C module (2): "Coordinator: ... When a server fails, the
coordinator is notified. In turn, it informs the other servers in the group
of the failure, and asks them to stop executing the job (and initiate a
fast recovery)."

In the analytical-failure formulation (see server.py), "informing all other
servers" is the act of ending the current compute phase: all failure clocks
stop, progress since the phase start is banked (minus optional checkpoint
rollback loss), the failed server is diagnosed and dispatched to repair, a
replacement is acquired through the Scheduler waterfall, the recovery cost
is paid, and a fresh phase begins (restarting every failure clock — the
paper's "failure process starts when a job is started on a server").
"""

from __future__ import annotations

import math
from typing import Generator, List, Optional

import numpy as np

from .engine import Environment, Interrupt
from .faultdomains import Injection, ShockInjector
from .metrics import RunResult
from .params import Params
from .repair import RepairShop
from .scheduler import Scheduler
from .server import FailureSampler, Server, ServerState


class Coordinator:
    def __init__(self, env: Environment, params: Params,
                 rng: np.random.Generator, metrics: RunResult,
                 scheduler: Scheduler, repair_shop: RepairShop,
                 sampler: FailureSampler):
        self.env = env
        self.params = params
        self.rng = rng
        self.metrics = metrics
        self.scheduler = scheduler
        self.repair_shop = repair_shop
        self.sampler = sampler
        # running servers partitioned by health class for O(1) sampling;
        # _pos maps sid -> (insertion-time bad flag, index) for swap-remove
        self.running_good: List[Server] = []
        self.running_bad: List[Server] = []
        self._pos: dict = {}
        self.remaining_work: float = params.job_length
        #: fault-domain injection stream (set by ClusterSimulation when
        #: Params.fault_domains / Params.campaign are configured)
        self.injector: Optional[ShockInjector] = None
        self._job_proc = None           # Process handle for interrupts
        self._deficit = 0               # running servers owed after shocks
        self._stalling = False          # inside the group-stall loop
        self._pending_shock_wait = 0.0  # planned post-shock restart wait

    # -- helpers -------------------------------------------------------------
    def _add_running(self, server: Server) -> None:
        lst = self.running_bad if server.is_bad else self.running_good
        self._pos[server.sid] = (server.is_bad, len(lst))
        lst.append(server)

    def _remove_running(self, server: Server) -> None:
        flag, idx = self._pos.pop(server.sid)
        lst = self.running_bad if flag else self.running_good
        last = lst.pop()
        if last is not server:
            lst[idx] = last
            self._pos[last.sid] = (flag, idx)

    def rebuild_running_partition(self) -> None:
        """Called after a bad-set regeneration re-flags servers."""
        servers = self.running_good + self.running_bad
        self.running_good = []
        self.running_bad = []
        self._pos.clear()
        for s in servers:
            self._add_running(s)

    def _diagnose(self, failed: Server) -> Optional[Server]:
        """Return the server to send to repair (None = undiagnosed)."""
        p = self.params
        if self.rng.random() >= p.diagnosis_probability:
            self.metrics.n_undiagnosed += 1
            return None
        if p.diagnosis_uncertainty > 0 and self.rng.random() < p.diagnosis_uncertainty:
            # wrong server fingered: a random *other* running server
            pool = self.running_good + self.running_bad
            others = [s for s in pool if s is not failed]
            if others:
                self.metrics.n_misdiagnosed += 1
                return others[int(self.rng.integers(len(others)))]
        return failed

    def _bank_progress(self, compute: float, ckpt_work: float) -> None:
        """Credit the ended phase's compute, minus checkpoint rollback.

        ``compute`` is the phase's total useful-compute time (the run
        record, gross of rollback, excluding checkpoint-write wall time);
        ``ckpt_work`` is the part past the last durable checkpoint, which
        is what a failure rolls back.  ``checkpoint_interval == 0`` keeps
        the historical model where nothing is ever lost.
        """
        p = self.params
        lost = ckpt_work if p.checkpoint_interval > 0 else 0.0
        self.metrics.lost_work += lost
        self.remaining_work -= (compute - lost)
        self.metrics.useful_work += (compute - lost)
        self.metrics.run_durations.append(compute)

    # -- the job ------------------------------------------------------------------
    def run_job(self) -> Generator:
        if self.injector is not None:
            return (yield from self._run_job_injected())
        p, m, env = self.params, self.metrics, self.env

        running = yield from self.scheduler.initial_allocation()
        for server in running:
            self._add_running(server)

        while self.remaining_work > 1e-9:
            if env.now >= p.max_sim_time:
                m.timed_out = True
                break
            if p.standbys_can_fail and self.scheduler.standbys:
                standby_good = [s for s in self.scheduler.standbys if not s.is_bad]
                standby_bad = [s for s in self.scheduler.standbys if s.is_bad]
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good + standby_good,
                    self.running_bad + standby_bad)
            else:
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good, self.running_bad)

            # ---- checkpoint segment loop ---------------------------------
            # the phase runs in segments bounded by the next checkpoint
            # write; the failure clock (``ttf``) is consumed by compute
            # time only — it is frozen, not restarted, while a paid write
            # runs.  Tie order matches the CTMC residual race: completion
            # beats a same-instant write (no final write on a finished
            # job) and a failure beats a same-instant write.
            compute = 0.0            # phase compute (the run record)
            ckpt_work = 0.0          # compute since the last durable write
            left = self.remaining_work
            completed = False
            while True:
                to_ckpt = (p.checkpoint_interval - ckpt_work
                           if p.checkpoint_interval > 0 else math.inf)
                if left <= ttf and left <= to_ckpt:
                    yield env.timeout(left)
                    compute += left
                    m.run_durations.append(compute)
                    m.useful_work += compute
                    self.remaining_work = 0.0
                    completed = True
                    break
                if ttf <= to_ckpt:
                    yield env.timeout(ttf)
                    compute += ttf
                    ckpt_work += ttf
                    break
                # checkpoint write: the checkpoint is durable from write
                # start; the write cost is pure wall-clock overhead
                yield env.timeout(to_ckpt)
                compute += to_ckpt
                left -= to_ckpt
                ttf -= to_ckpt
                ckpt_work = 0.0
                if p.checkpoint_cost > 0:
                    yield env.timeout(p.checkpoint_cost)
                    m.checkpoint_overhead += p.checkpoint_cost
            if completed:
                break

            # ---- failure: coordinator stops the group --------------------
            m.n_failures += 1
            if is_systematic:
                m.n_systematic_failures += 1
            else:
                m.n_random_failures += 1
            assert failed is not None
            failed.record_failure(env.now, is_systematic)
            self._bank_progress(compute, ckpt_work)

            # a failed standby (standbys_can_fail) just leaves the standby
            # list; the job itself does not restart
            if failed.state is ServerState.STANDBY:
                self.scheduler.standbys.remove(failed)
                self.repair_shop.submit(failed)
                continue

            # downtime clock for the recovery/waiting distribution
            # channels: failure instant -> compute restart (ETTR), with
            # the replacement-acquisition part recorded separately
            t_fail = env.now
            target = self._diagnose(failed)
            if target is not None:
                self._remove_running(target)
                self.repair_shop.submit(target)
                replacement = yield from self.scheduler.acquire_replacement()
                self._add_running(replacement)
            m.waiting_durations.append(env.now - t_fail)

            # checkpoint reload + restart
            yield env.timeout(p.recovery_time)
            m.recovery_overhead += p.recovery_time
            m.recovery_durations.append(env.now - t_fail)

        m.total_time = env.now
        self.scheduler.release_all(self.running_good + self.running_bad)
        self.running_good.clear()
        self.running_bad.clear()
        return m

    # -- fault-domain injections (see repro.core.faultdomains) ----------------
    def injection_loop(self) -> Generator:
        """Drive the merged shock/campaign stream as its own process.

        Fires each injection at its exact time; injections that kill
        running servers interrupt the job process (unless it is already
        group-stalled, where growing the deficit is all that's needed).
        Created *before* the job process so a same-instant tie resolves
        injection-first, matching the CTMC race where the campaign
        residual is the first deterministic column.
        """
        assert self.injector is not None
        while True:
            t_next = self.injector.peek()
            if not math.isfinite(t_next) or t_next >= self.params.max_sim_time:
                return
            yield self.env.timeout(max(t_next - self.env.now, 0.0))
            self._apply_injection(self.injector.pop())

    def _apply_injection(self, inj: Injection) -> None:
        """Zero-time bookkeeping for one injection.

        Kills are resolved per compartment exactly as the CTMC step
        resolves them in expectation: free/standby victims go straight
        to repair, in-shop victims re-break, running victims trigger a
        group restart whose replacements are drawn immediately (the
        restart *wait* is charged by the job process afterwards).
        """
        p, m = self.params, self.metrics
        if inj.kind == "maint_start":
            self.repair_shop.pause()
            m.n_campaign_events += 1
            return
        if inj.kind == "maint_end":
            self.repair_shop.resume()
            m.n_campaign_events += 1
            return
        if inj.kind == "shock":
            m.n_domain_shocks += 1
            if m.domain_shocks:
                m.domain_shocks[inj.domain] += 1
        else:  # campaign kill
            m.n_campaign_events += 1

        fleet = self.scheduler.pools.fleet
        killed_running: List[Server] = []
        n_killed = 0
        for sid in inj.members:
            server = fleet.servers[sid]
            state = server.state
            if state is ServerState.RUNNING and sid in self._pos:
                killed_running.append(server)
            elif (state is ServerState.STANDBY
                    and server in self.scheduler.standbys):
                self.scheduler.standbys.remove(server)
                self.repair_shop.submit(server)
                n_killed += 1
            elif state in (ServerState.WORKING_FREE, ServerState.SPARE):
                # a popped-but-not-joined (in-flight) server still carries
                # its pool state but is in no free list; it survives
                if self.scheduler.pools.remove(server):
                    self.repair_shop.submit(server)
                    n_killed += 1
            elif state in (ServerState.REPAIR_AUTO, ServerState.REPAIR_MANUAL):
                self.repair_shop.rebreak(server)
                n_killed += 1
            # RETIRED servers are beyond further harm

        for server in killed_running:
            self._remove_running(server)
            self.repair_shop.submit(server)
        n_killed += len(killed_running)
        m.n_shock_killed += n_killed
        if not killed_running:
            return

        # group restart: replacements join now (the CTMC race resolves
        # the moves at the shock step); the job process serves the wait
        repl, t_fw, t_fs, shortfall = self.scheduler.draw_replacements(
            len(killed_running))
        for server in repl:
            self._add_running(server)
        self._deficit += shortfall
        wait = 0.0
        if t_fs:
            wait = (p.waiting_time + p.preemption_cost
                    + p.host_selection_time)
        elif t_fw:
            wait = p.host_selection_time
        self._pending_shock_wait = wait
        if self._stalling:
            return  # already group-stalled; the deficit grew, that's all
        if self._job_proc is not None and self._job_proc.is_alive:
            self._job_proc.interrupt("shock")

    def _shock_recover(self, t0: float) -> Generator:
        """Serve the group restart after a shock/kill hit running servers.

        Replacements were already drawn by :meth:`_apply_injection`; this
        charges the one-group restart wait — host selection if any pool
        draw, waiting + preemption if any spare draw — plus recovery, or
        stalls until repair returns refill the deficit (then recovery
        only, matching the CTMC ``to_stalled``/unstall path).  Downtime
        is recorded at the resolve instant with its planned value, the
        CTMC engine's record-at-resolve convention.
        """
        p, m, env = self.params, self.metrics, self.env
        if self._deficit > 0:
            self._stalling = True
            stall_start = env.now
            try:
                while self._deficit > 0:
                    server = yield from self.scheduler.group_stall_acquire()
                    self._add_running(server)
                    self._deficit -= 1
            finally:
                self._stalling = False
            m.stall_time += env.now - stall_start
            wait = env.now - t0
            serve = p.recovery_time
        else:
            wait = self._pending_shock_wait
            serve = wait + p.recovery_time
        m.waiting_durations.append(wait)
        m.recovery_durations.append(wait + p.recovery_time)
        m.recovery_overhead += p.recovery_time
        try:
            yield env.timeout(serve)
        except Interrupt:
            # another shock replaced the pending restart (CTMC: the
            # OVERHEAD timer is overwritten by the new shock_timer)
            yield from self._shock_recover(env.now)

    def _run_job_injected(self) -> Generator:
        """:meth:`run_job` variant racing the shock/campaign stream.

        A run whose injector never fires executes exactly the statements
        of the plain loop (the zero-rate / empty-campaign reduction
        tests require bit-identical metrics); injections arrive as
        ``Interrupt("shock")`` thrown by :meth:`injection_loop`.
        """
        p, m, env = self.params, self.metrics, self.env

        running = yield from self.scheduler.initial_allocation()
        for server in running:
            self._add_running(server)

        while self.remaining_work > 1e-9:
            if env.now >= p.max_sim_time:
                m.timed_out = True
                break
            if p.standbys_can_fail and self.scheduler.standbys:
                standby_good = [s for s in self.scheduler.standbys
                                if not s.is_bad]
                standby_bad = [s for s in self.scheduler.standbys
                               if s.is_bad]
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good + standby_good,
                    self.running_bad + standby_bad)
            else:
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good, self.running_bad)

            # checkpoint segment loop (see run_job), racing the injector:
            # an Interrupt mid-compute rolls back to the last durable
            # checkpoint; an Interrupt mid-WRITE loses nothing (durable
            # from write start) and charges only the partial write wall
            # time actually elapsed — the CTMC engine's in_ckpt timing.
            compute = 0.0
            ckpt_work = 0.0
            left = self.remaining_work
            completed = False
            interrupted = False
            while True:
                to_ckpt = (p.checkpoint_interval - ckpt_work
                           if p.checkpoint_interval > 0 else math.inf)
                seg_start = env.now
                write_start = None
                try:
                    if left <= ttf and left <= to_ckpt:
                        yield env.timeout(left)
                        compute += left
                        m.run_durations.append(compute)
                        m.useful_work += compute
                        self.remaining_work = 0.0
                        completed = True
                        break
                    if ttf <= to_ckpt:
                        yield env.timeout(ttf)
                        compute += ttf
                        ckpt_work += ttf
                        break
                    yield env.timeout(to_ckpt)
                    compute += to_ckpt
                    left -= to_ckpt
                    ttf -= to_ckpt
                    ckpt_work = 0.0
                    if p.checkpoint_cost > 0:
                        write_start = env.now
                        yield env.timeout(p.checkpoint_cost)
                        m.checkpoint_overhead += p.checkpoint_cost
                except Interrupt:
                    # shock/kill hit the group: the run interval ends
                    # here (banked like a failure), then group restart
                    if write_start is not None:
                        m.checkpoint_overhead += env.now - write_start
                    else:
                        elapsed = env.now - seg_start
                        compute += elapsed
                        ckpt_work += elapsed
                    self._bank_progress(compute, ckpt_work)
                    yield from self._shock_recover(env.now)
                    interrupted = True
                    break
            if completed:
                break
            if interrupted:
                continue

            m.n_failures += 1
            if is_systematic:
                m.n_systematic_failures += 1
            else:
                m.n_random_failures += 1
            assert failed is not None
            failed.record_failure(env.now, is_systematic)
            self._bank_progress(compute, ckpt_work)

            if failed.state is ServerState.STANDBY:
                self.scheduler.standbys.remove(failed)
                self.repair_shop.submit(failed)
                continue

            t_fail = env.now
            target = self._diagnose(failed)
            try:
                if target is not None:
                    self._remove_running(target)
                    self.repair_shop.submit(target)
                    replacement = yield from \
                        self.scheduler.acquire_replacement()
                    self._add_running(replacement)
                m.waiting_durations.append(env.now - t_fail)
                yield env.timeout(p.recovery_time)
                m.recovery_overhead += p.recovery_time
                m.recovery_durations.append(env.now - t_fail)
            except Interrupt:
                # shock mid-recovery: the CTMC race overwrites the
                # pending timer with the shock restart — close this
                # failure's books at the shock instant and restart
                inflight = self.scheduler.take_inflight()
                if inflight is not None:
                    self._add_running(inflight)
                # re-anchor the deficit on the true shortfall: an
                # interrupted stall/acquisition leaves the group short
                # beyond the shock's own tally
                self._deficit = max(0, p.job_size - len(self.running_good)
                                    - len(self.running_bad))
                m.waiting_durations.append(env.now - t_fail)
                m.recovery_overhead += p.recovery_time
                m.recovery_durations.append(env.now - t_fail)
                yield from self._shock_recover(env.now)

        m.total_time = env.now
        self.scheduler.release_all(self.running_good + self.running_bad)
        self.running_good.clear()
        self.running_bad.clear()
        return m
