"""Coordinator module: the job-execution loop and failure broadcast.

Paper §III-C module (2): "Coordinator: ... When a server fails, the
coordinator is notified. In turn, it informs the other servers in the group
of the failure, and asks them to stop executing the job (and initiate a
fast recovery)."

In the analytical-failure formulation (see server.py), "informing all other
servers" is the act of ending the current compute phase: all failure clocks
stop, progress since the phase start is banked (minus optional checkpoint
rollback loss), the failed server is diagnosed and dispatched to repair, a
replacement is acquired through the Scheduler waterfall, the recovery cost
is paid, and a fresh phase begins (restarting every failure clock — the
paper's "failure process starts when a job is started on a server").
"""

from __future__ import annotations

import math
from typing import Generator, List, Optional

import numpy as np

from .engine import Environment
from .metrics import RunResult
from .params import Params
from .repair import RepairShop
from .scheduler import Scheduler
from .server import FailureSampler, Server, ServerState


class Coordinator:
    def __init__(self, env: Environment, params: Params,
                 rng: np.random.Generator, metrics: RunResult,
                 scheduler: Scheduler, repair_shop: RepairShop,
                 sampler: FailureSampler):
        self.env = env
        self.params = params
        self.rng = rng
        self.metrics = metrics
        self.scheduler = scheduler
        self.repair_shop = repair_shop
        self.sampler = sampler
        # running servers partitioned by health class for O(1) sampling;
        # _pos maps sid -> (insertion-time bad flag, index) for swap-remove
        self.running_good: List[Server] = []
        self.running_bad: List[Server] = []
        self._pos: dict = {}
        self.remaining_work: float = params.job_length

    # -- helpers -------------------------------------------------------------
    def _add_running(self, server: Server) -> None:
        lst = self.running_bad if server.is_bad else self.running_good
        self._pos[server.sid] = (server.is_bad, len(lst))
        lst.append(server)

    def _remove_running(self, server: Server) -> None:
        flag, idx = self._pos.pop(server.sid)
        lst = self.running_bad if flag else self.running_good
        last = lst.pop()
        if last is not server:
            lst[idx] = last
            self._pos[last.sid] = (flag, idx)

    def rebuild_running_partition(self) -> None:
        """Called after a bad-set regeneration re-flags servers."""
        servers = self.running_good + self.running_bad
        self.running_good = []
        self.running_bad = []
        self._pos.clear()
        for s in servers:
            self._add_running(s)

    def _diagnose(self, failed: Server) -> Optional[Server]:
        """Return the server to send to repair (None = undiagnosed)."""
        p = self.params
        if self.rng.random() >= p.diagnosis_probability:
            self.metrics.n_undiagnosed += 1
            return None
        if p.diagnosis_uncertainty > 0 and self.rng.random() < p.diagnosis_uncertainty:
            # wrong server fingered: a random *other* running server
            pool = self.running_good + self.running_bad
            others = [s for s in pool if s is not failed]
            if others:
                self.metrics.n_misdiagnosed += 1
                return others[int(self.rng.integers(len(others)))]
        return failed

    def _bank_progress(self, phase_start: float) -> None:
        """Credit work done in the ended phase, minus checkpoint rollback."""
        p = self.params
        progress = self.env.now - phase_start
        lost = 0.0
        if p.checkpoint_interval > 0:
            # work past the last completed checkpoint is rolled back
            lost = math.fmod(progress, p.checkpoint_interval)
        self.metrics.lost_work += lost
        self.remaining_work -= (progress - lost)
        self.metrics.useful_work += (progress - lost)
        self.metrics.run_durations.append(progress)

    # -- the job ------------------------------------------------------------------
    def run_job(self) -> Generator:
        p, m, env = self.params, self.metrics, self.env

        running = yield from self.scheduler.initial_allocation()
        for server in running:
            self._add_running(server)

        while self.remaining_work > 1e-9:
            if env.now >= p.max_sim_time:
                m.timed_out = True
                break
            phase_start = env.now
            if p.standbys_can_fail and self.scheduler.standbys:
                standby_good = [s for s in self.scheduler.standbys if not s.is_bad]
                standby_bad = [s for s in self.scheduler.standbys if s.is_bad]
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good + standby_good,
                    self.running_bad + standby_bad)
            else:
                ttf, failed, is_systematic = self.sampler.sample_first_failure(
                    self.running_good, self.running_bad)

            if ttf >= self.remaining_work:
                # phase runs to completion
                yield env.timeout(self.remaining_work)
                m.run_durations.append(self.remaining_work)
                m.useful_work += self.remaining_work
                self.remaining_work = 0.0
                break

            yield env.timeout(ttf)

            # ---- failure: coordinator stops the group --------------------
            m.n_failures += 1
            if is_systematic:
                m.n_systematic_failures += 1
            else:
                m.n_random_failures += 1
            assert failed is not None
            failed.record_failure(env.now, is_systematic)
            self._bank_progress(phase_start)

            # a failed standby (standbys_can_fail) just leaves the standby
            # list; the job itself does not restart
            if failed.state is ServerState.STANDBY:
                self.scheduler.standbys.remove(failed)
                self.repair_shop.submit(failed)
                continue

            # downtime clock for the recovery/waiting distribution
            # channels: failure instant -> compute restart (ETTR), with
            # the replacement-acquisition part recorded separately
            t_fail = env.now
            target = self._diagnose(failed)
            if target is not None:
                self._remove_running(target)
                self.repair_shop.submit(target)
                replacement = yield from self.scheduler.acquire_replacement()
                self._add_running(replacement)
            m.waiting_durations.append(env.now - t_fail)

            # checkpoint reload + restart
            yield env.timeout(p.recovery_time)
            m.recovery_overhead += p.recovery_time
            m.recovery_durations.append(env.now - t_fail)

        m.total_time = env.now
        self.scheduler.release_all(self.running_good + self.running_bad)
        self.running_good.clear()
        self.running_bad.clear()
        return m
