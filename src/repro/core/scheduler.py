"""Scheduler module: host selection, warm standbys, replacements, stalls.

Paper §III-C module (3): "Assigns servers to the job from a list of chosen
servers (host selection), and starts the job on the servers. It also keeps
track of the remaining length of the job and failed servers."

Replacement waterfall on a failure (paper §II-B):

  1. warm standby        -> swap-in, NO host selection, recovery only
  2. working-pool free   -> host_selection_time, then recovery
  3. spare pool          -> waiting_time (preempt other job) +
                            host_selection_time, then recovery
  4. nothing anywhere    -> STALL until a repaired server returns

Repaired servers return to *this* job (as standbys) if it still wants them
— "a server is returned to the job after repair if it was originally
assigned to the same job before it failed, without going through host
selection again" — otherwise to their origin pool.
"""

from __future__ import annotations

from typing import Generator, List, Optional, Set

from .engine import Environment, Event, Interrupt
from .metrics import RunResult
from .params import Params
from .pool import PoolManager
from .server import Server, ServerState


class Scheduler:
    def __init__(self, env: Environment, params: Params, pools: PoolManager,
                 metrics: RunResult):
        self.env = env
        self.params = params
        self.pools = pools
        self.metrics = metrics
        self.standbys: List[Server] = []
        #: servers the job has ever claimed and not released (for returns)
        self.job_members: Set[int] = set()
        self.job_active = False
        self._stall_event: Optional[Event] = None
        self._stall_server: Optional[Server] = None
        #: server popped from a pool by an in-flight acquisition (between
        #: the pop and the post-wait join) — a fault-domain interrupt
        #: mid-acquisition recovers it via :meth:`take_inflight` instead
        #: of leaking it
        self._inflight: Optional[Server] = None

    # -- initial allocation (t=0 host selection) ----------------------------
    def initial_allocation(self) -> Generator:
        """Select job_size + warm_standbys hosts from the working pool."""
        p = self.params
        yield self.env.timeout(p.host_selection_time)
        running: List[Server] = []
        for _ in range(p.job_size):
            server = self.pools.pop_working()
            if server is None:  # validate() precludes this at t=0
                raise RuntimeError("working pool cannot host the job")
            server.state = ServerState.RUNNING
            self.job_members.add(server.sid)
            running.append(server)
        for _ in range(p.warm_standbys):
            server = self.pools.pop_working()
            if server is None:
                break  # fewer standbys than requested; job still starts
            server.state = ServerState.STANDBY
            self.job_members.add(server.sid)
            self.standbys.append(server)
        self.job_active = True
        return running

    # -- replacement waterfall ------------------------------------------------
    def acquire_replacement(self) -> Generator:
        """Yield timeouts per the waterfall; returns the acquired Server."""
        p, m = self.params, self.metrics

        # 1. warm standby: immediate, no host selection.
        if self.standbys:
            server = self.standbys.pop()
            m.n_standby_swaps += 1
            server.state = ServerState.RUNNING
            return server

        # 2. working pool: pay a host-selection round.
        server = self.pools.pop_working()
        if server is not None:
            self._inflight = server
            yield self.env.timeout(p.host_selection_time)
            self._inflight = None
            m.n_host_selections += 1
            server.state = ServerState.RUNNING
            self.job_members.add(server.sid)
            return server

        # 3. spare pool: preempt another job, then host selection.
        server = self.pools.pop_spare()
        if server is not None:
            self._inflight = server
            yield self.env.timeout(p.waiting_time + p.preemption_cost)
            m.n_preemptions += 1
            yield self.env.timeout(p.host_selection_time)
            self._inflight = None
            m.n_host_selections += 1
            server.state = ServerState.RUNNING
            self.job_members.add(server.sid)
            return server

        # 4. stall: wait for any server to come back from repair.
        stall_start = self.env.now
        server = yield from self._stall_until_available()
        m.stall_time += self.env.now - stall_start
        # Returned servers rejoin without host selection if they were job
        # members; fresh pool servers pay host selection.
        if server.sid not in self.job_members:
            self._inflight = server
            yield self.env.timeout(p.host_selection_time)
            self._inflight = None
            m.n_host_selections += 1
            self.job_members.add(server.sid)
        server.state = ServerState.RUNNING
        return server

    def take_inflight(self) -> Optional[Server]:
        """Recover the server an interrupted acquisition had in flight.

        The CTMC race joins replacements to the run set at the failure
        step itself, so a shock arriving mid-acquisition must not lose
        the popped server: the coordinator claims it here and counts it
        as joined.
        """
        server, self._inflight = self._inflight, None
        if server is not None:
            server.state = ServerState.RUNNING
            self.job_members.add(server.sid)
        return server

    # -- fault-domain group restarts (see repro.core.faultdomains) ----------
    def draw_replacements(self, n: int):
        """Zero-time bulk waterfall draw for a domain-shock group restart.

        Mirrors the CTMC race, which resolves all replacement *moves* at
        the shock step and charges the time cost as one group restart:
        returns ``(servers, n_working, n_spare, shortfall)`` with the
        per-server counters (standby swaps, host selections, preemptions)
        already recorded.  The caller charges the restart wait.
        """
        m = self.metrics
        out: List[Server] = []
        t_sb = t_fw = t_fs = 0
        for _ in range(n):
            if self.standbys:
                server = self.standbys.pop()
                t_sb += 1
            else:
                server = self.pools.pop_working()
                if server is not None:
                    t_fw += 1
                else:
                    server = self.pools.pop_spare()
                    if server is not None:
                        t_fs += 1
                    else:
                        break
            server.state = ServerState.RUNNING
            self.job_members.add(server.sid)
            out.append(server)
        m.n_standby_swaps += t_sb
        m.n_host_selections += t_fw + t_fs
        m.n_preemptions += t_fs
        return out, t_fw, t_fs, n - len(out)

    def group_stall_acquire(self) -> Generator:
        """One deficit-refill acquisition for a shocked group.

        Matches the CTMC ``to_stalled`` join: a returning server joins
        the run set directly with no host-selection surcharge (the
        group pays a single recovery after the deficit clears).
        """
        server = yield from self._stall_until_available()
        server.state = ServerState.RUNNING
        self.job_members.add(server.sid)
        return server

    def _stall_until_available(self) -> Generator:
        """Block until on_server_return / pool release hands us a server."""
        self._stall_event = self.env.event()
        self._stall_server = None

        def _watcher(server: Server) -> None:
            # a release to a pool while we starve: grab it
            if self._stall_event is not None and not self._stall_event.triggered:
                got = (self.pools.pop_working() or self.pools.pop_spare())
                if got is not None:
                    self._stall_server = got
                    self._stall_event.succeed(got)

        self.pools.add_release_watcher(_watcher)
        try:
            # A direct hand-off via on_server_return may already have fired.
            yield self._stall_event
            assert self._stall_server is not None
            return self._stall_server
        except Interrupt:
            # a fault-domain injection interrupted the stall: a hand-off
            # may have landed between succeed() and our resumption —
            # park it in _inflight so the coordinator can claim it
            if self._stall_server is not None:
                self._inflight = self._stall_server
            raise
        finally:
            self.pools.remove_release_watcher(_watcher)
            self._stall_event = None
            self._stall_server = None

    #: when a fault-domain scenario is active, repaired servers backfill
    #: the job's standby complement first *regardless of membership* —
    #: after a correlated outage the degraded job is restored before the
    #: pools are (and the CTMC engine's return lane, which carries no
    #: membership, has exactly these semantics).  False (default) keeps
    #: the paper rule: only original job members return to the job.
    standby_refill_any = False

    # -- repaired-server returns --------------------------------------------
    def on_server_return(self, server: Server) -> None:
        """RepairShop callback: decide job-return vs pool-return."""
        # starved job gets the server immediately (direct hand-off)
        if self._stall_event is not None and not self._stall_event.triggered:
            self._stall_server = server
            self._stall_event.succeed(server)
            return
        if (self.job_active
                and (server.sid in self.job_members or self.standby_refill_any)
                and len(self.standbys) < self.params.warm_standbys):
            server.state = ServerState.STANDBY
            self.job_members.add(server.sid)
            self.standbys.append(server)
            return
        # no longer needed by the job
        self.job_members.discard(server.sid)
        self.pools.push(server)

    def on_server_retired(self, server: Server) -> None:
        self.job_members.discard(server.sid)
        self.pools.retire(server)

    # -- teardown ----------------------------------------------------------------
    def release_all(self, running: List[Server]) -> None:
        """Job finished: release running servers and standbys to pools."""
        self.job_active = False
        for server in running + self.standbys:
            self.job_members.discard(server.sid)
            self.pools.push(server)
        self.standbys.clear()
