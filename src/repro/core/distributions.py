"""Sampling distributions for failure inter-arrival and repair durations.

The paper assumes exponential distributions by default (assumption 2) but
states AIReSim "also supports the Lognormal and Weibull distributions" and
"can be extended with user-specified distributions".  Every distribution here
is parameterized by its *mean* so that swapping distributions holds the mean
occurrence rate fixed — the natural A/B comparison for reliability sweeps.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np


class Distribution:
    """Base: a sampler of non-negative durations with a defined mean."""

    def sample(self, rng: np.random.Generator) -> float:
        raise NotImplementedError

    @property
    def mean(self) -> float:
        raise NotImplementedError

    def is_memoryless(self) -> bool:
        return False


@dataclass(frozen=True)
class Exponential(Distribution):
    """Exponential with the given *rate* (events per unit time)."""

    rate: float

    def sample(self, rng: np.random.Generator) -> float:
        if self.rate <= 0.0:
            return math.inf
        return float(rng.exponential(1.0 / self.rate))

    @property
    def mean(self) -> float:
        return math.inf if self.rate <= 0 else 1.0 / self.rate

    def is_memoryless(self) -> bool:
        return True


@dataclass(frozen=True)
class Deterministic(Distribution):
    """Fixed duration — used by unit tests for exact-time assertions."""

    value: float

    def sample(self, rng: np.random.Generator) -> float:
        return float(self.value)

    @property
    def mean(self) -> float:
        return float(self.value)


@dataclass(frozen=True)
class LogNormal(Distribution):
    """LogNormal parameterized by its mean and the log-space sigma."""

    mean_value: float
    sigma: float = 1.0

    @property
    def mu(self) -> float:
        """Log-space location for the configured mean (-inf if disabled)."""
        if self.mean_value <= 0 or math.isinf(self.mean_value):
            return -math.inf
        return math.log(self.mean_value) - 0.5 * self.sigma ** 2

    @property
    def scale(self) -> float:
        """``exp(mu)`` — the median; 0 for a disabled (infinite-mean) clock.

        This is the scale-family parameter the vectorized engine traces:
        the hazard satisfies ``h_scale(t) = h_1(t / scale) / scale``.
        """
        mu = self.mu
        return 0.0 if math.isinf(mu) else math.exp(mu)

    def sample(self, rng: np.random.Generator) -> float:
        if self.mean_value <= 0 or math.isinf(self.mean_value):
            return math.inf
        return float(rng.lognormal(self.mu, self.sigma))

    @property
    def mean(self) -> float:
        return float(self.mean_value)


@dataclass(frozen=True)
class Weibull(Distribution):
    """Weibull parameterized by its mean and shape k.

    k < 1 models infant mortality (decreasing hazard), k > 1 wear-out
    (increasing hazard) — the two ends of the paper's bathtub curve.
    """

    mean_value: float
    k: float = 1.5

    @property
    def lam(self) -> float:
        """Weibull scale for the configured mean; 0 for a disabled clock.

        Single source of the mean -> scale conversion for both engines:
        the event sampler below and the vectorized engine's traced
        parameter columns read the same value.
        """
        if self.mean_value <= 0 or math.isinf(self.mean_value):
            return 0.0
        return self.mean_value / math.gamma(1.0 + 1.0 / self.k)

    def sample(self, rng: np.random.Generator) -> float:
        if self.mean_value <= 0 or math.isinf(self.mean_value):
            return math.inf
        return float(self.lam * rng.weibull(self.k))

    @property
    def mean(self) -> float:
        return float(self.mean_value)


# Registry so configs can name distributions by string (yaml-friendly) and
# users can register their own (paper: "extended with user-specified
# distributions").
#: factories accept (and ignore) unrelated kwargs so that one
#: Params.distribution_kwargs dict can serve failure AND repair
#: distributions of different families.
_REGISTRY: Dict[str, Callable[..., Distribution]] = {
    "exponential": lambda mean, **_: Exponential(
        rate=(0.0 if math.isinf(mean) else 1.0 / mean)),
    "deterministic": lambda mean, **_: Deterministic(value=mean),
    "lognormal": lambda mean, sigma=1.0, **_: LogNormal(
        mean_value=mean, sigma=sigma),
    "weibull": lambda mean, k=1.5, **_: Weibull(mean_value=mean, k=k),
}


def register_distribution(name: str, factory: Callable[..., Distribution]) -> None:
    _REGISTRY[name.lower()] = factory


def make_distribution(name: str, mean: float, **kwargs) -> Distribution:
    """Build a duration distribution with the given mean by registry name."""
    try:
        factory = _REGISTRY[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown distribution {name!r}; known: {sorted(_REGISTRY)}") from None
    return factory(mean, **kwargs)


def failure_distribution(name: str, rate: float, **kwargs) -> Distribution:
    """Build a failure inter-arrival distribution from a *rate* (1/mean)."""
    mean = math.inf if rate <= 0 else 1.0 / rate
    return make_distribution(name, mean, **kwargs)
