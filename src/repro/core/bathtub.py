"""Bathtub-curve failure model (paper §II-A).

"Typically, hardware failures follow a classic 'bath-tub' curve, with
most of the systematic issues manifesting at both ends of the curve,
while the flat portion of the curve (operational phase) consists mostly
of random failures.  However, modern hardware is becoming increasingly
difficult to test thoroughly ... systematic failures are becoming more
common even during the operational phase."

This module provides an age-dependent hazard:

    h(t) = h_infant * exp(-t / tau_infant)        (decreasing, early)
         + h_flat                                  (operational)
         + h_wear * max(0, (t - t_wear) / tau_wear)  (increasing, late)

sampled exactly by inversion of the cumulative hazard (closed-form
pieces + numerically inverted total).  Registered as the "bathtub"
failure distribution so a single Params switch turns it on:

    Params(failure_distribution="bathtub",
           distribution_kwargs={"infant_factor": 20, ...})

The mean-preserving parameterization keeps the long-run average rate
equal to the configured failure rate, so bathtub-vs-exponential sweeps
isolate the *shape* effect (tested in tests/test_bathtub.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .distributions import Distribution, register_distribution

MINUTES_PER_DAY = 24 * 60


@dataclass(frozen=True)
class Bathtub(Distribution):
    """Age-dependent hazard with infant-mortality and wear-out phases.

    mean_value:    target mean time-to-failure of the *flat* phase
    infant_factor: hazard multiple at t=0 (relative to flat)
    infant_tau:    decay time of the infant phase (minutes)
    wear_start:    onset of wear-out (minutes)
    wear_tau:      time for the wear hazard to reach the flat hazard
    """

    mean_value: float
    infant_factor: float = 10.0
    infant_tau: float = 7.0 * MINUTES_PER_DAY
    wear_start: float = 365.0 * MINUTES_PER_DAY
    wear_tau: float = 90.0 * MINUTES_PER_DAY

    @property
    def _h_flat(self) -> float:
        return 1.0 / self.mean_value

    def hazard(self, t: float) -> float:
        h = self._h_flat
        out = h + (self.infant_factor - 1.0) * h * math.exp(-t / self.infant_tau)
        if t > self.wear_start:
            out += h * (t - self.wear_start) / self.wear_tau
        return out

    def cumulative_hazard(self, t: float) -> float:
        h = self._h_flat
        H = h * t
        H += (self.infant_factor - 1.0) * h * self.infant_tau \
            * (1.0 - math.exp(-t / self.infant_tau))
        if t > self.wear_start:
            dt = t - self.wear_start
            H += h * dt * dt / (2.0 * self.wear_tau)
        return H

    def sample(self, rng: np.random.Generator) -> float:
        """Inverse-CDF via bisection on H(t) = -ln(U) (H is increasing)."""
        if self.mean_value <= 0 or math.isinf(self.mean_value):
            return math.inf
        target = -math.log(max(rng.random(), 1e-300))
        lo, hi = 0.0, self.mean_value
        while self.cumulative_hazard(hi) < target:
            hi *= 2.0
            if hi > 1e12:
                return hi
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if self.cumulative_hazard(mid) < target:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)

    @property
    def mean(self) -> float:
        return float(self.mean_value)

    def phase_at(self, t: float) -> str:
        if t < 3.0 * self.infant_tau:
            return "infant"
        if t > self.wear_start:
            return "wear-out"
        return "operational"


def _make_bathtub(mean, infant_factor=10.0, infant_tau=7.0 * MINUTES_PER_DAY,
                  wear_start=365.0 * MINUTES_PER_DAY,
                  wear_tau=90.0 * MINUTES_PER_DAY, **_):
    return Bathtub(mean_value=mean, infant_factor=infant_factor,
                   infant_tau=infant_tau, wear_start=wear_start,
                   wear_tau=wear_tau)


register_distribution("bathtub", _make_bathtub)
