"""Repairs module: diagnosis -> automated repair -> manual repair -> return.

Paper §III-C module (4) with assumptions 3-5:

  * upon failure a server first undergoes *automated* repair; with
    probability ``1 - automated_repair_probability`` the problem is beyond
    automated scope and the server escalates to *manual* repair (after the
    automated attempt's time has been spent);
  * both repair kinds can *silently fail* (status says repaired, problem
    persists) with their respective failure probabilities;
  * a successful repair converts a bad server to good (stateless repairs);
    repairing a good server (random failure / misdiagnosis) is a no-op;
  * repair durations are exponentially distributed around the configured
    means (assumption 4); pluggable like failure distributions;
  * optional score-based retirement: a server exceeding
    ``retirement_threshold`` failures within ``retirement_window`` minutes
    is permanently removed instead of reintegrated;
  * optional finite capacity (``Params.repair_servers``): at most that
    many servers are *in service* at once; the rest queue inside the
    shop.  A departure admits one queued server chosen uniformly at
    random — class/owner-proportional over the queued counts, which is
    what the vectorized CTMC engine's compartment model needs for
    exact-in-law parity.  Escalation to manual repair keeps its service
    slot (the server never leaves the technician's bench).  Capacity 0
    (default) queues nothing and draws nothing extra from the RNG, so
    unlimited-shop runs stay bit-identical to the pre-capacity engine.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from .distributions import Distribution, make_distribution
from .engine import Environment, Event, Interrupt
from .metrics import RunResult
from .params import Params
from .server import Server, ServerState


def repair_distributions(params: Params) -> Tuple[Distribution, Distribution]:
    """(automated, manual) repair-duration distributions for these Params.

    The single construction point for BOTH engines: the event engine's
    :class:`RepairShop` samples from these objects, and the vectorized
    engine's :func:`repro.core.hazards.repair_columns` reads its traced
    scale/shape parameters off the same instances — so a kwarg default
    retuned in :mod:`repro.core.distributions` moves the two engines
    together instead of the fast path keeping a stale copy.
    """
    kw = params.distribution_kwargs
    return (make_distribution(params.repair_distribution,
                              params.auto_repair_time, **kw),
            make_distribution(params.repair_distribution,
                              params.manual_repair_time, **kw))


class RepairShop:
    def __init__(self, env: Environment, params: Params,
                 rng: np.random.Generator, metrics: RunResult,
                 on_return: Callable[[Server], None],
                 on_retire: Optional[Callable[[Server], None]] = None):
        self.env = env
        self.params = params
        self.rng = rng
        self.metrics = metrics
        self.on_return = on_return
        self.on_retire = on_retire
        self.in_repair: set = set()
        #: service-slot bound (0 = unlimited) + the waiting line behind it
        self.capacity = params.repair_servers
        self.queue: list = []
        self._n_active = 0
        #: lifetime count of submissions that had to queue (shop full) —
        #: the event twin of the CTMC engine's n_shop_queued lane
        self.n_queued_events = 0
        self._auto_dist, self._manual_dist = repair_distributions(params)
        #: sid -> live repair Process (fault-domain rebreaks / maintenance
        #: pauses need a handle to interrupt specific stages)
        self._procs: dict = {}
        self._paused = False
        self._resume_events: list = []

    # -- public API ----------------------------------------------------------
    def submit(self, server: Server) -> None:
        """Send a failed server through the repair pipeline (async).

        With finite capacity, a full shop parks the server in the queue
        instead; it is still "in the shop" (``in_repair``) for
        conservation accounting, just not yet in service.
        """
        if server in self.in_repair:
            raise RuntimeError(f"{server!r} already in repair")
        self.in_repair.add(server)
        if self.capacity and self._n_active >= self.capacity:
            server.state = ServerState.REPAIR_AUTO   # waiting for the bench
            self.queue.append(server)
            self.n_queued_events += 1
            return
        self._start_service(server)

    def _start_service(self, server: Server) -> None:
        self._n_active += 1
        self._procs[server.sid] = self.env.process(
            self._repair_process(server), name=f"repair-{server.sid}")

    def _depart(self) -> None:
        """A server left service: free its slot and admit from the queue.

        Admission is a *uniform* draw over the queued servers, not FIFO:
        uniform-over-servers equals proportional-over-(class, owner)
        counts, the exchangeability property that makes the compiled
        CTMC engine's count-based admission exact in law.  An empty
        queue draws nothing, so capacity-0 runs never touch the RNG.
        """
        self._n_active -= 1
        if self.queue and (not self.capacity
                           or self._n_active < self.capacity):
            idx = int(self.rng.integers(len(self.queue)))
            nxt = self.queue.pop(idx)
            self._start_service(nxt)

    @property
    def n_in_repair(self) -> int:
        return len(self.in_repair)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    # -- fault-domain hooks (see repro.core.faultdomains) --------------------
    def pause(self) -> None:
        """Maintenance window opens: freeze every in-flight repair stage.

        Stages keep their remaining duration and resume where they left
        off when :meth:`resume` fires (the CTMC engine gates the same
        window by zeroing repair rates, exact-in-law for exponentials).
        """
        self._paused = True
        for proc in list(self._procs.values()):
            if proc.is_alive and proc._target is not None:
                proc.interrupt("pause")

    def resume(self) -> None:
        """Maintenance window closes: paused stages pick back up."""
        self._paused = False
        for evt in self._resume_events:
            if not evt.triggered:
                evt.succeed()
        self._resume_events.clear()

    def rebreak(self, server: Server) -> None:
        """A domain shock struck a server already in the shop: its current
        repair stage restarts with a fresh draw.  Exact-in-law a no-op
        under exponential repairs (memorylessness); real progress loss
        under Weibull / lognormal / deterministic repairs."""
        proc = self._procs.get(server.sid)
        if proc is not None and proc.is_alive and proc._target is not None:
            proc.interrupt("rebreak")

    def _stage_wait(self, dist: Distribution):
        """Serve one repair stage, honoring pauses and re-breaks.

        The duration is sampled *before* the pause check so a run whose
        campaign never fires consumes the RNG stream in exactly the
        baseline order (the zero-rate reduction tests rely on this).
        """
        remaining = dist.sample(self.rng)
        while True:
            if self._paused:
                evt: Event = self.env.event()
                self._resume_events.append(evt)
                try:
                    yield evt
                except Interrupt as itr:
                    if itr.cause == "rebreak":
                        remaining = dist.sample(self.rng)
                continue
            start = self.env.now
            try:
                yield self.env.timeout(remaining)
                return
            except Interrupt as itr:
                if itr.cause == "rebreak":
                    remaining = dist.sample(self.rng)
                else:  # pause: keep whatever stage time is left
                    remaining = max(remaining - (self.env.now - start), 0.0)

    # -- pipeline ----------------------------------------------------------
    def _repair_process(self, server: Server):
        p, rng = self.params, self.rng
        server.n_repairs += 1

        # Stage 1: automated testing + repair (always attempted first).
        server.state = ServerState.REPAIR_AUTO
        yield from self._stage_wait(self._auto_dist)
        self.metrics.n_auto_repairs += 1

        if rng.random() < p.automated_repair_probability:
            # Problem within automated scope; did the repair actually work?
            success = rng.random() >= p.auto_repair_failure_probability
        else:
            # Beyond automated scope -> manual repair (assumption 3).
            server.state = ServerState.REPAIR_MANUAL
            yield from self._stage_wait(self._manual_dist)
            self.metrics.n_manual_repairs += 1
            success = rng.random() >= p.manual_repair_failure_probability

        if success:
            # Assumption 5: a successful repair makes a bad server good.
            server.is_bad = False
        else:
            self.metrics.n_failed_repairs += 1

        self.in_repair.discard(server)
        self._procs.pop(server.sid, None)
        self._depart()

        # Score-based retirement (extension; off when threshold == 0).
        if (p.retirement_threshold > 0 and
                server.failures_in_window(self.env.now, p.retirement_window)
                >= p.retirement_threshold):
            self.metrics.n_retired += 1
            if self.on_retire is not None:
                self.on_retire(server)
            return

        # Reintegrate: Scheduler decides job-return vs pool-return.
        self.on_return(server)
