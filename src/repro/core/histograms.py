"""Streaming fixed-bin histograms for distribution-level outputs.

The paper's Fig. 2 tunes on recovery/waiting *distributions*, and the
operational studies it cites make checkpoint and spare-capacity decisions
from tail percentiles (p99 ETTF/ETTR), not means.  The event engine keeps
full per-run Python lists, but the vectorized CTMC scan cannot: its exact
per-run ring buffer (``Params.max_run_records``) truncates at multi-year
horizons.  A fixed-bin log-spaced histogram closes that gap — O(bins)
memory per replica, no run-count bound, percentiles exact to one bin
width at any horizon.

Layout (shared by the numpy accumulator here and the in-scan JAX
accumulator in :mod:`repro.core.vectorized`):

  * ``edges`` — ``n_bins + 1`` log-spaced boundaries over [low, high);
  * ``counts`` — ``n_bins + 2`` slots: ``counts[0]`` is the underflow bin
    [0, edges[0]), ``counts[i]`` covers [edges[i-1], edges[i]) for
    1 <= i <= n_bins (left-closed / right-open, so a value exactly on an
    edge lands deterministically in the bin it opens), and
    ``counts[n_bins + 1]`` is the overflow bin [edges[-1], inf).

``np.searchsorted(edges, values, side="right")`` maps values to exactly
this indexing, which is why both accumulators agree bit-for-bit on bin
assignment (up to the float32 edge representation the compiled scan
carries).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

#: channel order is part of the compiled-scan state layout — the CTMC
#: engine accumulates the subset a :class:`HistogramSpec` selects, in
#: this order.  ``goodput`` (one per-replica fraction per completed job)
#: is opt-in: the default spec tracks the original three duration
#: channels so existing compiled programs keep their state layout.
HIST_CHANNELS: Tuple[str, ...] = ("run_duration", "recovery", "waiting",
                                  "goodput")

#: the default tracked subset (every duration channel; goodput opt-in)
DEFAULT_CHANNELS: Tuple[str, ...] = ("run_duration", "recovery", "waiting")


@dataclass(frozen=True)
class HistogramSpec:
    """Bin layout + tracked channels for streaming distribution outputs.

    Defaults span 10^-2 .. 10^7 minutes (sub-second to ~19 years) in 128
    log-spaced bins: ~17.6% relative bin width, the resolution floor of
    every reported histogram percentile.  Channels:

      * ``run_duration`` — failure-to-failure useful-compute intervals
        (the ETTF-style metric); one record per completed run.
      * ``recovery``     — failure-to-compute-restart downtime (ETTR):
        recovery + host selection + preemption wait + stall, as incurred.
      * ``waiting``      — replacement-acquisition delay alone (the ETTR
        minus the fixed recovery reload); 0 for standby swaps and
        undiagnosed failures, so mass in the underflow bin is expected.
      * ``goodput``      — opt-in (not in the default subset): each
        completed replica's useful-work / wall-time fraction, one record
        per finished job.  Fractions live in (0, 1], far below the
        default ``low`` edge — pair it with a linear-friendly range such
        as ``HistogramSpec(low=0.01, high=1.0)``.

    Selecting a channel subset compiles the others *out* of the CTMC
    scan state (smaller carry, fewer scatter lanes), not just out of the
    reports; an empty tuple disables the accumulator like
    ``Params(histogram=None)``.

    >>> spec = HistogramSpec(low=1.0, high=100.0, n_bins=2,
    ...                      channels=("run_duration",))
    >>> spec.n_counts            # n_bins + underflow + overflow slots
    4
    >>> [round(float(e), 1) for e in spec.edges()]
    [1.0, 10.0, 100.0]
    >>> h = Histogram.from_values(spec, [0.5, 2.0, 3.0, 42.0, 1e6])
    >>> [int(c) for c in h.counts]          # under, [1,10), [10,100), over
    [1, 2, 1, 1]
    >>> round(h.percentile(50), 2)          # exact to one bin width
    7.75
    """

    low: float = 1e-2
    high: float = 1e7
    n_bins: int = 128
    channels: Tuple[str, ...] = DEFAULT_CHANNELS

    def __post_init__(self):
        # tolerate list input (yaml/json round trips); keep hashable
        object.__setattr__(self, "channels", tuple(self.channels))

    def validate(self) -> None:
        if not 0 < self.low < self.high:
            raise ValueError(
                f"histogram range must satisfy 0 < low < high, got "
                f"[{self.low}, {self.high})")
        if self.n_bins < 1:
            raise ValueError("histogram n_bins must be >= 1")
        unknown = set(self.channels) - set(HIST_CHANNELS)
        if unknown:
            raise ValueError(f"unknown histogram channels {sorted(unknown)}; "
                             f"available: {HIST_CHANNELS}")

    @property
    def n_counts(self) -> int:
        """Count slots including the underflow and overflow bins."""
        return self.n_bins + 2

    def edges(self) -> np.ndarray:
        """Log-spaced bin boundaries, shape (n_bins + 1,)."""
        return np.geomspace(self.low, self.high, self.n_bins + 1)

    @classmethod
    def from_dict(cls, d: Dict) -> "HistogramSpec":
        return cls(**d)


SpecOrEdges = Union[HistogramSpec, np.ndarray, Sequence[float]]


def _as_edges(spec_or_edges: SpecOrEdges) -> np.ndarray:
    if isinstance(spec_or_edges, HistogramSpec):
        return spec_or_edges.edges()
    return np.asarray(spec_or_edges, np.float64)


class Histogram:
    """One channel's accumulated counts — the pure-numpy reference.

    The event engine builds these from its per-run Python lists
    (:func:`Histogram.from_values`); the CTMC engine produces the
    identical ``counts`` layout inside the compiled scan.  ``merge`` is
    associative and commutative (it is plain count addition), so
    replica-chunked accumulation order never matters.
    """

    __slots__ = ("edges", "counts")

    def __init__(self, edges: SpecOrEdges,
                 counts: Optional[np.ndarray] = None):
        self.edges = _as_edges(edges)
        if counts is None:
            counts = np.zeros(len(self.edges) + 1, np.float64)
        self.counts = np.asarray(counts, np.float64).copy()
        if self.counts.shape != (len(self.edges) + 1,):
            raise ValueError(
                f"counts shape {self.counts.shape} does not match "
                f"{len(self.edges) + 1} bins (n_bins + under/overflow)")

    @classmethod
    def from_values(cls, spec_or_edges: SpecOrEdges,
                    values: Sequence[float]) -> "Histogram":
        return cls(spec_or_edges).add(values)

    # -- accumulation -----------------------------------------------------
    def add(self, values: Sequence[float]) -> "Histogram":
        """Accumulate values in place; returns self for chaining."""
        vals = np.asarray(list(values) if not isinstance(values, np.ndarray)
                          else values, np.float64)
        if vals.size:
            idx = np.searchsorted(self.edges, vals, side="right")
            np.add.at(self.counts, idx, 1.0)
        return self

    def merge(self, other: "Histogram") -> "Histogram":
        """New histogram with summed counts (associative + commutative)."""
        if not np.array_equal(self.edges, other.edges):
            raise ValueError("cannot merge histograms with different edges")
        return Histogram(self.edges, self.counts + other.counts)

    # -- queries ----------------------------------------------------------
    @property
    def total(self) -> float:
        return float(self.counts.sum())

    def cdf(self) -> np.ndarray:
        """Cumulative fraction at each bin's *upper* edge (monotone)."""
        total = max(self.total, 1.0)
        return np.cumsum(self.counts) / total

    def _bin_bounds(self, i: int) -> Tuple[float, float]:
        """[lower, upper) of count slot i; underflow starts at 0 (all
        tracked channels are non-negative durations)."""
        lo = 0.0 if i == 0 else float(self.edges[i - 1])
        hi = float(self.edges[-1]) if i >= len(self.edges) \
            else float(self.edges[i])
        return lo, hi

    def bin_width_at(self, x: float) -> float:
        """Width of the bin containing x — the resolution of any
        percentile that lands there."""
        i = int(np.searchsorted(self.edges, x, side="right"))
        lo, hi = self._bin_bounds(i)
        return hi - lo

    def percentile(self, q: float) -> float:
        """Percentile estimate, linear interpolation inside the bin.

        Exact to one bin width by construction; the overflow bin reports
        its lower edge (the histogram cannot see beyond ``high``).
        """
        total = self.total
        if total == 0:
            return float("nan")
        target = q / 100.0 * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, len(self.counts) - 1)
        if i == len(self.counts) - 1:        # overflow bin: no upper bound
            return float(self.edges[-1])
        lo, hi = self._bin_bounds(i)
        below = cum[i - 1] if i > 0 else 0.0
        frac = (target - below) / max(self.counts[i], 1e-30)
        return float(lo + min(max(frac, 0.0), 1.0) * (hi - lo))

    def _representatives(self) -> np.ndarray:
        """Per-bin representative values for moment estimates: geometric
        midpoints; half the low edge for underflow, the top edge for
        overflow."""
        e = self.edges
        reps = np.empty(len(self.counts))
        reps[0] = e[0] / 2.0
        reps[1:-1] = np.sqrt(e[:-1] * e[1:])
        reps[-1] = e[-1]
        return reps

    def mean(self) -> float:
        total = self.total
        if total == 0:
            return float("nan")
        return float((self.counts * self._representatives()).sum() / total)

    def std(self) -> float:
        total = self.total
        if total <= 1:
            return 0.0 if total == 1 else float("nan")
        reps = self._representatives()
        m = (self.counts * reps).sum() / total
        var = (self.counts * (reps - m) ** 2).sum() / (total - 1)
        return float(np.sqrt(max(var, 0.0)))

    def minimum(self) -> float:
        nz = np.nonzero(self.counts)[0]
        if nz.size == 0:
            return float("nan")
        return self._bin_bounds(int(nz[0]))[0]

    def maximum(self) -> float:
        nz = np.nonzero(self.counts)[0]
        if nz.size == 0:
            return float("nan")
        return self._bin_bounds(int(nz[-1]))[1]

    def __repr__(self) -> str:
        return (f"Histogram(n_bins={len(self.edges) - 1}, "
                f"total={self.total:.0f}, "
                f"range=[{self.edges[0]:g}, {self.edges[-1]:g}))")


def percentiles_per_row(edges: SpecOrEdges, counts_2d: np.ndarray,
                        q: float) -> np.ndarray:
    """Vectorized :meth:`Histogram.percentile` over a stack of histograms.

    ``counts_2d`` is an ``(R, n_bins + 2)`` matrix of per-replica bin
    counts (the CTMC engine's raw ``hist_{channel}`` output).  Returns
    ``(R,)`` percentile estimates — bit-compatible with building one
    :class:`Histogram` per row and calling ``percentile(q)``, which is
    what the event-engine path does — with NaN for empty rows.  This is
    the workhorse of the cross-replica dispersion statistics
    (``{channel}_p99_replica``): per-replica tail percentiles whose
    spread across replicas measures run-to-run variability, which the
    pooled histogram (one merged distribution) cannot see.
    """
    edges = _as_edges(edges)
    counts = np.asarray(counts_2d, np.float64)
    if counts.ndim != 2 or counts.shape[1] != len(edges) + 1:
        raise ValueError(
            f"counts shape {counts.shape} does not match "
            f"(R, {len(edges) + 1}) for {len(edges)} edges")
    total = counts.sum(axis=1)
    cum = np.cumsum(counts, axis=1)
    target = q / 100.0 * total
    i = np.sum(cum < target[:, None], axis=1)          # searchsorted left
    i = np.minimum(i, counts.shape[1] - 1)
    lo_edges = np.concatenate([[0.0], edges])          # slot lower bounds
    hi_edges = np.concatenate([edges, [edges[-1]]])    # slot upper bounds
    below = np.where(i > 0,
                     np.take_along_axis(cum, np.maximum(i - 1, 0)[:, None],
                                        axis=1)[:, 0], 0.0)
    in_bin = np.take_along_axis(counts, i[:, None], axis=1)[:, 0]
    frac = np.clip((target - below) / np.maximum(in_bin, 1e-30), 0.0, 1.0)
    val = lo_edges[i] + frac * (hi_edges[i] - lo_edges[i])
    # the overflow slot has no upper bound: report its lower edge, the
    # same convention as Histogram.percentile
    val = np.where(i == counts.shape[1] - 1, edges[-1], val)
    return np.where(total > 0, val, np.nan)
