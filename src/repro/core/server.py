"""Server module: per-server state, the fleet, and the failure sampler.

Paper §III-C module (1): "Server: Keeps track of each server's failure and
recovery. When a job is started on a server, a failure process starts at the
same time. ... Note that we approximate this process by analytical
calculation of the failure rates."

We follow the paper's own approximation: rather than scheduling one event
per server (4096 heap entries re-sampled on every restart), the fleet-wide
*first* failure is sampled analytically:

  * exponential distributions (default): the minimum of N exponential clocks
    is exponential with the summed rate; the firing clock is chosen
    proportionally to its rate.  Exact, O(1) per failure.
  * other distributions: per-server samples are drawn vectorized with numpy
    and the argmin taken.  Exact, O(N) per restart.

Both honor the paper's semantics that failure clocks (re)start whenever the
job (re)starts on a server.
"""

from __future__ import annotations

import enum
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .distributions import failure_distribution
from .params import Params


class ServerState(enum.Enum):
    WORKING_FREE = "working_free"   # powered-on, ready in the working pool
    SPARE = "spare"                 # in spare pool, running other jobs
    RUNNING = "running"             # executing the AI job
    STANDBY = "standby"             # allocated to the job as warm standby
    REPAIR_AUTO = "repair_auto"
    REPAIR_MANUAL = "repair_manual"
    RETIRED = "retired"


class Server:
    """One server's identity, health, and failure history."""

    __slots__ = ("sid", "is_bad", "state", "origin_spare", "failure_times",
                 "n_failures", "n_systematic", "n_random", "n_repairs")

    def __init__(self, sid: int, is_bad: bool, origin_spare: bool):
        self.sid = sid
        self.is_bad = is_bad
        self.state = ServerState.SPARE if origin_spare else ServerState.WORKING_FREE
        self.origin_spare = origin_spare
        self.failure_times: List[float] = []
        self.n_failures = 0
        self.n_systematic = 0
        self.n_random = 0
        self.n_repairs = 0

    def record_failure(self, now: float, systematic: bool) -> None:
        self.failure_times.append(now)
        self.n_failures += 1
        if systematic:
            self.n_systematic += 1
        else:
            self.n_random += 1

    def failures_in_window(self, now: float, window: float) -> int:
        cutoff = now - window
        # failure_times is append-only sorted; scan from the back
        count = 0
        for t in reversed(self.failure_times):
            if t < cutoff:
                break
            count += 1
        return count

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Server({self.sid}, {'bad' if self.is_bad else 'good'}, "
                f"{self.state.value})")


class Fleet:
    """All servers in the cluster (working pool + spare pool)."""

    def __init__(self, params: Params, rng: np.random.Generator):
        self.params = params
        self.rng = rng
        total = params.working_pool_size + params.spare_pool_size
        self.servers: List[Server] = [
            Server(sid, False, origin_spare=(sid >= params.working_pool_size))
            for sid in range(total)
        ]
        self._assign_bad_set()

    def _assign_bad_set(self) -> None:
        total = len(self.servers)
        n_bad = int(round(self.params.systematic_failure_fraction * total))
        bad_ids = self.rng.choice(total, size=n_bad, replace=False)
        flags = np.zeros(total, dtype=bool)
        flags[bad_ids] = True
        for server, flag in zip(self.servers, flags):
            server.is_bad = bool(flag)

    def regenerate_bad_set(self) -> None:
        """Assumption 1, case 2: periodically re-draw which servers are bad
        (aging / new hardware models entering the fleet)."""
        self._assign_bad_set()


class FailureSampler:
    """Samples the fleet-wide first failure among running servers."""

    def __init__(self, params: Params, rng: np.random.Generator):
        self.params = params
        self.rng = rng
        self._exponential = params.failure_distribution.lower() == "exponential"
        self._rand_dist = failure_distribution(
            params.failure_distribution, params.random_failure_rate,
            **params.distribution_kwargs)
        self._sys_dist = failure_distribution(
            params.failure_distribution, params.systematic_failure_rate,
            **params.distribution_kwargs)

    def sample_first_failure(
        self, good: Sequence[Server], bad: Sequence[Server],
    ) -> Tuple[float, Optional[Server], bool]:
        """Return (time_to_failure, failing_server, is_systematic).

        ``good``/``bad`` are indexable collections of currently-executing
        servers by health class.  Returns (inf, None, False) if no failure
        can occur.
        """
        if self._exponential:
            return self._sample_exponential(good, bad)
        return self._sample_generic(good, bad)

    # -- exact O(1) exponential path ---------------------------------------
    def _sample_exponential(self, good, bad):
        p = self.params
        n_good, n_bad = len(good), len(bad)
        # three competing clock families: good-random, bad-random, bad-systematic
        r_gr = n_good * p.random_failure_rate
        r_br = n_bad * p.random_failure_rate
        r_bs = n_bad * p.systematic_failure_rate
        total = r_gr + r_br + r_bs
        if total <= 0.0:
            return math.inf, None, False
        ttf = float(self.rng.exponential(1.0 / total))
        u = self.rng.random() * total
        if u < r_gr:
            server = good[int(self.rng.integers(n_good))]
            return ttf, server, False
        if u < r_gr + r_br:
            server = bad[int(self.rng.integers(n_bad))]
            return ttf, server, False
        server = bad[int(self.rng.integers(n_bad))]
        return ttf, server, True

    # -- generic vectorized path (lognormal / weibull / user) ---------------
    def _sample_generic(self, good, bad):
        n_good, n_bad = len(good), len(bad)
        if n_good + n_bad == 0:
            return math.inf, None, False
        best_t, best_server, best_sys = math.inf, None, False
        if n_good:
            t = np.array([self._rand_dist.sample(self.rng) for _ in range(n_good)])
            i = int(np.argmin(t))
            if t[i] < best_t:
                best_t, best_server, best_sys = float(t[i]), good[i], False
        if n_bad:
            t_r = np.array([self._rand_dist.sample(self.rng) for _ in range(n_bad)])
            t_s = np.array([self._sys_dist.sample(self.rng) for _ in range(n_bad)])
            ir, is_ = int(np.argmin(t_r)), int(np.argmin(t_s))
            if t_r[ir] < best_t:
                best_t, best_server, best_sys = float(t_r[ir]), bad[ir], False
            if t_s[is_] < best_t:
                best_t, best_server, best_sys = float(t_s[is_]), bad[is_], True
        if math.isinf(best_t):
            return math.inf, None, False
        return best_t, best_server, best_sys
