"""Pool module: working-pool / spare-pool bookkeeping.

Paper §III-C module (5): "Pool: Keeps track of the servers in working and
spare pools, and moves servers between them if needed."

Pure bookkeeping — all time costs (host selection, spare-pool preemption
waiting) are charged by the Scheduler, which owns the simulation clock
interactions.  Servers released when no longer needed return to their
*origin* pool: spare-pool servers go back to running other jobs (paper:
"When the need for additional servers for the AI job subsides, these
servers are returned to the spare pool").
"""

from __future__ import annotations

from typing import Callable, List, Optional

from .params import Params
from .server import Fleet, Server, ServerState


class PoolManager:
    def __init__(self, params: Params, fleet: Fleet):
        self.params = params
        self.fleet = fleet
        self.working_free: List[Server] = [
            s for s in fleet.servers if not s.origin_spare]
        self.spare_free: List[Server] = [
            s for s in fleet.servers if s.origin_spare]
        self.retired: List[Server] = []
        #: callbacks fired when a server is released back to a pool — the
        #: Scheduler registers here to un-stall a starved job.
        self._release_watchers: List[Callable[[Server], None]] = []

    # -- acquisition -------------------------------------------------------
    def pop_working(self) -> Optional[Server]:
        """Take a powered-on ready server from the working pool."""
        if not self.working_free:
            return None
        server = self.working_free.pop()
        return server

    def pop_spare(self) -> Optional[Server]:
        """Take a server from the spare pool (caller charges waiting_time)."""
        if not self.spare_free:
            return None
        server = self.spare_free.pop()
        return server

    def remove(self, server: Server) -> bool:
        """Take a *specific* free server out of its pool (domain kills).

        Returns False if the server is not currently sitting in a free
        list — e.g. it was popped by an in-flight replacement
        acquisition and is in limbo between pool and job.
        """
        for lst in (self.working_free, self.spare_free):
            try:
                lst.remove(server)
                return True
            except ValueError:
                pass
        return False

    # -- release -----------------------------------------------------------
    def push(self, server: Server) -> None:
        """Return a server to its origin pool and notify watchers."""
        if server.state is ServerState.RETIRED:
            raise ValueError(f"cannot release retired {server!r}")
        if server.origin_spare:
            server.state = ServerState.SPARE
            self.spare_free.append(server)
        else:
            server.state = ServerState.WORKING_FREE
            self.working_free.append(server)
        for watcher in list(self._release_watchers):
            watcher(server)

    def retire(self, server: Server) -> None:
        server.state = ServerState.RETIRED
        self.retired.append(server)

    # -- stall support -------------------------------------------------------
    def add_release_watcher(self, cb: Callable[[Server], None]) -> None:
        self._release_watchers.append(cb)

    def remove_release_watcher(self, cb: Callable[[Server], None]) -> None:
        try:
            self._release_watchers.remove(cb)
        except ValueError:
            pass

    # -- accounting ------------------------------------------------------------
    @property
    def n_working_free(self) -> int:
        return len(self.working_free)

    @property
    def n_spare_free(self) -> int:
        return len(self.spare_free)

    @property
    def n_retired(self) -> int:
        return len(self.retired)

    def conservation_counts(self) -> dict:
        """Server-count snapshot for the conservation invariant tests."""
        by_state: dict = {}
        for s in self.fleet.servers:
            by_state[s.state.value] = by_state.get(s.state.value, 0) + 1
        return by_state
