"""Engine dispatch: route replication studies to the right simulator.

AIReSim has two engines with one statistical contract:

  * ``event`` — the generator-coroutine DES (:mod:`repro.core.simulation`).
    Exact for every feature (retirement, bad-set regeneration, arbitrary
    distributions, checkpoint rollback), one trajectory at a time.
  * ``ctmc``  — the vectorized JAX engine (:mod:`repro.core.vectorized`).
    Covers the paper's exponential model, the age-dependent Weibull /
    bathtub / lognormal failure families, trace-driven ``empirical``
    piecewise-constant hazards (fitted from event logs via
    :mod:`repro.core.empirical`), *and* Weibull / lognormal /
    deterministic repair distributions (see ``vectorized.supports`` and
    docs/distributions.md), plus checkpoint rollback + write cost
    (``checkpoint_interval`` / ``checkpoint_cost``, both traced sweep
    axes), simulating thousands of replicas — and, via
    :func:`run_replications_batch`, whole sweep grids, including
    *structural* grids over job_size / pool sizes / warm_standbys — as a
    single compiled XLA program per hazard family (structure padding;
    see the vectorized module docstring).  Run-duration statistics are
    exact on both engines: the CTMC scan records per-run intervals in a
    ring buffer sized by ``Params.max_run_records``.

``engine="auto"`` (the default everywhere) picks ``ctmc`` whenever the
parameters are inside its supported envelope and silently falls back to
``event`` otherwise, so callers get the fast path for free without losing
feature coverage.  Passing ``engine="ctmc"`` explicitly raises if the
parameters are unsupported rather than silently degrading.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from . import vectorized, vectorized_multijob
from .histograms import Histogram
from .metrics import (RunResult, Stat, aggregate, aggregate_arrays,
                      aggregate_multijob_arrays, histograms_from_arrays,
                      histograms_from_results, pool_histograms)
from .multijob import JobSpec, MultiJobResult, simulate_multijob
from .params import Params
from .simulation import simulate

ENGINES = ("auto", "event", "ctmc")


def resolve_engine(params: Params, engine: str = "auto") -> str:
    """Map an engine request to the concrete engine that will run."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")
    if engine == "auto":
        return "ctmc" if vectorized.supports(params) else "event"
    if engine == "ctmc":
        # built from vectorized.unsupported_reasons — the single source
        # of truth shared with supports() — so the message names the
        # *actual* exclusion(s) instead of a hand-maintained stale list
        reasons = vectorized.unsupported_reasons(params)
        if reasons:
            raise ValueError(
                "engine='ctmc' requested but these Params are outside "
                "the CTMC envelope: " + "; ".join(reasons)
                + "; use engine='auto' to fall back to the event engine")
    return engine


@dataclass
class Replications:
    """Aggregated outcome of one replication study (one sweep point)."""

    engine: str                     # concrete engine that ran: event | ctmc
    n: int                          # number of replications
    stats: Dict[str, Stat]
    #: per-replication RunResults (event engine only; empty for ctmc —
    #: the whole point of the batched path is never materializing them)
    results: List[RunResult] = field(default_factory=list)
    #: raw {metric: (n,) ndarray} (ctmc engine only)
    arrays: Optional[Dict[str, np.ndarray]] = None
    #: pooled streaming histograms per channel (both engines, whenever
    #: ``Params.histogram`` is set) — unbounded-run-count ETTF/ETTR/
    #: waiting distributions, percentiles exact to one bin width
    histograms: Dict[str, Histogram] = field(default_factory=dict)


def _from_arrays(arrays: Dict[str, np.ndarray], n: int) -> Replications:
    incomplete = int(n - arrays["completed"].sum())
    if incomplete:
        warnings.warn(
            f"{incomplete}/{n} CTMC replicas hit the step budget before "
            "finishing the job; means are biased low — raise max_steps "
            "(truncation is surfaced as the 'n_incomplete' metric and the "
            "'completed' fraction in stats and sweep CSVs)",
            RuntimeWarning, stacklevel=3)
    overflows = int(arrays.get("n_repair_overflow", np.zeros(1)).sum())
    if overflows:
        warnings.warn(
            f"{overflows} diagnosed failure(s) found the repair-slot lane "
            "full (the server never leaves the shop; results are biased) "
            "— raise Params.repair_slots",
            RuntimeWarning, stacklevel=3)
    hists = histograms_from_arrays(arrays)
    return Replications(engine="ctmc", n=n,
                        stats=aggregate_arrays(arrays, histograms=hists),
                        arrays=arrays, histograms=hists)


def _from_results(results: List[RunResult], n: int,
                  params: Params) -> Replications:
    hists = histograms_from_results(results, params.histogram)
    return Replications(engine="event", n=n,
                        stats=aggregate(results, histograms=hists),
                        results=results, histograms=hists)


def run_replications(params: Params, n: int, engine: str = "auto",
                     base_seed: Optional[int] = None,
                     impl: Optional[str] = None,
                     max_steps: Optional[int] = None) -> Replications:
    """Run ``n`` independent replications on the selected engine."""
    chosen = resolve_engine(params, engine)
    if chosen == "ctmc":
        seed = params.seed if base_seed is None else base_seed
        arrays = vectorized.simulate_ctmc(params, n_replicas=n, seed=seed,
                                          impl=impl, max_steps=max_steps)
        return _from_arrays(arrays, n)
    results = simulate(params, n, base_seed=base_seed)
    return _from_results(results, n, params)


def run_replications_batch(params_list: Sequence[Params], n: int,
                           engine: str = "auto",
                           base_seed: Optional[int] = None,
                           impl: Optional[str] = None,
                           max_steps: Optional[int] = None,
                           progress: Optional[Callable[[int], None]] = None,
                           padded: bool = True,
                           bucketed: bool = True,
                           ) -> List[Replications]:
    """Replication studies for a whole sweep grid, batched where possible.

    Every point that resolves to the CTMC engine is executed in a single
    ``vectorized.simulate_ctmc_sweep`` call — with ``padded=True`` (the
    default) even a mixed-structure grid compiles exactly one XLA
    program; ``padded=False`` keeps the legacy one-program-per-structure
    grouping for A/B benchmarks.  ``bucketed=True`` (default, padded path
    only) additionally rounds the (points, replicas, step-budget) shape
    signature up to its power-of-two bucket with inert padding rows, so
    repeated sweeps of different sizes reuse one compiled program.  The
    rest run through the event engine one by one.  Results come back in
    input order regardless of routing.

    ``progress(i)`` is invoked when work on grid point ``i`` starts:
    once per point as the sequential event engine reaches it, and for
    all batched CTMC points up front (they genuinely start together).

    A failure-free grid finishes in exactly host-selection + job time,
    which makes the routing observable:

    >>> from repro.core import Params, run_replications_batch
    >>> calm = Params(job_size=2, working_pool_size=3, spare_pool_size=1,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> reps = run_replications_batch(
    ...     [calm, calm.replace(job_length=20.0)], n=2, engine="event")
    >>> [round(r.stats["total_time"].mean, 1) for r in reps]  # +3.0 select
    [13.0, 23.0]
    >>> [r.engine for r in reps]
    ['event', 'event']
    """
    params_list = list(params_list)
    chosen = [resolve_engine(p, engine) for p in params_list]
    out: List[Optional[Replications]] = [None] * len(params_list)

    ctmc_idx = [i for i, c in enumerate(chosen) if c == "ctmc"]
    if ctmc_idx:
        if progress:
            for i in ctmc_idx:
                progress(i)
        seed = (params_list[ctmc_idx[0]].seed if base_seed is None
                else base_seed)
        arrays_list = vectorized.simulate_ctmc_sweep(
            [params_list[i] for i in ctmc_idx], n_replicas=n, seed=seed,
            impl=impl, max_steps=max_steps, padded=padded,
            bucketed=bucketed)
        for i, arrays in zip(ctmc_idx, arrays_list):
            out[i] = _from_arrays(arrays, n)

    for i, c in enumerate(chosen):
        if c == "event":
            if progress:
                progress(i)
            results = simulate(params_list[i], n, base_seed=base_seed)
            out[i] = _from_results(results, n, params_list[i])
    return out


# ---------------------------------------------------------------------------
# multi-job dispatch
# ---------------------------------------------------------------------------

def resolve_engine_multijob(cluster: Params, jobs: Sequence[JobSpec],
                            engine: str = "auto") -> str:
    """Multi-job twin of :func:`resolve_engine`.

    ``auto`` picks the compiled multi-job CTMC engine
    (:mod:`repro.core.vectorized_multijob`) whenever the cluster is
    inside its envelope — exponential failures and repairs, all jobs
    starting at t=0, none of the event-only extensions — and falls back
    to the event-loop :class:`~repro.core.multijob.MultiJobSimulation`
    otherwise.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")
    if engine == "auto":
        return ("ctmc"
                if vectorized_multijob.supports_multijob(cluster, jobs)
                else "event")
    if engine == "ctmc":
        reasons = vectorized_multijob.unsupported_reasons_multijob(
            cluster, jobs)
        if reasons:
            raise ValueError(
                "engine='ctmc' requested but this multi-job cluster is "
                "outside the CTMC envelope: " + "; ".join(reasons)
                + "; use engine='auto' to fall back")
    return engine


@dataclass
class MultiJobReplications:
    """Aggregated outcome of one multi-job replication study."""

    engine: str                     # concrete engine that ran
    n: int                          # number of replications
    #: one full Replications per job (same Stat keys as single-job runs)
    per_job: List[Replications]
    #: fleet-level Stats: makespan, shared-shop counters, stall_handoffs,
    #: n_shop_queued, conservation_err, completed, fleet_* sums, and
    #: fleet-pooled {channel}_dist
    fleet: Dict[str, Stat]
    #: fleet-pooled streaming histograms (all jobs' channels merged)
    histograms: Dict[str, Histogram] = field(default_factory=dict)


def _multijob_from_arrays(point: Dict[str, object],
                          n: int) -> MultiJobReplications:
    agg = aggregate_multijob_arrays(point)
    per_job = []
    for arrays, stats, hists in zip(point["per_job"], agg["per_job"],
                                    agg["per_job_histograms"]):
        per_job.append(Replications(engine="ctmc", n=n, stats=stats,
                                    arrays=arrays, histograms=hists))
    incomplete = int(n - point["completed"].sum())
    if incomplete:
        warnings.warn(
            f"{incomplete}/{n} multi-job CTMC replicas hit the step budget "
            "before every job finished; means are biased low — raise "
            "max_steps", RuntimeWarning, stacklevel=3)
    return MultiJobReplications(engine="ctmc", n=n, per_job=per_job,
                                fleet=agg["fleet"],
                                histograms=agg["histograms"])


def _multijob_from_results(results: List[MultiJobResult], n: int,
                           cluster: Params) -> MultiJobReplications:
    n_jobs = len(results[0].per_job)
    per_job = [
        _from_results([r.per_job[j] for r in results], n, cluster)
        for j in range(n_jobs)]
    fleet: Dict[str, Stat] = {}
    lanes = {
        "makespan": [r.makespan for r in results],
        "stall_handoffs": [float(r.stall_events) for r in results],
        "n_auto_repairs": [float(r.cluster.n_auto_repairs)
                           for r in results],
        "n_manual_repairs": [float(r.cluster.n_manual_repairs)
                             for r in results],
        "n_failed_repairs": [float(r.cluster.n_failed_repairs)
                             for r in results],
        "n_shop_queued": [float(r.queue_events) for r in results],
        # the event loop conserves servers by construction (pinned by
        # test_multijob_conserves_servers); reported for key parity
        "conservation_err": [0.0] * n,
        "completed": [0.0 if any(p.timed_out for p in r.per_job) else 1.0
                      for r in results],
        "fleet_n_failures": [float(r.total_failures) for r in results],
        "fleet_stall_time": [sum(p.stall_time for p in r.per_job)
                             for r in results],
        "fleet_useful_work": [sum(p.useful_work for p in r.per_job)
                              for r in results],
    }
    for name, xs in lanes.items():
        fleet[name] = Stat.of(xs)
    pooled = pool_histograms([rep.histograms for rep in per_job])
    for ch, h in pooled.items():
        fleet[f"{ch}_dist"] = Stat.from_histogram(h)
    return MultiJobReplications(engine="event", n=n, per_job=per_job,
                                fleet=fleet, histograms=pooled)


def run_replications_multijob(cluster: Params, jobs: Sequence[JobSpec],
                              n: int, engine: str = "auto",
                              base_seed: Optional[int] = None,
                              impl: Optional[str] = None,
                              max_steps: Optional[int] = None,
                              ) -> MultiJobReplications:
    """``n`` independent multi-job replications on the selected engine."""
    return run_multijob_batch([(cluster, tuple(jobs))], n, engine=engine,
                              base_seed=base_seed, impl=impl,
                              max_steps=max_steps)[0]


def run_multijob_batch(points: Sequence, n: int, engine: str = "auto",
                       base_seed: Optional[int] = None,
                       impl: Optional[str] = None,
                       max_steps: Optional[int] = None,
                       ) -> List[MultiJobReplications]:
    """Multi-job replication studies for a whole capacity grid.

    ``points`` is a sequence of ``(cluster Params, [JobSpec, ...])``
    pairs.  Every point inside the multi-job CTMC envelope runs in a
    single :func:`~repro.core.vectorized_multijob.simulate_multijob_ctmc_sweep`
    call — points sharing a job count compile to ONE XLA program no
    matter how sizes, rates, or pool/shop capacities vary — and the rest
    fall back to the event-loop ``MultiJobSimulation`` one by one.
    """
    points = [(c, tuple(js)) for c, js in points]
    chosen = [resolve_engine_multijob(c, js, engine) for c, js in points]
    out: List[Optional[MultiJobReplications]] = [None] * len(points)

    ctmc_idx = [i for i, c in enumerate(chosen) if c == "ctmc"]
    if ctmc_idx:
        seed = (points[ctmc_idx[0]][0].seed if base_seed is None
                else base_seed)
        point_outs = vectorized_multijob.simulate_multijob_ctmc_sweep(
            [points[i] for i in ctmc_idx], n_replicas=n, seed=seed,
            impl=impl, max_steps=max_steps)
        for i, po in zip(ctmc_idx, point_outs):
            out[i] = _multijob_from_arrays(po, n)

    for i, c in enumerate(chosen):
        if c == "event":
            cluster, js = points[i]
            results = simulate_multijob(
                cluster, list(js), n_replications=n,
                base_seed=cluster.seed if base_seed is None else base_seed)
            out[i] = _multijob_from_results(results, n, cluster)
    return out
