"""Vectorized multi-job CTMC engine: whole-cluster sweeps as one program.

The paper's headline case study is *capacity planning*: many concurrent
jobs of mixed sizes contending for one spare pool and one repair shop.
The single-job CTMC engine (:mod:`repro.core.vectorized`) models exactly
one job; this module promotes the event-loop multi-job semantics
(:mod:`repro.core.multijob` / ``scheduler`` / ``coordinator``) into the
compiled scan.

State layout (batch axis B = points x replicas, J jobs **static**):

  * per-job compartment blocks ``run`` / ``sb`` — each job carries its
    own running set and warm-standby complement over the 4 (origin x
    health) classes, its own phase/timer/work_left lanes, and its own
    run/recovery/waiting histogram channels;
  * shared pool lanes ``fw`` / ``fs`` — ONE working pool and ONE spare
    pool all jobs draw from (the contention the paper predicts at
    replacement acquisition);
  * a shared finite-server repair shop, partitioned **by owning job**:
    ``auto`` / ``man`` are the in-service stages (generalizing the PR 5
    repair-slot lane into a shop with ``Params.repair_servers`` service
    slots) and ``q`` is the waiting line behind them.  A departure
    admits one queued server proportionally over the queued (job,
    class) counts — exactly the uniform-random admission the event
    engine's :class:`~repro.core.repair.RepairShop` draws, so admission
    is exact in law.  ``repair_servers=0`` keeps the shop unbounded and
    the queue lane permanently empty.

Job count/structure is the only static compile key; job sizes, lengths,
rates, warm-standby targets, and pool/shop capacities are all traced —
so a mixed-size capacity grid (spare-pool size x repair servers) runs
as ONE compiled XLA program via :func:`simulate_multijob_ctmc_sweep`.

Dispatch semantics promoted from the event engine's ``Dispatcher``:

  * a repaired server goes to the **longest-stalled** job first (FIFO
    over stall-start times; ties resolve to the lowest job index, the
    stability of Python's ``min``), paying the host-selection surcharge
    iff the receiver is not the owner that submitted it;
  * otherwise the owning job refills its standby complement (if still
    active and below its warm target);
  * otherwise the server returns to its origin pool.

A completing job releases its running + standby servers to the pools;
stalled jobs grab one each (earliest stall first — the release-watcher
order of the event engine) with the host-selection surcharge always
charged (released servers are never members of the starved job).

Reduction: a 1-job cluster with an unbounded shop **routes to the
single-job engine** (``cluster.replace(job-spec overrides)`` through
:func:`repro.core.vectorized.simulate_ctmc_sweep`) — bit-identical
results from the same compiled program class.

Carve-outs (the event ``MultiJobSimulation`` remains the oracle):
exponential failures AND repairs only, no fault domains / campaigns /
checkpoint rollback / retirement / regeneration / failing standbys, and
all jobs start at t=0.  ``supports_multijob`` gates dispatch; see
docs/multijob.md for the exact-in-law guarantees and the documented
approximations (expectation initial bad-split, class-proportional
picks, batch-proportional release hand-offs).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from . import hazards
from .multijob import JobSpec
from .params import Params
from .vectorized import (COMPUTE, DONE, OVERHEAD, STALL, _next_pow2,
                         _selected_channels, default_max_steps)
from .vectorized import DEFAULT_CHUNK_STEPS

#: per-job scalar metrics carried as (B, J) lanes — the per-job
#: RunResult fields the event oracle reports
_MJ_JOB_METRICS = (
    "total_time", "useful_work", "n_failures", "n_random_failures",
    "n_systematic_failures", "n_undiagnosed", "n_misdiagnosed",
    "n_preemptions", "n_host_selections", "n_standby_swaps",
    "stall_time", "recovery_overhead",
)

#: cluster-level (B,) metrics: the shared repair shop's counters (the
#: event engine's ``MultiJobResult.cluster``), the dispatcher's
#: stall hand-off count, shop-queue pressure, and the conservation check
_MJ_CLUSTER_METRICS = ("n_auto_repairs", "n_manual_repairs",
                       "n_failed_repairs", "stall_handoffs",
                       "n_shop_queued", "conservation_err")

#: uniform lanes per step: u_time, u_pick (event race), u_diag, u_wrong,
#: u_cls, u_esc, u_succ, u_pool (failure/repair path — same roles as the
#: single-job engine), u_adm (queue admission pick), u_rel
#: (completion-release class picks, golden-ratio shifted per hand-off)
_N_UNIFORMS = 10

_PHI = 0.6180339887498949


def unsupported_reasons_multijob(cluster: Params,
                                 jobs: Sequence[JobSpec]) -> list:
    """Why this cluster is outside the multi-job CTMC envelope.

    Empty list = inside.  The single source of truth for
    :func:`supports_multijob` and the ``engine="ctmc"`` refusal
    message built by :mod:`repro.core.backend` — mirroring
    ``vectorized.unsupported_reasons`` so the messages can never go
    stale against the actual dispatch conditions again.
    """
    reasons = []
    if len(jobs) < 1:
        reasons.append("no jobs given")
    if hazards.hazard_kind(cluster) != "exponential":
        reasons.append(
            "non-exponential failure distribution (the multi-job "
            "program has no per-job hazard lanes yet; the single-job "
            "CTMC engine covers weibull/bathtub/lognormal/empirical)")
    if hazards.repair_kind(cluster) != "exponential":
        reasons.append(
            "non-exponential repair distribution (the shared "
            "repair-shop lane is exponential-stage only)")
    if cluster.fault_domains is not None or cluster.campaign is not None:
        reasons.append(
            "fault domains / campaigns are single-job-fast-path or "
            "event-engine territory here")
    if cluster.retirement_threshold != 0:
        reasons.append("retirement policies are event-engine-only")
    if cluster.bad_set_regeneration_period != 0:
        reasons.append("bad-set regeneration is event-engine-only")
    if cluster.checkpoint_interval != 0:
        reasons.append("checkpoint rollback is event-engine-only")
    if cluster.standbys_can_fail:
        reasons.append("failing warm standbys are event-engine-only")
    if any(j.start_time != 0.0 for j in jobs):
        reasons.append(
            "staggered job start times (all jobs must start at t=0)")
    return reasons


def supports_multijob(cluster: Params, jobs: Sequence[JobSpec]) -> bool:
    """Can the multi-job CTMC engine run this cluster exactly-in-law?

    The multi-job compartment model covers the paper's exponential
    baseline — exponential failures and repairs — with any number of
    mixed-size jobs sharing one spare pool and one (optionally finite)
    repair shop.  Age-dependent hazards, per-server repair slots, fault
    domains/campaigns, and the event-engine-only extensions stay on the
    event-loop oracle, as do staggered job start times.
    """
    return not unsupported_reasons_multijob(cluster, jobs)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def _mj_initial_counts(cluster: Params, jobs: Sequence[JobSpec]) -> dict:
    """Sequential expectation-split allocation, mirroring the event
    engine's job-order pops from one shared working pool at t=hs."""
    wp, sp = cluster.working_pool_size, cluster.spare_pool_size
    total = wp + sp
    n_bad = int(round(cluster.systematic_failure_fraction * total))
    bad_w = round(n_bad * wp / total)
    bad_s = n_bad - bad_w

    def split(n_take, pool_good, pool_bad):
        frac_bad = pool_bad / max(pool_good + pool_bad, 1)
        take_bad = int(round(n_take * frac_bad))
        return n_take - take_bad, take_bad

    w_good, w_bad = wp - bad_w, bad_w
    run, sb = [], []
    for spec in jobs:
        rg, rb = split(spec.job_size, w_good, w_bad)
        w_good -= rg
        w_bad -= rb
        n_sb = min(spec.warm_standbys, w_good + w_bad)
        sg, s_b = split(n_sb, w_good, w_bad)
        w_good -= sg
        w_bad -= s_b
        run.append([rg, rb, 0, 0])
        sb.append([sg, s_b, 0, 0])
    return {"run": run, "sb": sb,
            "fw": [w_good, w_bad, 0, 0],
            "fs": [0, 0, sp - bad_s, bad_s],
            "fleet_total": float(total)}


def _mj_initial_state_batch(points: Sequence[Tuple[Params, tuple]],
                            R: int, max_runs: int,
                            ) -> Dict[str, jnp.ndarray]:
    """Padded initial state for a structural grid, point-major (P*R, ...).

    As in the single-job engine, structure (job sizes, pool sizes, job
    lengths) enters purely as per-point initial *values*: every point of
    a group shares the (J-static) compartment layout, so a mixed-size
    capacity grid is one compiled program.
    """
    P = len(points)
    B = P * R
    J = len(points[0][1])
    counts = [_mj_initial_counts(c, js) for c, js in points]

    def rep(arr):
        return jnp.asarray(np.repeat(np.asarray(arr, np.float32), R,
                                     axis=0))

    state: Dict[str, jnp.ndarray] = {}
    state["run"] = rep([c["run"] for c in counts])          # (B, J, 4)
    state["sb"] = rep([c["sb"] for c in counts])
    state["fw"] = rep([c["fw"] for c in counts])            # (B, 4)
    state["fs"] = rep([c["fs"] for c in counts])
    state["auto"] = jnp.zeros((B, J, 4), jnp.float32)
    state["man"] = jnp.zeros((B, J, 4), jnp.float32)
    state["q"] = jnp.zeros((B, J, 4), jnp.float32)
    state["fleet_total"] = rep([c["fleet_total"] for c in counts])  # (B,)
    state["t"] = rep([c.host_selection_time for c, _ in points])
    state["work_left"] = rep([[j.job_length for j in js]
                              for _, js in points])         # (B, J)
    state["timer"] = jnp.full((B, J), jnp.inf, jnp.float32)
    state["stall_start"] = jnp.zeros((B, J), jnp.float32)
    state["phase"] = jnp.full((B, J), COMPUTE, jnp.int32)
    state["cur_run"] = jnp.zeros((B, J), jnp.float32)
    state["n_runs"] = jnp.zeros((B, J), jnp.int32)
    state["run_durations"] = jnp.zeros((B, J, max_runs), jnp.float32)
    spec = points[0][0].histogram
    sel = _selected_channels(spec)
    if sel:
        state["hist"] = jnp.zeros((B, J, len(sel), spec.n_counts),
                                  jnp.float32)
        state["hist_edges"] = jnp.asarray(spec.edges(), jnp.float32)
    for m in _MJ_JOB_METRICS:
        state.setdefault(m, jnp.zeros((B, J), jnp.float32))
    for m in _MJ_CLUSTER_METRICS:
        state[m] = jnp.zeros((B,), jnp.float32)
    return state


_UNBATCHED = ("hist_edges",)


def _mj_bucket_pad(state: Dict[str, jnp.ndarray], P: int, R: int,
                   P_pad: int, R_pad: int) -> Dict[str, jnp.ndarray]:
    """Pad a (P*R, ...) point-major state to (P_pad*R_pad, ...) with
    inert rows (every job DONE from step 0, zero occupancies)."""
    out: Dict[str, jnp.ndarray] = {}
    for k, v in state.items():
        if k in _UNBATCHED:
            out[k] = v
            continue
        v = v.reshape((P, R) + v.shape[1:])
        pad = [(0, P_pad - P), (0, R_pad - R)] + [(0, 0)] * (v.ndim - 2)
        out[k] = jnp.pad(v, pad).reshape((P_pad * R_pad,) + v.shape[2:])
    real = ((jnp.arange(P_pad * R_pad) // R_pad < P)
            & (jnp.arange(P_pad * R_pad) % R_pad < R))
    out["phase"] = jnp.where(real[:, None], out["phase"], DONE)
    return out


def _pick_cat(counts: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Categorical draw proportional to counts: (B, K) x (B,) -> (B,)."""
    total = jnp.maximum(counts.sum(-1), 1e-30)
    cdf = jnp.cumsum(counts, axis=-1) / total[..., None]
    return jnp.minimum(
        jnp.sum((u[..., None] >= cdf).astype(jnp.int32), -1),
        counts.shape[-1] - 1)


def _onehot4(c: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.one_hot(c, 4, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# one transition
# ---------------------------------------------------------------------------

def _mj_step_u(s: Dict[str, jnp.ndarray], u: jnp.ndarray, pv: jnp.ndarray,
               J: int, impl: Optional[str],
               hist_channels: tuple) -> Dict[str, jnp.ndarray]:
    """One multi-job CTMC transition for a batch of replicas.

    ``pv`` columns: 14 shared model parameters [r_rand, r_sys, recovery,
    host_sel, waiting, auto_t, man_t, auto_fail, man_fail, p_auto, dp,
    du, preempt_cost, repair_servers] followed by J per-job warm-standby
    targets — a single vector or one row per replica (the batched sweep
    layout).  Race layout: 16J exponential lanes ([random-failure x4,
    systematic x4, auto-completion x4, manual x4] per job, job-major
    within each family block) + 2J deterministic residuals (per-job
    completion, then per-job overhead timer).
    """
    B = s["t"].shape[0]
    if pv.ndim == 1:
        col = [pv[i] for i in range(14)]
        warm = pv[14:14 + J]                                   # (J,)
        warm_of = lambda j: warm[j]                            # (B,)
    else:
        col = [pv[:, i] for i in range(14)]
        warm = pv[:, 14:14 + J]                                # (B, J)
        brows_w = jnp.arange(B)
        warm_of = lambda j: warm[brows_w, j]
    (r_rand, r_sys, recovery, host_sel, waiting, auto_t, man_t,
     auto_fail, man_fail, p_auto, dp, du, preempt_cost, cap) = col

    (u_time, u_pick, u_diag, u_wrong, u_cls, u_esc, u_succ, u_pool,
     u_adm, u_rel) = (u[:, i] for i in range(_N_UNIFORMS))

    rows = jnp.arange(B)
    jobs_ax = jnp.arange(J)
    computing = s["phase"] == COMPUTE                          # (B, J)
    in_overhead = s["phase"] == OVERHEAD
    stalled_pre = s["phase"] == STALL
    active_any = jnp.any(s["phase"] != DONE, axis=-1)          # (B,)

    def _e(x):      # scalar-or-(B,) param -> broadcast over (B, J, 4)
        return x if jnp.ndim(x) == 0 else x[:, None, None]

    def _j(x):      # scalar-or-(B,) param -> broadcast over (B, J)
        return x if jnp.ndim(x) == 0 else x[:, None]

    # ---- rates (B, 16J) -------------------------------------------------
    run = s["run"]
    bad_mask = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    comp3 = computing[..., None]
    fail_rand = run * _e(r_rand) * comp3
    fail_sys = run * bad_mask[None, None, :] * _e(r_sys) * comp3
    auto_rate = s["auto"] / jnp.maximum(_e(auto_t), 1e-9)
    man_rate = s["man"] / jnp.maximum(_e(man_t), 1e-9)
    rates = jnp.concatenate(
        [fail_rand.reshape(B, 4 * J), fail_sys.reshape(B, 4 * J),
         auto_rate.reshape(B, 4 * J), man_rate.reshape(B, 4 * J)],
        axis=-1) * active_any[:, None]

    residuals = jnp.concatenate(
        [jnp.where(computing, s["work_left"], jnp.inf),
         jnp.where(in_overhead, s["timer"], jnp.inf)], axis=-1)  # (B, 2J)

    dt, ev = ops.event_race(rates, residuals, u_time, u_pick, impl=impl)
    dt = jnp.where(active_any & jnp.isfinite(dt), dt, 0.0)
    kx = 16 * J

    cls = (ev % 4).astype(jnp.int32)
    ej = ((ev % (4 * J)) // 4).astype(jnp.int32)       # owning/failing job
    ej1h = jax.nn.one_hot(ej, J, dtype=jnp.float32)    # (B, J)
    ej1b = ej1h > 0.5
    is_fail = active_any & (ev < 8 * J)
    is_sys = active_any & (ev >= 4 * J) & (ev < 8 * J)
    is_auto = active_any & (ev >= 8 * J) & (ev < 12 * J)
    is_man = active_any & (ev >= 12 * J) & (ev < 16 * J)
    is_complete = active_any[:, None] \
        & (ev[:, None] == kx + jobs_ax[None, :])               # (B, J)
    is_timer = active_any[:, None] \
        & (ev[:, None] == kx + J + jobs_ax[None, :])

    ns = dict(s)
    t_new = s["t"] + dt
    ns["t"] = t_new

    # ---- progress / completion / timers --------------------------------
    progress = jnp.where(computing, dt[:, None], 0.0)          # (B, J)
    ns["work_left"] = s["work_left"] - progress
    ns["useful_work"] = s["useful_work"] + progress
    timer_dec = jnp.where(in_overhead, s["timer"] - dt[:, None], s["timer"])
    ns["phase"] = jnp.where(is_complete, DONE, s["phase"])
    ns["phase"] = jnp.where(is_timer, COMPUTE, ns["phase"])
    ns["timer"] = jnp.where(is_timer, jnp.inf, timer_dec)
    ns["total_time"] = jnp.where(is_complete, t_new[:, None],
                                 s["total_time"])

    # ---- exact per-job run durations ------------------------------------
    fail_j = is_fail[:, None] & ej1b                           # (B, J)
    record = fail_j | is_complete
    run_val = s["cur_run"] + progress
    max_runs = s["run_durations"].shape[2]
    if max_runs:
        slot = jnp.mod(s["n_runs"], max_runs)                  # (B, J)
        kept = jnp.take_along_axis(s["run_durations"], slot[..., None],
                                   axis=2)[..., 0]
        new = jnp.where(record, run_val, kept)
        ns["run_durations"] = s["run_durations"].at[
            rows[:, None], jobs_ax[None, :], slot].set(new)
    ns["n_runs"] = s["n_runs"] + record.astype(jnp.int32)
    ns["cur_run"] = jnp.where(record, 0.0, run_val)

    # ---- failure handling ----------------------------------------------
    f32 = lambda m: m.astype(jnp.float32)
    ns["n_failures"] = s["n_failures"] + f32(fail_j)
    ns["n_systematic_failures"] = s["n_systematic_failures"] \
        + f32(is_sys[:, None] & ej1b)
    ns["n_random_failures"] = s["n_random_failures"] \
        + f32((is_fail & ~is_sys)[:, None] & ej1b)

    diagnosed = is_fail & (u_diag < dp)
    wrong = diagnosed & (u_wrong < du)
    ns["n_undiagnosed"] = s["n_undiagnosed"] \
        + f32((is_fail & ~diagnosed)[:, None] & ej1b)
    ns["n_misdiagnosed"] = s["n_misdiagnosed"] + f32(wrong[:, None] & ej1b)

    run_f = run[rows, ej]                                      # (B, 4)
    sb_f = s["sb"][rows, ej]
    # stacked proportional picks: misdiagnosis target within the failing
    # job's own running set, standby take, working take, spare take
    stacked = jnp.stack([run_f, sb_f, s["fw"], s["fs"]], axis=1)
    uu = jnp.stack([u_cls, u_cls, u_pool, u_pool], axis=1)
    total_p = jnp.maximum(stacked.sum(-1), 1e-30)
    cdf_p = jnp.cumsum(stacked, axis=-1) / total_p[..., None]
    picks = jnp.minimum(
        jnp.sum((uu[..., None] >= cdf_p).astype(jnp.int32), -1), 3)
    pick1h = jax.nn.one_hot(picks, 4, dtype=jnp.float32)       # (B, 4, 4)

    rm1h = jnp.where(wrong[:, None], pick1h[:, 0], _onehot4(cls)) \
        * diagnosed[:, None]                                   # (B, 4)
    ns["run"] = s["run"].at[rows, ej].add(-rm1h)

    # shop entry: a free service slot starts the automated stage at
    # once; a full shop parks the server in the queue lane (by owner)
    cap_eff = jnp.where(cap > 0, cap, jnp.inf)
    shop_active = s["auto"].sum((-2, -1)) + s["man"].sum((-2, -1))  # (B,)
    has_slot = shop_active < cap_eff
    enters = diagnosed & has_slot
    queues = diagnosed & ~has_slot
    ns["auto"] = s["auto"].at[rows, ej].add(rm1h * enters[:, None])
    ns["q"] = s["q"].at[rows, ej].add(rm1h * queues[:, None])
    ns["n_shop_queued"] = s["n_shop_queued"] + f32(queues)

    # replacement waterfall: own standbys -> shared working -> shared
    # spare -> stall (the §II-B priority order, per job)
    sb_tot = sb_f.sum(-1)
    fw_tot = s["fw"].sum(-1)
    fs_tot = s["fs"].sum(-1)
    use_sb = diagnosed & (sb_tot > 0)
    use_fw = diagnosed & ~use_sb & (fw_tot > 0)
    use_fs = diagnosed & ~use_sb & ~use_fw & (fs_tot > 0)
    goes_stall = diagnosed & ~use_sb & ~use_fw & ~use_fs

    take = (pick1h[:, 1] * use_sb[:, None]
            + pick1h[:, 2] * use_fw[:, None]
            + pick1h[:, 3] * use_fs[:, None])
    ns["sb"] = s["sb"].at[rows, ej].add(-pick1h[:, 1] * use_sb[:, None])
    ns["fw"] = s["fw"] - pick1h[:, 2] * use_fw[:, None]
    ns["fs"] = s["fs"] - pick1h[:, 3] * use_fs[:, None]
    ns["run"] = ns["run"].at[rows, ej].add(take)
    ns["n_standby_swaps"] = s["n_standby_swaps"] + f32(use_sb[:, None] & ej1b)
    ns["n_host_selections"] = s["n_host_selections"] \
        + f32((use_fw | use_fs)[:, None] & ej1b)
    ns["n_preemptions"] = s["n_preemptions"] + f32(use_fs[:, None] & ej1b)

    fail_timer = (recovery
                  + jnp.where(use_fw | use_fs, host_sel, 0.0)
                  + jnp.where(use_fs, waiting + preempt_cost, 0.0))
    resolves = is_fail & ~goes_stall
    resolves_j = resolves[:, None] & ej1b
    stall_j = goes_stall[:, None] & ej1b
    ns["timer"] = jnp.where(resolves_j, fail_timer[:, None], ns["timer"])
    ns["phase"] = jnp.where(resolves_j, OVERHEAD, ns["phase"])
    ns["phase"] = jnp.where(stall_j, STALL, ns["phase"])
    ns["stall_start"] = jnp.where(stall_j, t_new[:, None], s["stall_start"])
    ns["recovery_overhead"] = s["recovery_overhead"] \
        + jnp.where(resolves_j, _j(recovery), 0.0)

    # ---- repair completions ---------------------------------------------
    rep1h = _onehot4(cls)
    ns["auto"] = ns["auto"].at[rows, ej].add(-rep1h * is_auto[:, None])
    ns["n_auto_repairs"] = s["n_auto_repairs"] + f32(is_auto)
    escalate = is_auto & (u_esc >= p_auto)
    ns["man"] = s["man"].at[rows, ej].add(
        rep1h * escalate[:, None] - rep1h * is_man[:, None])
    ns["n_manual_repairs"] = s["n_manual_repairs"] + f32(is_man)

    finishes = (is_auto & ~escalate) | is_man
    fail_prob = jnp.where(is_man, man_fail, auto_fail)
    healed = finishes & (u_succ >= fail_prob)
    ns["n_failed_repairs"] = s["n_failed_repairs"] + f32(finishes & ~healed)
    out_cls = jnp.where(healed, cls - (cls % 2), cls)          # bad -> good
    out1h = _onehot4(out_cls)
    spare_origin = out_cls >= 2

    # dispatcher: longest-stalled job anywhere > owner standby refill >
    # origin pool.  The host-selection surcharge applies iff the
    # receiver is NOT the owner that submitted the server (the event
    # engine's membership rule — only original members rejoin free).
    any_stalled = stalled_pre.any(-1)
    k_star = jnp.argmin(jnp.where(stalled_pre, s["stall_start"], jnp.inf),
                        axis=-1)                               # (B,)
    to_stalled = finishes & any_stalled
    k1b = jax.nn.one_hot(k_star, J, dtype=jnp.float32) > 0.5
    to_stalled_j = to_stalled[:, None] & k1b
    surcharge = to_stalled & (k_star != ej)
    ns["run"] = ns["run"].at[rows, k_star].add(
        out1h * to_stalled[:, None])
    unstall_timer = recovery + jnp.where(surcharge, host_sel, 0.0)
    ns["phase"] = jnp.where(to_stalled_j, OVERHEAD, ns["phase"])
    ns["timer"] = jnp.where(to_stalled_j, unstall_timer[:, None],
                            ns["timer"])
    stall_wait = t_new - s["stall_start"][rows, k_star]
    ns["stall_time"] = s["stall_time"] \
        + jnp.where(to_stalled_j, stall_wait[:, None], 0.0)
    ns["n_host_selections"] = ns["n_host_selections"] \
        + f32(surcharge[:, None] & k1b)
    ns["recovery_overhead"] = ns["recovery_overhead"] \
        + jnp.where(to_stalled_j, _j(recovery), 0.0)
    ns["stall_handoffs"] = s["stall_handoffs"] + f32(to_stalled)

    owner_active = s["phase"][rows, ej] != DONE
    sb_owner_tot = ns["sb"][rows, ej].sum(-1)
    to_sb = finishes & ~to_stalled & owner_active \
        & (sb_owner_tot < warm_of(ej))
    to_pool = finishes & ~to_stalled & ~to_sb
    ns["sb"] = ns["sb"].at[rows, ej].add(out1h * to_sb[:, None])
    ns["fw"] = ns["fw"] + out1h * (to_pool & ~spare_origin)[:, None]
    ns["fs"] = ns["fs"] + out1h * (to_pool & spare_origin)[:, None]

    # a departure frees a service slot: admit one queued server,
    # proportionally over the queued (job, class) counts — exact in law
    # vs the event shop's uniform-random admission
    q_flat = ns["q"].reshape(B, 4 * J)
    admit = finishes & (q_flat.sum(-1) > 0)
    pick_q = _pick_cat(q_flat, u_adm)
    qj = (pick_q // 4).astype(jnp.int32)
    qc1h = _onehot4(pick_q % 4) * admit[:, None]
    ns["q"] = ns["q"].at[rows, qj].add(-qc1h)
    ns["auto"] = ns["auto"].at[rows, qj].add(qc1h)

    # ---- histogram bookkeeping for failure/unstall paths ---------------
    # per step each job records at most one recovery/waiting event:
    # a resolved failure (its own), a repair-return unstall, or (below)
    # a completion-release unstall
    ended = resolves_j | to_stalled_j                          # (B, J)
    rec_fail = fail_timer[:, None]
    rec_unst = (stall_wait + unstall_timer)[:, None]
    downtime = jnp.where(resolves_j, rec_fail,
                         jnp.where(to_stalled_j, rec_unst, 0.0))
    acq_fail = (fail_timer - recovery)[:, None]
    acq_unst = (stall_wait + unstall_timer - recovery)[:, None]
    acquire_wait = jnp.where(resolves_j, acq_fail,
                             jnp.where(to_stalled_j, acq_unst, 0.0))

    # ---- job completion: release running + standbys ---------------------
    any_complete = is_complete.any(-1)
    ci = jnp.argmax(is_complete, axis=-1)                      # (B,)
    rel = (ns["run"][rows, ci] + ns["sb"][rows, ci]) \
        * any_complete[:, None]                                # (B, 4)
    ns["run"] = ns["run"].at[rows, ci].multiply(
        jnp.where(any_complete, 0.0, 1.0)[:, None])
    ns["sb"] = ns["sb"].at[rows, ci].multiply(
        jnp.where(any_complete, 0.0, 1.0)[:, None])

    # released servers go to starving jobs first (earliest stall first,
    # one each — the release-watcher semantics), always paying the
    # host-selection surcharge; class picks are proportional over the
    # released batch (documented approximation: the event engine hands
    # the literal pushed server, an exchangeable draw from the same
    # batch).  The remainder lands in the origin pools.
    stalled_now = (ns["phase"] == STALL) & ~is_complete
    rel_rem = rel
    rel_timer = jnp.broadcast_to(
        jnp.asarray(recovery + host_sel, jnp.float32), (B,))
    for r in range(max(J - 1, 0)):
        can = any_complete & stalled_now.any(-1) & (rel_rem.sum(-1) > 0)
        k_r = jnp.argmin(jnp.where(stalled_now, ns["stall_start"],
                                   jnp.inf), axis=-1)
        kr1b = jax.nn.one_hot(k_r, J, dtype=jnp.float32) > 0.5
        can_j = can[:, None] & kr1b
        u_r = jnp.mod(u_rel + r * _PHI, 1.0)
        p1h = _onehot4(_pick_cat(rel_rem, u_r)) * can[:, None]
        rel_rem = rel_rem - p1h
        ns["run"] = ns["run"].at[rows, k_r].add(p1h)
        rel_wait = t_new - ns["stall_start"][rows, k_r]
        ns["phase"] = jnp.where(can_j, OVERHEAD, ns["phase"])
        ns["timer"] = jnp.where(can_j, rel_timer[:, None], ns["timer"])
        ns["stall_time"] = ns["stall_time"] \
            + jnp.where(can_j, rel_wait[:, None], 0.0)
        ns["n_host_selections"] = ns["n_host_selections"] + f32(can_j)
        ns["recovery_overhead"] = ns["recovery_overhead"] \
            + jnp.where(can_j, _j(recovery), 0.0)
        ended = ended | can_j
        downtime = jnp.where(can_j, (rel_wait + rel_timer)[:, None],
                             downtime)
        acquire_wait = jnp.where(
            can_j, (rel_wait + rel_timer - recovery)[:, None],
            acquire_wait)
        stalled_now = stalled_now & ~can_j
    ns["fw"] = ns["fw"] + rel_rem * jnp.asarray([1, 1, 0, 0], jnp.float32)
    ns["fs"] = ns["fs"] + rel_rem * jnp.asarray([0, 0, 1, 1], jnp.float32)

    # ---- streaming per-job histograms -----------------------------------
    if "hist" in s:
        channel_vals = {"run_duration": (run_val, record),
                        "recovery": (downtime, ended),
                        "waiting": (acquire_wait, ended)}
        vals = jnp.stack([channel_vals[ch][0] for ch in hist_channels],
                         axis=2)                               # (B, J, S)
        masks = jnp.stack([channel_vals[ch][1] for ch in hist_channels],
                          axis=2)
        idx = jnp.searchsorted(s["hist_edges"], vals, side="right")
        ns["hist"] = s["hist"].at[
            rows[:, None, None], jobs_ax[None, :, None],
            jnp.arange(len(hist_channels))[None, None, :], idx].add(
            masks.astype(jnp.float32))

    # ---- conservation invariant ----------------------------------------
    tot = (ns["run"].sum((-2, -1)) + ns["sb"].sum((-2, -1))
           + ns["auto"].sum((-2, -1)) + ns["man"].sum((-2, -1))
           + ns["q"].sum((-2, -1)) + ns["fw"].sum(-1) + ns["fs"].sum(-1))
    ns["conservation_err"] = jnp.maximum(
        s["conservation_err"], jnp.abs(tot - s["fleet_total"]))
    return ns


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _mj_params_vector(cluster: Params, jobs: Sequence[JobSpec],
                      ) -> jnp.ndarray:
    base = np.asarray([
        cluster.random_failure_rate, cluster.systematic_failure_rate,
        cluster.recovery_time, cluster.host_selection_time,
        cluster.waiting_time, cluster.auto_repair_time,
        cluster.manual_repair_time, cluster.auto_repair_failure_probability,
        cluster.manual_repair_failure_probability,
        cluster.automated_repair_probability,
        cluster.diagnosis_probability, cluster.diagnosis_uncertainty,
        cluster.preemption_cost, float(cluster.repair_servers),
    ], np.float32)
    warm = np.asarray([float(j.warm_standbys) for j in jobs], np.float32)
    return jnp.asarray(np.concatenate([base, warm]))


def default_max_steps_multijob(cluster: Params,
                               jobs: Sequence[JobSpec],
                               safety: float = 2.0) -> int:
    """Per-job single-job budgets summed (each race event is one step),
    plus head-room for shop-queue churn under a tight capacity."""
    steps = 0
    for spec in jobs:
        p = cluster.replace(job_size=spec.job_size,
                            job_length=spec.job_length,
                            warm_standbys=spec.warm_standbys,
                            repair_servers=0)
        steps += default_max_steps(p, safety)
    return steps


def _mj_chunk_loop(pv: jnp.ndarray, key: jax.Array, P: int, R: int,
                   chunk: int, n_chunks, rem: int, J: int,
                   impl: Optional[str], early_exit: bool,
                   hist_channels: tuple,
                   init_state: Dict[str, jnp.ndarray]):
    """Chunked scan with early exit — the multi-job twin of the
    single-job ``_chunk_loop`` (same chunking, bucketing, and
    common-random-number conventions; see that docstring).  Not jitted
    itself: called from the single-device jit entry
    :func:`_mj_run_chunked` and from inside the ``shard_map`` body of
    :func:`_mj_run_chunked_sharded`."""
    R_draw = _next_pow2(R)

    def scan_body(state, u):
        if P > 1:
            u = jnp.tile(u, (P, 1))
        return _mj_step_u(state, u, pv, J, impl, hist_channels), None

    def run_chunk(state, i, n_steps):
        us = jax.random.uniform(jax.random.fold_in(key, i),
                                (n_steps, R_draw, _N_UNIFORMS),
                                dtype=jnp.float32, minval=1e-12, maxval=1.0)
        if R_draw != R:
            us = us[:, :R]
        state, _ = jax.lax.scan(scan_body, state, us)
        return state

    def chunk_body(carry):
        i, state = carry
        return i + 1, run_chunk(state, i, chunk)

    def cond(carry):
        i, state = carry
        not_done = i < n_chunks
        if early_exit:
            not_done &= jnp.any(state["phase"] != DONE)
        return not_done

    _, state = jax.lax.while_loop(cond, chunk_body,
                                  (jnp.int32(0), init_state))
    if rem:
        def do_rem(s):
            return run_chunk(s, n_chunks, rem)

        if early_exit:
            state = jax.lax.cond(jnp.any(state["phase"] != DONE),
                                 do_rem, lambda s: s, state)
        else:
            state = do_rem(state)
    state["completed"] = (state["phase"] == DONE).astype(jnp.float32)
    state["total_time"] = jnp.where(state["phase"] == DONE,
                                    state["total_time"],
                                    state["t"][:, None])
    return state


@partial(jax.jit, static_argnames=("P", "R", "chunk", "rem", "J", "impl",
                                   "early_exit", "hist_channels"))
def _mj_run_chunked(pv: jnp.ndarray, key: jax.Array, P: int, R: int,
                    chunk: int, n_chunks, rem: int, J: int,
                    impl: Optional[str], early_exit: bool,
                    hist_channels: tuple,
                    init_state: Dict[str, jnp.ndarray]):
    """Single-device jit entry over :func:`_mj_chunk_loop`."""
    return _mj_chunk_loop(pv, key, P, R, chunk, n_chunks, rem, J, impl,
                          early_exit, hist_channels, init_state)


@partial(jax.jit, static_argnames=("mesh", "P", "R", "chunk", "rem", "J",
                                   "impl", "early_exit", "hist_channels"))
def _mj_run_chunked_sharded(pv: jnp.ndarray, keys: jax.Array, P: int,
                            R: int, chunk: int, n_chunks, rem: int, J: int,
                            impl: Optional[str], early_exit: bool,
                            hist_channels: tuple,
                            init_state: Dict[str, jnp.ndarray], *, mesh):
    """Replica-sharded twin of :func:`_mj_run_chunked` via ``shard_map``.

    Same contract as the single-job
    :func:`repro.core.vectorized._run_chunked_sharded`: state leaves
    reshape ``(P*R, ...) -> (P, R, ...)`` and shard the replica axis
    over the 1-D mesh, each shard runs :func:`_mj_chunk_loop` with its
    own folded key, ``hist_edges`` rides along replicated, no
    collectives (shards early-exit independently), and the ``out_specs``
    concatenation is the cross-device merge.  A 1-device mesh is
    bit-identical to :func:`_mj_run_chunked`.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.parallel import sharding as rsharding

    n_shards = mesh.shape[rsharding.REPLICA_AXIS]
    R_loc = R // n_shards
    unbatched = {k: init_state[k] for k in _UNBATCHED if k in init_state}
    state = {k: v.reshape((P, R) + v.shape[1:])
             for k, v in init_state.items() if k not in unbatched}
    rspec = PartitionSpec(None, rsharding.REPLICA_AXIS)
    pv2 = pv.reshape((P, R, pv.shape[-1]))
    out_specs = {k: rspec for k in list(state) + ["completed"]}

    def body(keys_s, pv_s, n_chunks_s, unbatched_s, state_s):
        flat = {k: v.reshape((P * R_loc,) + v.shape[2:])
                for k, v in state_s.items()}
        flat.update(unbatched_s)
        out = _mj_chunk_loop(pv_s.reshape(P * R_loc, pv_s.shape[-1]),
                             keys_s[0], P, R_loc, chunk, n_chunks_s, rem,
                             J, impl, early_exit, hist_channels, flat)
        for k in unbatched_s:
            out.pop(k)
        return {k: v.reshape((P, R_loc) + v.shape[1:])
                for k, v in out.items()}

    out = shard_map(
        body, mesh=mesh,
        in_specs=(PartitionSpec(rsharding.REPLICA_AXIS), rspec,
                  PartitionSpec(),
                  {k: PartitionSpec() for k in unbatched},
                  rsharding.replica_state_specs(state)),
        out_specs=out_specs, check_rep=False,
    )(keys, pv2, n_chunks, unbatched, state)
    out = {k: v.reshape((P * R,) + v.shape[2:]) for k, v in out.items()}
    out.update(unbatched)
    return out


def compile_cache_size() -> Optional[int]:
    """Compiled-program cache entries of the multi-job chunked driver
    (None when jax's private cache introspection is unavailable)."""
    fn = getattr(_mj_run_chunked, "_cache_size", None)
    return fn() if callable(fn) else None


def shard_compile_cache_size() -> Optional[int]:
    """Compiled-program cache entries of the *sharded* multi-job driver
    (same contract as :func:`compile_cache_size`)."""
    fn = getattr(_mj_run_chunked_sharded, "_cache_size", None)
    return fn() if callable(fn) else None


def _unsupported_error(cluster: Params, jobs) -> ValueError:
    reasons = unsupported_reasons_multijob(cluster, jobs) \
        or ["unknown reason — please report"]
    return ValueError(
        "this multi-job cluster is outside the CTMC envelope: "
        + "; ".join(reasons)
        + "; use core.multijob.simulate_multijob (or engine='auto') "
        "instead")


def _extract_point(state, rows, J: int, channels: tuple,
                   ) -> Dict[str, object]:
    """Per-point result: a list of single-job-compatible array dicts
    (one per job — ``metrics.aggregate_arrays`` consumes them directly)
    plus the cluster-level lanes."""
    per_job: List[Dict[str, np.ndarray]] = []
    edges = (np.asarray(state["hist_edges"], np.float64)
             if "hist" in state and channels else None)
    for j in range(J):
        d: Dict[str, np.ndarray] = {}
        for m in _MJ_JOB_METRICS:
            d[m] = np.asarray(state[m][rows, j])
        d["lost_work"] = np.zeros_like(d["useful_work"])
        d["completed"] = np.asarray(
            state["phase"][rows, j] == DONE, np.float32)
        d["run_durations"] = np.asarray(state["run_durations"][rows, j])
        d["n_runs"] = np.asarray(state["n_runs"][rows, j])
        d["cur_run"] = np.asarray(state["cur_run"][rows, j])
        if edges is not None:
            hist = np.asarray(state["hist"][rows, j], np.float64)
            for ch_i, ch in enumerate(channels):
                d[f"hist_{ch}"] = hist[:, ch_i]
            d["hist_edges"] = edges
        per_job.append(d)
    out: Dict[str, object] = {"per_job": per_job}
    for m in _MJ_CLUSTER_METRICS:
        out[m] = np.asarray(state[m][rows])
    tt = np.stack([d["total_time"] for d in per_job], axis=-1)
    out["makespan"] = tt.max(-1)
    out["completed"] = np.asarray(
        np.prod([d["completed"] for d in per_job], axis=0), np.float32)
    return out


def _wrap_single_job(arrays: Dict[str, np.ndarray]) -> Dict[str, object]:
    """Adapt a single-job CTMC result dict to the multi-job shape (the
    J=1, unbounded-shop reduction path)."""
    R = len(arrays["total_time"])
    zeros = np.zeros(R, np.float32)
    out: Dict[str, object] = {"per_job": [arrays]}
    out["makespan"] = np.asarray(arrays["total_time"])
    out["completed"] = np.asarray(arrays.get("completed", zeros + 1.0))
    for m in ("n_auto_repairs", "n_manual_repairs", "n_failed_repairs"):
        out[m] = np.asarray(arrays.get(m, zeros))
    for m in ("stall_handoffs", "n_shop_queued", "conservation_err"):
        out[m] = zeros
    return out


def simulate_multijob_ctmc_sweep(
        points: Sequence[Tuple[Params, Sequence[JobSpec]]],
        n_replicas: int = 1024, seed: int = 0,
        max_steps: Optional[int] = None,
        impl: Optional[str] = None,
        chunk_steps: Optional[int] = None,
        early_exit: bool = True,
        bucketed: bool = True,
        max_runs: Optional[int] = None,
        shards: Optional[int] = None) -> List[Dict[str, object]]:
    """Batched multi-job sweep: one compiled program per job-count group.

    ``points`` is a sequence of ``(cluster Params, [JobSpec, ...])``
    pairs.  Points sharing a job count J (the only static structure key)
    — regardless of job sizes, lengths, rates, pool sizes, or shop
    capacity, all of which are traced — run as ONE flat (P*R,) batch
    through one XLA compilation, with pow2 shape bucketing and common
    random numbers exactly like the single-job sweep.

    Returns one dict per point: ``per_job`` is a list of
    single-job-compatible array dicts (feed each to
    ``metrics.aggregate_arrays``), plus cluster lanes ``makespan``,
    ``stall_handoffs``, the shared-shop counters, ``n_shop_queued``,
    ``conservation_err`` (max per-step deviation of the server-count
    invariant — exactly 0.0 in a correct run), and the all-jobs
    ``completed`` flag.

    Reduction: 1-job points with ``repair_servers == 0`` route through
    the single-job engine (bit-identical to a direct
    :func:`repro.core.vectorized.simulate_ctmc_sweep` call, same compile
    cache) — the multi-job program is only built when the multi-job
    machinery is actually needed.
    """
    from . import vectorized as vz

    points = [(c, tuple(js)) for c, js in points]
    for c, js in points:
        if not supports_multijob(c, js):
            raise _unsupported_error(c, js)
        # the cluster-level job fields are unused in multi-job mode;
        # validate through a per-job surrogate (the event engine's
        # Coordinator params are built the same way)
        c.replace(job_size=js[0].job_size, job_length=js[0].job_length,
                  warm_standbys=js[0].warm_standbys).validate()
        total_needed = sum(j.job_size + j.warm_standbys for j in js)
        if c.working_pool_size < total_needed:
            raise ValueError(
                f"working pool {c.working_pool_size} cannot host "
                f"{len(js)} jobs needing {total_needed}")
    if not points:
        return []
    if len({c.histogram for c, _ in points}) > 1:
        raise ValueError(
            "all points of a batched multi-job sweep must share the same "
            "Params.histogram spec (the in-scan accumulator layout is "
            "per-batch); split the grid or unify the spec")

    results: List[Optional[Dict[str, object]]] = [None] * len(points)
    channels = _selected_channels(points[0][0].histogram)
    # replica sharding + kernel dispatch resolve exactly like the
    # single-job sweep: explicit args win, else the (single) Params
    # value — a mixed engine_shards grid raises, mixed kernel impls
    # split the compile groups
    shards = vz._resolve_shards(shards, [c for c, _ in points])

    # group: the single-job reduction, then one group per job count
    single_idx = [i for i, (c, js) in enumerate(points)
                  if len(js) == 1 and c.repair_servers == 0]
    if single_idx:
        sp = [points[i][0].replace(job_size=points[i][1][0].job_size,
                                   job_length=points[i][1][0].job_length,
                                   warm_standbys=points[i][1][0]
                                   .warm_standbys)
              for i in single_idx]
        outs = vz.simulate_ctmc_sweep(
            sp, n_replicas=n_replicas, seed=seed, max_steps=max_steps,
            impl=impl, chunk_steps=chunk_steps, early_exit=early_exit,
            bucketed=bucketed, max_runs=max_runs, shards=shards)
        for i, arr in zip(single_idx, outs):
            results[i] = _wrap_single_job(arr)

    groups: Dict[tuple, list] = {}
    for i, (c, js) in enumerate(points):
        if results[i] is None:
            impl_eff = impl if impl is not None else c.event_race_impl
            groups.setdefault((len(js), impl_eff), []).append(i)
    for (J, impl_eff), idxs in groups.items():
        pts = [points[i] for i in idxs]
        P, R = len(pts), n_replicas
        steps = max_steps or max(default_max_steps_multijob(c, js)
                                 for c, js in pts)
        chunk = min(chunk_steps or DEFAULT_CHUNK_STEPS, steps)
        P_run, R_run = ((_next_pow2(P), _next_pow2(R)) if bucketed
                        else (P, R))
        if bucketed and max_steps is None:
            steps = -(-steps // chunk) * chunk
        mr = (max(c.max_run_records for c, _ in pts) if max_runs is None
              else max_runs)
        pv = jnp.stack([_mj_params_vector(c, js) for c, js in pts])
        if P_run != P:
            pv = jnp.pad(pv, ((0, P_run - P), (0, 0)), mode="edge")
        pv_flat = jnp.repeat(pv, R_run, axis=0)
        init_state = _mj_initial_state_batch(pts, R, mr)
        if (P_run, R_run) != (P, R):
            init_state = _mj_bucket_pad(init_state, P, R, P_run, R_run)
        run_args = (P_run, R_run, chunk, jnp.int32(steps // chunk),
                    steps % chunk, J, impl_eff, early_exit, channels,
                    init_state)
        key = jax.random.PRNGKey(seed)
        if shards:
            from repro.parallel import sharding as rsharding
            out = _mj_run_chunked_sharded(
                pv_flat, rsharding.shard_keys(key, shards), *run_args,
                mesh=vz._shard_mesh(shards, R_run))
        else:
            out = _mj_run_chunked(pv_flat, key, *run_args)
        for jg, i in enumerate(idxs):
            rows = (slice(jg * R_run, jg * R_run + R) if R_run == R
                    else np.arange(R) + jg * R_run)
            results[i] = _extract_point(out, rows, J, channels)
    return results


def simulate_multijob_ctmc(cluster: Params, jobs: Sequence[JobSpec],
                           n_replicas: int = 1024, seed: int = 0,
                           **kw) -> Dict[str, object]:
    """Single-point convenience wrapper over the batched sweep."""
    return simulate_multijob_ctmc_sweep([(cluster, tuple(jobs))],
                                        n_replicas=n_replicas, seed=seed,
                                        **kw)[0]
