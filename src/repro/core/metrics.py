"""Output metrics of a simulation run (paper §III-B Outputs).

AIReSim reports: (1) total time to train the job, (2) failure counts split
random/systematic, (3) preemptions, (4) repair counts (auto/manual), and
(5) run durations between restarts — with mean/median/std/percentiles over
replications.  We add stall time, host selections, retirements, and wasted
(recovery/lost) time, which the capacity-planning case study needs.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .histograms import (HIST_CHANNELS, Histogram, HistogramSpec,
                         percentiles_per_row)


@dataclass
class RunResult:
    """Raw outputs of a single simulation replication."""

    total_time: float = 0.0            # minutes from t=0 to job completion
    useful_work: float = 0.0           # == params.job_length on success
    n_failures: int = 0
    n_random_failures: int = 0
    n_systematic_failures: int = 0
    n_undiagnosed: int = 0
    n_misdiagnosed: int = 0
    n_preemptions: int = 0             # spare-pool draws
    n_auto_repairs: int = 0
    n_manual_repairs: int = 0
    n_failed_repairs: int = 0          # silent repair failures
    n_host_selections: int = 0         # full host-selection rounds (excl. t=0)
    n_standby_swaps: int = 0
    n_retired: int = 0
    #: CTMC engine only: diagnosed failures that found the repair-slot
    #: lane full (see ``Params.repair_slots``).  The event engine has no
    #: slot bound, so this is exactly zero on the event path.
    n_repair_overflow: int = 0
    #: correlated-failure counters (see repro.core.faultdomains): shock
    #: events, servers killed by shocks/campaign kills (all compartments,
    #: in-shop re-breaks included), and campaign schedule entries fired
    n_domain_shocks: int = 0
    n_shock_killed: int = 0
    n_campaign_events: int = 0
    #: per-domain shock counts ([] unless Params.fault_domains is set)
    domain_shocks: List[int] = field(default_factory=list)
    stall_time: float = 0.0            # job waiting with zero capacity
    recovery_overhead: float = 0.0     # sum of recovery_time charges
    lost_work: float = 0.0             # checkpoint-rollback loss (extension)
    #: wall-clock minutes spent writing periodic checkpoints
    #: (``Params.checkpoint_cost`` per completed write; partial for a
    #: write a shock interrupted)
    checkpoint_overhead: float = 0.0
    run_durations: List[float] = field(default_factory=list)
    #: per-failure downtime (failure -> compute restart; ETTR) and the
    #: replacement-acquisition part of it alone — the event-engine
    #: sources of the "recovery" / "waiting" histogram channels
    recovery_durations: List[float] = field(default_factory=list)
    waiting_durations: List[float] = field(default_factory=list)
    timed_out: bool = False            # hit max_sim_time before completing

    @property
    def overhead_fraction(self) -> float:
        """Fraction of wall time not spent on useful work."""
        if self.total_time <= 0:
            return 0.0
        return 1.0 - self.useful_work / self.total_time

    @property
    def effective_utilization(self) -> float:
        return 1.0 - self.overhead_fraction

    @property
    def goodput(self) -> float:
        """Useful work per wall-clock minute — the operator-facing
        objective (Meta's "Revisiting Reliability" framing): 1.0 means
        every minute trained; rollback (``lost_work``), checkpoint
        writes, recovery, and stalls all pull it down."""
        if self.total_time <= 0:
            return 0.0
        return self.useful_work / self.total_time

    @property
    def goodput_samples(self) -> List[float]:
        """The ``goodput`` histogram channel's source: one realized
        goodput sample per *finished* job (timed-out runs record
        nothing, matching the CTMC engine's record-at-completion)."""
        if self.timed_out or self.total_time <= 0:
            return []
        return [self.useful_work / self.total_time]

    @property
    def mean_run_duration(self) -> float:
        return float(np.mean(self.run_durations)) if self.run_durations else 0.0

    @property
    def n_incomplete(self) -> int:
        """1 if this replication hit max_sim_time (or, on the CTMC
        engine, the step budget) before finishing the job — the scalar
        twin of ``timed_out`` so truncation shows up in aggregate stats
        and sweep CSV columns, not just a RuntimeWarning."""
        return int(self.timed_out)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["mean_run_duration"] = self.mean_run_duration
        d["overhead_fraction"] = self.overhead_fraction
        d["goodput"] = self.goodput
        d["n_incomplete"] = self.n_incomplete
        for k in ("run_durations", "recovery_durations", "waiting_durations",
                  "domain_shocks"):
            del d[k]
        return d


#: histogram channel -> RunResult list holding its raw values
_CHANNEL_SOURCES = {"run_duration": "run_durations",
                    "recovery": "recovery_durations",
                    "waiting": "waiting_durations",
                    "goodput": "goodput_samples"}


#: metric -> extractor used for aggregate statistics
_SCALAR_METRICS = (
    "total_time", "n_failures", "n_random_failures", "n_systematic_failures",
    "n_preemptions", "n_auto_repairs", "n_manual_repairs", "n_failed_repairs",
    "n_host_selections", "n_standby_swaps", "n_retired", "n_undiagnosed",
    "n_misdiagnosed", "n_repair_overflow", "n_domain_shocks",
    "n_shock_killed", "n_campaign_events", "n_incomplete", "stall_time",
    "recovery_overhead", "lost_work", "checkpoint_overhead",
    "mean_run_duration", "overhead_fraction", "goodput",
)

_PERCENTILES = (25, 50, 75, 90, 99)
#: histogram-backed stats add the deep tail (unbounded run counts make
#: p99.9 meaningful); keys stay numeric for CSV column naming
_HIST_PERCENTILES = (25, 50, 75, 90, 99, 99.9)
#: the per-replica tail percentile whose cross-replica spread is
#: surfaced as the ``{channel}_p99_replica`` dispersion Stat
REPLICA_TAIL_PERCENTILE = 99


@dataclass(frozen=True)
class Stat:
    mean: float
    median: float
    std: float
    minimum: float
    maximum: float
    percentiles: Dict[int, float]

    @classmethod
    def of(cls, xs: Sequence[float]) -> "Stat":
        a = np.asarray(list(xs), dtype=np.float64)
        if a.size == 0:
            # empty inputs (empty sweeps, zero recorded runs) must yield
            # a well-formed NaN Stat, never raise from np.percentile
            nan = float("nan")
            return cls(nan, nan, nan, nan, nan, {p: nan for p in _PERCENTILES})
        return cls(
            mean=float(a.mean()),
            median=float(np.median(a)),
            std=float(a.std(ddof=1)) if a.size > 1 else 0.0,
            minimum=float(a.min()),
            maximum=float(a.max()),
            percentiles={p: float(np.percentile(a, p)) for p in _PERCENTILES},
        )

    @classmethod
    def from_histogram(cls, h: Histogram) -> "Stat":
        """Distribution statistics from accumulated bin counts.

        Percentiles (incl. p99.9) are exact to one bin width; mean/std
        use geometric bin midpoints.  An empty histogram yields the same
        NaN-filled Stat as an empty sequence.
        """
        if h.total == 0:
            nan = float("nan")
            return cls(nan, nan, nan, nan, nan,
                       {p: nan for p in _HIST_PERCENTILES})
        return cls(
            mean=h.mean(),
            median=h.percentile(50),
            std=h.std(),
            minimum=h.minimum(),
            maximum=h.maximum(),
            percentiles={p: h.percentile(p) for p in _HIST_PERCENTILES},
        )

    @property
    def iqr(self) -> float:
        """Interquartile range (p75 - p25) — the robust spread measure
        the dispersion stats (``{channel}_p99_replica``) are read with:
        e.g. ``stats["recovery_p99_replica"].iqr`` is the IQR of
        per-replica p99 ETTR across replicas."""
        nan = float("nan")
        return (self.percentiles.get(75, nan)
                - self.percentiles.get(25, nan))

    def ci95_halfwidth(self, n: int) -> float:
        if n <= 1 or math.isnan(self.std):
            return 0.0
        return 1.96 * self.std / math.sqrt(n)


def histograms_from_results(results: Sequence[RunResult],
                            spec: Optional[HistogramSpec],
                            ) -> Dict[str, Histogram]:
    """Pooled per-channel histograms from event-engine per-run lists.

    This is the pure-numpy reference accumulator: the CTMC scan fills
    the identical bin layout in compiled code, so the two engines'
    distributions are directly comparable bin by bin.
    """
    if spec is None:
        return {}
    out: Dict[str, Histogram] = {}
    for ch in spec.channels:
        h = Histogram(spec)
        for r in results:
            h.add(getattr(r, _CHANNEL_SOURCES[ch]))
        out[ch] = h
    return out


def histograms_from_arrays(arrays: Dict[str, np.ndarray],
                           ) -> Dict[str, Histogram]:
    """Pooled per-channel histograms from CTMC per-replica bin counts."""
    if "hist_edges" not in arrays:
        return {}
    edges = np.asarray(arrays["hist_edges"], np.float64)
    out: Dict[str, Histogram] = {}
    for ch in HIST_CHANNELS:
        key = f"hist_{ch}"
        if key in arrays:
            counts = np.asarray(arrays[key], np.float64).sum(axis=0)
            out[ch] = Histogram(edges, counts)
    return out


def aggregate(results: Sequence[RunResult],
              histogram: Optional[HistogramSpec] = None,
              histograms: Optional[Dict[str, Histogram]] = None,
              ) -> Dict[str, Stat]:
    """Cross-replication statistics for every scalar output metric.

    With a :class:`HistogramSpec`, also reports ``{channel}_dist`` Stats
    (percentiles incl. p99.9, exact to one bin width) from the pooled
    per-run lists — the event-engine counterpart of the CTMC engine's
    streaming histograms — plus ``{channel}_p99_replica`` dispersion
    Stats: each replication's own p99 (binned through the same layout
    the CTMC engine uses, so the stat is engine-comparable), aggregated
    across replications; read the cross-replica IQR off ``.iqr``.
    Callers that already pooled (the backend) pass the prebuilt
    ``histograms`` dict to skip re-binning.
    """
    out: Dict[str, Stat] = {}
    for name in _SCALAR_METRICS:
        out[name] = Stat.of([float(getattr(r, name)) for r in results])
    out["completed"] = Stat.of([0.0 if r.timed_out else 1.0
                                for r in results])
    # run durations pooled across replications; the event engine keeps
    # full per-run lists, so nothing is ever truncated on this path
    pooled: List[float] = []
    for r in results:
        pooled.extend(r.run_durations)
    out["run_duration_pooled"] = Stat.of(pooled)
    out["run_duration_truncated"] = Stat.of([0.0] * len(results))
    if histograms is None:
        histograms = histograms_from_results(results, histogram)
    for ch, h in histograms.items():
        out[f"{ch}_dist"] = Stat.from_histogram(h)
        # cross-replica dispersion: each replication's own p99,
        # estimated through the same bin layout the CTMC engine uses so
        # the stat means the same thing on both engines
        per = []
        for r in results:
            vals = getattr(r, _CHANNEL_SOURCES[ch])
            if vals:
                per.append(Histogram.from_values(h.edges, vals)
                           .percentile(REPLICA_TAIL_PERCENTILE))
        out[f"{ch}_p{REPLICA_TAIL_PERCENTILE}_replica"] = Stat.of(per)
    return out


def aggregate_arrays(arrays: Dict[str, np.ndarray],
                     histograms: Optional[Dict[str, Histogram]] = None,
                     ) -> Dict[str, Stat]:
    """:func:`aggregate`-compatible statistics from per-replica arrays.

    Input is the ``{metric: (R,) ndarray}`` dict produced by the
    vectorized CTMC engine (:mod:`repro.core.vectorized`).  Metrics
    absent from the arrays are filled with zeros — currently only
    ``n_retired``, which is exactly zero inside the CTMC envelope
    (``supports`` requires ``retirement_threshold == 0``).  Derived
    metrics are computed from the raw arrays:

      * ``overhead_fraction``  = 1 - useful_work / total_time
      * ``mean_run_duration``  — exact: the engine's per-run records
        satisfy sum(records) = useful_work + lost_work - cur_run, so the
        per-replica mean interval is that sum over ``n_runs`` even when
        the ring buffer overwrote old records.

    ``run_duration_pooled`` pools every surviving recorded interval from
    the ``run_durations`` (R, max_runs) ring buffers — the same pooling
    the event engine applies to its per-run lists — and
    ``run_duration_truncated`` counts the records the cap overwrote
    (raise ``Params.max_run_records`` to keep them).

    Streaming-histogram channels (``hist_{channel}`` (R, n_bins+2)
    per-replica counts + shared ``hist_edges``) pool across replicas into
    ``{channel}_dist`` Stats whose percentiles are exact to one bin width
    with **no** run-count bound — the trustworthy distribution source
    whenever ``run_duration_truncated`` is nonzero.  A prebuilt
    ``histograms`` dict (the backend's) skips re-pooling.  The raw
    per-replica counts additionally yield ``{channel}_p99_replica``
    dispersion Stats (each replica's own p99 via the vectorized
    :func:`repro.core.histograms.percentiles_per_row`; ``.iqr`` is the
    cross-replica IQR) — pooling first would erase that spread.

    Legacy fallback: arrays lacking the run-duration records (foreign
    producers) degrade to the old total_time/(n_failures+1)
    approximation for both run-duration statistics.
    """
    some = next(iter(arrays.values()))
    R = len(some)
    zeros = np.zeros(R, dtype=np.float64)
    total_time = np.asarray(arrays["total_time"], np.float64)
    safe_total = np.maximum(total_time, 1e-12)
    derived = {
        "overhead_fraction": np.where(
            total_time > 0,
            1.0 - np.asarray(arrays["useful_work"], np.float64) / safe_total,
            0.0),
        "goodput": np.where(
            total_time > 0,
            np.asarray(arrays["useful_work"], np.float64) / safe_total,
            0.0),
    }
    if "completed" in arrays:
        # per-replica truncation indicator: the scalar twin of the
        # backend's step-budget RuntimeWarning (satellite of ISSUE 6)
        derived["n_incomplete"] = 1.0 - np.asarray(arrays["completed"],
                                                   np.float64)
    exact = "run_durations" in arrays and "n_runs" in arrays
    if exact:
        buf = np.asarray(arrays["run_durations"], np.float64)
        n_runs = np.asarray(arrays["n_runs"], np.int64)
        max_runs = buf.shape[1]
        n_valid = np.minimum(n_runs, max_runs)
        valid = np.arange(max_runs)[None, :] < n_valid[:, None]
        recorded_total = (
            np.asarray(arrays["useful_work"], np.float64)
            + np.asarray(arrays.get("lost_work", zeros), np.float64)
            - np.asarray(arrays.get("cur_run", zeros), np.float64))
        derived["mean_run_duration"] = np.where(
            n_runs > 0, recorded_total / np.maximum(n_runs, 1), 0.0)
        # max_runs=0 means recording was compiled out: pool the (still
        # exact) per-replica means instead of individual intervals
        pooled = buf[valid] if max_runs else derived["mean_run_duration"]
        truncated = (n_runs - n_valid).astype(np.float64)
    else:
        derived["mean_run_duration"] = total_time / (
            np.asarray(arrays["n_failures"], np.float64) + 1.0)
        pooled = derived["mean_run_duration"]
        truncated = zeros
    out: Dict[str, Stat] = {}
    for name in _SCALAR_METRICS:
        if name in arrays:
            xs = np.asarray(arrays[name], np.float64)
        elif name in derived:
            xs = derived[name]
        else:
            xs = zeros
        out[name] = Stat.of(xs)
    if "completed" in arrays:   # fraction of replicas that finished the
        # job inside the step budget (CTMC) — parity with timed_out
        out["completed"] = Stat.of(np.asarray(arrays["completed"],
                                              np.float64))
    out["run_duration_pooled"] = Stat.of(pooled)
    out["run_duration_truncated"] = Stat.of(truncated)
    if histograms is None:
        histograms = histograms_from_arrays(arrays)
    for ch, h in histograms.items():
        out[f"{ch}_dist"] = Stat.from_histogram(h)
    if "hist_edges" in arrays:
        # cross-replica dispersion of distribution tails: vectorized
        # per-replica percentiles straight from the raw (R, n_bins + 2)
        # counts (pooling first would erase run-to-run spread)
        edges = np.asarray(arrays["hist_edges"], np.float64)
        for ch in HIST_CHANNELS:
            key = f"hist_{ch}"
            if key in arrays:
                per = percentiles_per_row(edges, arrays[key],
                                          REPLICA_TAIL_PERCENTILE)
                out[f"{ch}_p{REPLICA_TAIL_PERCENTILE}_replica"] = Stat.of(
                    per[np.isfinite(per)])
    return out


def pool_histograms(hist_dicts: Sequence[Dict[str, Histogram]],
                    ) -> Dict[str, Histogram]:
    """Merge per-channel histogram dicts by summing bin counts.

    The multi-job engines report one histogram dict per job; pooling
    them gives the fleet-level ETTF/recovery/waiting distributions (all
    dicts share the cluster's single ``Params.histogram`` layout)."""
    out: Dict[str, Histogram] = {}
    for d in hist_dicts:
        for ch, h in d.items():
            out[ch] = out[ch].merge(h) if ch in out else Histogram(
                h.edges, h.counts)
    return out


#: fleet-level (R,) lanes of a multi-job CTMC point dict
_MJ_FLEET_METRICS = ("makespan", "stall_handoffs", "n_auto_repairs",
                     "n_manual_repairs", "n_failed_repairs",
                     "n_shop_queued", "conservation_err", "completed")


def aggregate_multijob_arrays(point: Dict[str, Any],
                              ) -> Dict[str, Any]:
    """Per-job + fleet-pooled statistics for one multi-job CTMC point.

    ``point`` is one element of
    :func:`repro.core.vectorized_multijob.simulate_multijob_ctmc_sweep`'s
    return: per-job array dicts (each :func:`aggregate_arrays`-shaped)
    plus cluster-level (R,) lanes.  Returns::

        {"per_job": [Stat dict per job],
         "fleet":   {makespan, shop counters, stall_handoffs,
                     n_shop_queued, conservation_err, completed,
                     fleet_n_failures, fleet_stall_time,
                     fleet_useful_work, {channel}_dist, ...},
         "histograms": fleet-pooled {channel: Histogram},
         "per_job_histograms": [{channel: Histogram} per job]}

    Fleet sums are per-replication (summed across jobs, then aggregated
    across replicas), so their Stats carry real cross-replica spread.
    """
    per_job_hists = [histograms_from_arrays(d) for d in point["per_job"]]
    per_job = [aggregate_arrays(d, histograms=h)
               for d, h in zip(point["per_job"], per_job_hists)]
    fleet: Dict[str, Stat] = {}
    for name in _MJ_FLEET_METRICS:
        fleet[name] = Stat.of(np.asarray(point[name], np.float64))
    for pooled_name, src in (("fleet_n_failures", "n_failures"),
                             ("fleet_stall_time", "stall_time"),
                             ("fleet_useful_work", "useful_work")):
        tot = np.sum([np.asarray(d[src], np.float64)
                      for d in point["per_job"]], axis=0)
        fleet[pooled_name] = Stat.of(tot)
    pooled = pool_histograms(per_job_hists)
    for ch, h in pooled.items():
        fleet[f"{ch}_dist"] = Stat.from_histogram(h)
    return {"per_job": per_job, "fleet": fleet, "histograms": pooled,
            "per_job_histograms": per_job_hists}


def summarize(results: Sequence[RunResult]) -> Dict[str, float]:
    """Flat {metric: mean} view — convenient for sweep tables."""
    agg = aggregate(results)
    return {name: stat.mean for name, stat in agg.items()}
