"""Experiment harness: one-way and two-way parameter sweeps (paper §III-D).

The paper's user-facing API:

    OneWaySweep("Systematic Failure Fraction",
                "systematic_failure_fraction", [0.1, 0.2, 0.3])

Each sweep point runs ``n_replications`` independent simulations and
aggregates the paper's output metrics.  TwoWaySweep crosses two parameter
ranges (the paper's evaluation crosses every knob with working_pool_size).
Results can be dumped as CSV or JSON; a yaml experiment file is supported
via :func:`load_experiment`.

Special virtual parameter ``systematic_failure_rate_multiplier`` sets the
systematic rate as a multiple of the (possibly swept) random rate, the way
Table I expresses it.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from .metrics import RunResult, Stat, aggregate
from .params import Params
from .simulation import simulate

#: sweep-table columns (means over replications)
DEFAULT_STATS = ("total_time", "n_failures", "n_random_failures",
                 "n_systematic_failures", "n_preemptions", "n_auto_repairs",
                 "n_manual_repairs", "n_host_selections", "stall_time",
                 "overhead_fraction", "mean_run_duration")


def _apply_param(params: Params, name: str, value: Any) -> Params:
    """Set a (possibly virtual) parameter on a Params copy."""
    if name == "systematic_failure_rate_multiplier":
        return params.replace(
            systematic_failure_rate=value * params.random_failure_rate)
    if not hasattr(params, name):
        raise ValueError(f"unknown parameter {name!r}")
    # preserve int-ness of count-typed fields
    current = getattr(params, name)
    if isinstance(current, int) and not isinstance(current, bool):
        value = int(value)
    return params.replace(**{name: value})


@dataclass
class SweepPoint:
    values: Dict[str, Any]
    results: List[RunResult]
    stats: Dict[str, Stat]

    def row(self, columns: Sequence[str] = DEFAULT_STATS) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.values)
        for c in columns:
            out[c] = self.stats[c].mean
        out["total_time_ci95"] = self.stats["total_time"].ci95_halfwidth(
            len(self.results))
        return out


@dataclass
class SweepResult:
    name: str
    parameter_names: List[str]
    points: List[SweepPoint]

    def to_rows(self, columns: Sequence[str] = DEFAULT_STATS) -> List[Dict[str, Any]]:
        return [p.row(columns) for p in self.points]

    def write_csv(self, path: str, columns: Sequence[str] = DEFAULT_STATS) -> None:
        rows = self.to_rows(columns)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            writer.writeheader()
            writer.writerows(rows)

    def write_json(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({
                "name": self.name,
                "parameters": self.parameter_names,
                "rows": self.to_rows(),
            }, f, indent=2)

    def column(self, metric: str) -> List[float]:
        return [p.stats[metric].mean for p in self.points]


class OneWaySweep:
    """Vary one parameter over a list of values (paper's OneWaySweep)."""

    def __init__(self, title: str, parameter: str, values: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0):
        self.title = title
        self.parameter = parameter
        self.values = list(values)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        points = []
        for i, v in enumerate(self.values):
            if progress:
                progress(f"{self.title}: {self.parameter}={v}")
            p = _apply_param(self.base_params, self.parameter, v)
            # common random numbers across points: same seed per replication
            results = simulate(p, self.n_replications, base_seed=self.base_seed)
            points.append(SweepPoint({self.parameter: v}, results,
                                     aggregate(results)))
        return SweepResult(self.title, [self.parameter], points)


class TwoWaySweep:
    """Cross two parameter ranges (the paper's evaluation design)."""

    def __init__(self, title: str, parameter_a: str, values_a: Sequence[Any],
                 parameter_b: str, values_b: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0):
        self.title = title
        self.parameter_a, self.values_a = parameter_a, list(values_a)
        self.parameter_b, self.values_b = parameter_b, list(values_b)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        points = []
        for va in self.values_a:
            for vb in self.values_b:
                if progress:
                    progress(f"{self.title}: {self.parameter_a}={va}, "
                             f"{self.parameter_b}={vb}")
                p = _apply_param(self.base_params, self.parameter_a, va)
                p = _apply_param(p, self.parameter_b, vb)
                results = simulate(p, self.n_replications,
                                   base_seed=self.base_seed)
                points.append(SweepPoint(
                    {self.parameter_a: va, self.parameter_b: vb},
                    results, aggregate(results)))
        return SweepResult(self.title,
                           [self.parameter_a, self.parameter_b], points)


def load_experiment(path: str) -> List[Any]:
    """Build sweeps from a yaml/json experiment file.

    Schema::

        base_params: {recovery_time: 20, ...}
        n_replications: 5
        sweeps:
          - {title: ..., parameter: ..., values: [...]}                    # one-way
          - {title: ..., parameter_a: ..., values_a: [...],
             parameter_b: ..., values_b: [...]}                            # two-way
    """
    with open(path) as f:
        if path.endswith((".yaml", ".yml")):
            import yaml
            spec = yaml.safe_load(f)
        else:
            spec = json.load(f)
    base = Params.from_dict(spec.get("base_params", {})) \
        if spec.get("base_params") else Params()
    n_rep = int(spec.get("n_replications", 5))
    sweeps: List[Any] = []
    for s in spec.get("sweeps", []):
        if "parameter" in s:
            sweeps.append(OneWaySweep(s.get("title", s["parameter"]),
                                      s["parameter"], s["values"],
                                      n_replications=n_rep, base_params=base))
        else:
            sweeps.append(TwoWaySweep(s.get("title", "two-way"),
                                      s["parameter_a"], s["values_a"],
                                      s["parameter_b"], s["values_b"],
                                      n_replications=n_rep, base_params=base))
    return sweeps
