"""Experiment harness: one-way and two-way parameter sweeps (paper §III-D).

The paper's user-facing API:

    OneWaySweep("Systematic Failure Fraction",
                "systematic_failure_fraction", [0.1, 0.2, 0.3])

Each sweep point runs ``n_replications`` independent simulations and
aggregates the paper's output metrics.  TwoWaySweep crosses two parameter
ranges (the paper's evaluation crosses every knob with working_pool_size).
Results can be dumped as CSV or JSON; a yaml experiment file is supported
via :func:`load_experiment`.

Engine selection (``engine=`` on every sweep, default ``"auto"``): sweeps
route through :mod:`repro.core.backend`, which batches every grid point
that fits the vectorized CTMC engine's envelope into a single compiled
XLA program and runs the rest through the event-driven engine.  Thanks
to structure padding this includes *structural* sweeps (job_size, pool
sizes, warm_standbys, ...): a mixed-structure grid still compiles once
(``padded=False`` opts back into per-structure compilation for A/B
measurements).  Failure-hazard and repair-distribution *families* are
static compile switches (one batch per combination), but their
*parameters* stay traced — a repair-policy grid over
``auto_repair_time`` / ``manual_repair_time`` under Weibull or
lognormal repairs compiles exactly one program, like any rate grid.
For the trace-driven ``empirical`` family the static switch includes
only the segment *count*: edge positions and segment rates are traced
columns, so a grid of hazards fitted from different log slices (same
bin count) batches into one program too.  See the backend module
docstring for the exactness caveats of each engine.

Special virtual parameter ``systematic_failure_rate_multiplier`` sets the
systematic rate as a multiple of the (possibly swept) random rate, the way
Table I expresses it.  ``rack_shock_rate`` / ``pod_shock_rate`` sweep the
correlated-failure-domain shock intensities (Params.fault_domains must be
set); the rates are traced columns on the CTMC fast path, so a whole
shock-rate grid compiles once.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from .backend import (MultiJobReplications, Replications,
                      run_multijob_batch, run_replications_batch)
from .metrics import RunResult, Stat
from .multijob import JobSpec
from .params import Params

#: sweep-table columns (means over replications)
DEFAULT_STATS = ("total_time", "n_failures", "n_random_failures",
                 "n_systematic_failures", "n_preemptions", "n_auto_repairs",
                 "n_manual_repairs", "n_host_selections", "stall_time",
                 "overhead_fraction", "goodput", "lost_work",
                 "checkpoint_overhead", "mean_run_duration",
                 "n_domain_shocks", "n_incomplete")


def _apply_param(params: Params, name: str, value: Any) -> Params:
    """Set a (possibly virtual) parameter on a Params copy."""
    if name == "systematic_failure_rate_multiplier":
        return params.replace(
            systematic_failure_rate=value * params.random_failure_rate)
    if name in ("rack_shock_rate", "pod_shock_rate"):
        if params.fault_domains is None:
            raise ValueError(
                f"sweeping {name!r} requires Params.fault_domains")
        return params.replace(fault_domains=dataclasses.replace(
            params.fault_domains, **{name: value}))
    if not hasattr(params, name):
        raise ValueError(f"unknown parameter {name!r}")
    # preserve int-ness of count-typed fields
    current = getattr(params, name)
    if isinstance(current, int) and not isinstance(current, bool):
        value = int(value)
    return params.replace(**{name: value})


#: percentiles written per distribution channel to sweep tables
DIST_PERCENTILES = (50, 90, 99)


@dataclass
class SweepPoint:
    values: Dict[str, Any]
    results: List[RunResult]        # per-replication results (event engine)
    stats: Dict[str, Stat]
    #: replication count (== len(results) on the event engine; the batched
    #: CTMC path aggregates arrays directly and leaves ``results`` empty)
    n: Optional[int] = None
    engine: str = "event"
    #: pooled streaming histograms per channel (when Params.histogram set)
    histograms: Dict[str, Any] = field(default_factory=dict)

    @property
    def n_replications(self) -> int:
        return self.n if self.n is not None else len(self.results)

    def row(self, columns: Sequence[str] = DEFAULT_STATS) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.values)
        for c in columns:
            out[c] = self.stats[c].mean
        out["total_time_ci95"] = self.stats["total_time"].ci95_halfwidth(
            self.n_replications)
        # distribution percentiles from the streaming histograms, e.g.
        # run_duration_p50 / recovery_p99 — exact to one bin width of the
        # Params.histogram spec (a resolution caveat, not sampling error)
        for name, stat in self.stats.items():
            if name.endswith("_dist"):
                for q in DIST_PERCENTILES:
                    out[f"{name[:-5]}_p{q}"] = stat.percentiles.get(
                        q, float("nan"))
        return out

    @classmethod
    def of(cls, values: Dict[str, Any], rep: Replications) -> "SweepPoint":
        return cls(values, rep.results, rep.stats, n=rep.n,
                   engine=rep.engine, histograms=rep.histograms)


@dataclass
class SweepResult:
    name: str
    parameter_names: List[str]
    points: List[SweepPoint]

    def to_rows(self, columns: Sequence[str] = DEFAULT_STATS) -> List[Dict[str, Any]]:
        return [p.row(columns) for p in self.points]

    def write_csv(self, path: str, columns: Sequence[str] = DEFAULT_STATS) -> None:
        rows = self.to_rows(columns)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if rows:
            fieldnames = list(rows[0].keys())
        else:  # empty sweep: still emit a well-formed header-only file
            fieldnames = (list(self.parameter_names) + list(columns)
                          + ["total_time_ci95"])
        with open(path, "w", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=fieldnames)
            writer.writeheader()
            writer.writerows(rows)

    def write_json(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({
                "name": self.name,
                "parameters": self.parameter_names,
                "rows": self.to_rows(),
            }, f, indent=2)

    def column(self, metric: str) -> List[float]:
        return [p.stats[metric].mean for p in self.points]


class OneWaySweep:
    """Vary one parameter over a list of values (paper's OneWaySweep).

    Every grid point runs ``n_replications`` replications through the
    engine dispatch layer (``engine="auto"`` batches all fast-path
    points — exponential, Weibull, bathtub, and lognormal failure
    models with exponential or non-exponential repairs alike — into
    one compiled program per family combination; see docs/engines.md).
    Results come back as a :class:`SweepResult` whose points carry full
    :class:`repro.core.metrics.Stat` dicts, pooled histograms, and CSV
    writers.

    >>> from repro.core import OneWaySweep, Params
    >>> calm = Params(job_size=2, working_pool_size=3, spare_pool_size=1,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> res = OneWaySweep("demo", "job_length", [10.0, 20.0],
    ...                   n_replications=1, base_params=calm,
    ...                   engine="event").run()
    >>> [round(p.stats["total_time"].mean, 1) for p in res.points]
    [13.0, 23.0]
    >>> res.to_rows()[0]["job_length"]
    10.0
    """

    def __init__(self, title: str, parameter: str, values: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0, engine: str = "auto",
                 padded: bool = True, bucketed: bool = True):
        self.title = title
        self.parameter = parameter
        self.values = list(values)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed
        self.engine = engine
        self.padded = padded
        self.bucketed = bucketed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        grid = [_apply_param(self.base_params, self.parameter, v)
                for v in self.values]
        cb = (lambda i: progress(
            f"{self.title}: {self.parameter}={self.values[i]}")) \
            if progress else None
        # common random numbers across points: the event engine reuses
        # base_seed per replication; the batched CTMC engine tiles one
        # uniform draw per replica column across all points.
        reps = run_replications_batch(grid, self.n_replications,
                                      engine=self.engine,
                                      base_seed=self.base_seed, progress=cb,
                                      padded=self.padded,
                                      bucketed=self.bucketed)
        points = [SweepPoint.of({self.parameter: v}, rep)
                  for v, rep in zip(self.values, reps)]
        return SweepResult(self.title, [self.parameter], points)


class TwoWaySweep:
    """Cross two parameter ranges (the paper's evaluation design).

    The grid is the full cross product, points ordered with
    ``parameter_b`` varying fastest; everything else matches
    :class:`OneWaySweep`.

    >>> from repro.core import Params, TwoWaySweep
    >>> calm = Params(job_size=2, working_pool_size=3, spare_pool_size=1,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> res = TwoWaySweep("demo", "job_length", [10.0, 20.0],
    ...                   "host_selection_time", [0.0, 5.0],
    ...                   n_replications=1, base_params=calm,
    ...                   engine="event").run()
    >>> [(p.values["job_length"], p.values["host_selection_time"],
    ...   round(p.stats["total_time"].mean, 1)) for p in res.points]
    [(10.0, 0.0, 10.0), (10.0, 5.0, 15.0), (20.0, 0.0, 20.0), (20.0, 5.0, 25.0)]
    """

    def __init__(self, title: str, parameter_a: str, values_a: Sequence[Any],
                 parameter_b: str, values_b: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0, engine: str = "auto",
                 padded: bool = True, bucketed: bool = True):
        self.title = title
        self.parameter_a, self.values_a = parameter_a, list(values_a)
        self.parameter_b, self.values_b = parameter_b, list(values_b)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed
        self.engine = engine
        self.padded = padded
        self.bucketed = bucketed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        combos = [(va, vb) for va in self.values_a for vb in self.values_b]
        grid = [_apply_param(_apply_param(self.base_params,
                                          self.parameter_a, va),
                             self.parameter_b, vb)
                for va, vb in combos]
        cb = (lambda i: progress(
            f"{self.title}: {self.parameter_a}={combos[i][0]}, "
            f"{self.parameter_b}={combos[i][1]}")) if progress else None
        reps = run_replications_batch(grid, self.n_replications,
                                      engine=self.engine,
                                      base_seed=self.base_seed, progress=cb,
                                      padded=self.padded,
                                      bucketed=self.bucketed)
        points = [SweepPoint.of({self.parameter_a: va, self.parameter_b: vb},
                                rep)
                  for (va, vb), rep in zip(combos, reps)]
        return SweepResult(self.title,
                           [self.parameter_a, self.parameter_b], points)


#: fleet-level sweep-table columns for multi-job capacity grids
MULTIJOB_FLEET_STATS = ("makespan", "fleet_n_failures", "fleet_stall_time",
                        "n_auto_repairs", "n_manual_repairs",
                        "n_failed_repairs", "stall_handoffs",
                        "n_shop_queued", "completed")

#: per-job columns expanded to ``job{i}_{name}`` in multi-job tables
MULTIJOB_JOB_STATS = ("total_time", "n_failures", "stall_time",
                      "n_preemptions", "overhead_fraction")


def _multijob_point_stats(rep: MultiJobReplications) -> Dict[str, Stat]:
    """Flatten a MultiJobReplications into one SweepPoint stats dict.

    Fleet stats keep their names (plus a ``total_time`` alias for the
    makespan, which the generic CSV writer's ci95 column reads); per-job
    stats are prefixed ``job{i}_``.
    """
    stats: Dict[str, Stat] = dict(rep.fleet)
    stats["total_time"] = rep.fleet["makespan"]
    for i, job_rep in enumerate(rep.per_job):
        for name in MULTIJOB_JOB_STATS:
            stats[f"job{i}_{name}"] = job_rep.stats[name]
    return stats


class MultiJobSweep:
    """Capacity-planning grid over a fixed multi-job cluster.

    Crosses one or two *cluster-level* parameters (spare_pool_size,
    repair_servers, failure rates, ...) while the job mix — sizes,
    lengths, warm-standby targets — stays fixed.  On ``engine="auto"``
    every point inside the multi-job CTMC envelope runs in one
    ``simulate_multijob_ctmc_sweep`` call: the job count is the only
    compile key, so the whole grid (mixed job sizes included) is ONE
    compiled XLA program.  CSV rows carry the fleet columns
    (:data:`MULTIJOB_FLEET_STATS`) plus per-job ``job{i}_{metric}``
    columns (:data:`MULTIJOB_JOB_STATS`).

    >>> from repro.core import JobSpec, MultiJobSweep, Params
    >>> calm = Params(job_size=2, working_pool_size=8, spare_pool_size=2,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> jobs = [JobSpec(2, 10.0, warm_standbys=0),
    ...         JobSpec(3, 20.0, warm_standbys=0)]
    >>> sweep = MultiJobSweep("demo", jobs, "spare_pool_size", [2, 4],
    ...                       n_replications=2, base_params=calm,
    ...                       engine="event")
    >>> res = sweep.run()
    >>> [round(p.stats["makespan"].mean, 1) for p in res.points]  # +3 select
    [23.0, 23.0]
    >>> sorted(res.to_rows(sweep.columns())[0])[:3]
    ['completed', 'fleet_n_failures', 'fleet_stall_time']
    """

    def __init__(self, title: str, jobs: Sequence[JobSpec],
                 parameter: str, values: Sequence[Any],
                 parameter_b: Optional[str] = None,
                 values_b: Optional[Sequence[Any]] = None,
                 n_replications: int = 5,
                 base_params: Optional[Params] = None,
                 base_seed: int = 0, engine: str = "auto"):
        self.title = title
        self.jobs = [JobSpec(j.job_size, j.job_length, j.warm_standbys,
                             j.start_time) if not isinstance(j, JobSpec)
                     else j for j in jobs]
        self.parameter, self.values = parameter, list(values)
        self.parameter_b = parameter_b
        self.values_b = list(values_b) if values_b is not None else None
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed
        self.engine = engine

    def columns(self) -> List[str]:
        """Default CSV column list for this grid's job count."""
        return list(MULTIJOB_FLEET_STATS) + [
            f"job{i}_{name}" for i in range(len(self.jobs))
            for name in MULTIJOB_JOB_STATS]

    def _combos(self) -> List[Dict[str, Any]]:
        if self.parameter_b is None:
            return [{self.parameter: v} for v in self.values]
        return [{self.parameter: va, self.parameter_b: vb}
                for va in self.values for vb in self.values_b]

    def run(self, progress: Optional[Callable[[str], None]] = None,
            ) -> SweepResult:
        combos = self._combos()
        grid = []
        for values in combos:
            p = self.base_params
            for name, v in values.items():
                p = _apply_param(p, name, v)
            grid.append((p, tuple(self.jobs)))
        if progress:
            progress(f"{self.title}: {len(grid)} points x "
                     f"{len(self.jobs)} jobs")
        reps = run_multijob_batch(grid, self.n_replications,
                                  engine=self.engine,
                                  base_seed=self.base_seed)
        points = [SweepPoint(values, [], _multijob_point_stats(rep),
                             n=rep.n, engine=rep.engine,
                             histograms=rep.histograms)
                  for values, rep in zip(combos, reps)]
        names = [self.parameter] + ([self.parameter_b]
                                    if self.parameter_b else [])
        return SweepResult(self.title, names, points)


def load_experiment(path: str, engine: Optional[str] = None) -> List[Any]:
    """Build sweeps from a yaml/json experiment file.

    Schema::

        base_params: {recovery_time: 20, ...}
        n_replications: 5
        engine: auto          # optional: auto | event | ctmc
        sweeps:
          - {title: ..., parameter: ..., values: [...]}                    # one-way
          - {title: ..., parameter_a: ..., values_a: [...],
             parameter_b: ..., values_b: [...]}                            # two-way

    ``engine`` (argument or file key; the argument wins) selects the
    execution engine for every sweep; the default ``auto`` batches all
    CTMC-compatible points into one compiled program.
    """
    with open(path) as f:
        if path.endswith((".yaml", ".yml")):
            import yaml
            spec = yaml.safe_load(f)
        else:
            spec = json.load(f)
    base = Params.from_dict(spec.get("base_params", {})) \
        if spec.get("base_params") else Params()
    n_rep = int(spec.get("n_replications", 5))
    eng = engine or spec.get("engine", "auto")
    sweeps: List[Any] = []
    for s in spec.get("sweeps", []):
        if "parameter" in s:
            sweeps.append(OneWaySweep(s.get("title", s["parameter"]),
                                      s["parameter"], s["values"],
                                      n_replications=n_rep, base_params=base,
                                      engine=eng))
        else:
            sweeps.append(TwoWaySweep(s.get("title", "two-way"),
                                      s["parameter_a"], s["values_a"],
                                      s["parameter_b"], s["values_b"],
                                      n_replications=n_rep, base_params=base,
                                      engine=eng))
    return sweeps
