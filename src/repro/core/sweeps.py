"""Experiment harness: one-way and two-way parameter sweeps (paper §III-D).

The paper's user-facing API:

    OneWaySweep("Systematic Failure Fraction",
                "systematic_failure_fraction", [0.1, 0.2, 0.3])

Each sweep point runs ``n_replications`` independent simulations and
aggregates the paper's output metrics.  TwoWaySweep crosses two parameter
ranges (the paper's evaluation crosses every knob with working_pool_size).
Results can be dumped as CSV or JSON; a yaml experiment file is supported
via :func:`load_experiment`.

Engine selection (``engine=`` on every sweep, default ``"auto"``): sweeps
route through :mod:`repro.core.backend`, which batches every grid point
that fits the vectorized CTMC engine's envelope into a single compiled
XLA program and runs the rest through the event-driven engine.  Thanks
to structure padding this includes *structural* sweeps (job_size, pool
sizes, warm_standbys, ...): a mixed-structure grid still compiles once
(``padded=False`` opts back into per-structure compilation for A/B
measurements).  Failure-hazard and repair-distribution *families* are
static compile switches (one batch per combination), but their
*parameters* stay traced — a repair-policy grid over
``auto_repair_time`` / ``manual_repair_time`` under Weibull or
lognormal repairs compiles exactly one program, like any rate grid.
See the backend module docstring for the exactness caveats of each
engine.

Special virtual parameter ``systematic_failure_rate_multiplier`` sets the
systematic rate as a multiple of the (possibly swept) random rate, the way
Table I expresses it.  ``rack_shock_rate`` / ``pod_shock_rate`` sweep the
correlated-failure-domain shock intensities (Params.fault_domains must be
set); the rates are traced columns on the CTMC fast path, so a whole
shock-rate grid compiles once.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from .backend import Replications, run_replications_batch
from .metrics import RunResult, Stat
from .params import Params

#: sweep-table columns (means over replications)
DEFAULT_STATS = ("total_time", "n_failures", "n_random_failures",
                 "n_systematic_failures", "n_preemptions", "n_auto_repairs",
                 "n_manual_repairs", "n_host_selections", "stall_time",
                 "overhead_fraction", "mean_run_duration",
                 "n_domain_shocks", "n_incomplete")


def _apply_param(params: Params, name: str, value: Any) -> Params:
    """Set a (possibly virtual) parameter on a Params copy."""
    if name == "systematic_failure_rate_multiplier":
        return params.replace(
            systematic_failure_rate=value * params.random_failure_rate)
    if name in ("rack_shock_rate", "pod_shock_rate"):
        if params.fault_domains is None:
            raise ValueError(
                f"sweeping {name!r} requires Params.fault_domains")
        return params.replace(fault_domains=dataclasses.replace(
            params.fault_domains, **{name: value}))
    if not hasattr(params, name):
        raise ValueError(f"unknown parameter {name!r}")
    # preserve int-ness of count-typed fields
    current = getattr(params, name)
    if isinstance(current, int) and not isinstance(current, bool):
        value = int(value)
    return params.replace(**{name: value})


#: percentiles written per distribution channel to sweep tables
DIST_PERCENTILES = (50, 90, 99)


@dataclass
class SweepPoint:
    values: Dict[str, Any]
    results: List[RunResult]        # per-replication results (event engine)
    stats: Dict[str, Stat]
    #: replication count (== len(results) on the event engine; the batched
    #: CTMC path aggregates arrays directly and leaves ``results`` empty)
    n: Optional[int] = None
    engine: str = "event"
    #: pooled streaming histograms per channel (when Params.histogram set)
    histograms: Dict[str, Any] = field(default_factory=dict)

    @property
    def n_replications(self) -> int:
        return self.n if self.n is not None else len(self.results)

    def row(self, columns: Sequence[str] = DEFAULT_STATS) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.values)
        for c in columns:
            out[c] = self.stats[c].mean
        out["total_time_ci95"] = self.stats["total_time"].ci95_halfwidth(
            self.n_replications)
        # distribution percentiles from the streaming histograms, e.g.
        # run_duration_p50 / recovery_p99 — exact to one bin width of the
        # Params.histogram spec (a resolution caveat, not sampling error)
        for name, stat in self.stats.items():
            if name.endswith("_dist"):
                for q in DIST_PERCENTILES:
                    out[f"{name[:-5]}_p{q}"] = stat.percentiles.get(
                        q, float("nan"))
        return out

    @classmethod
    def of(cls, values: Dict[str, Any], rep: Replications) -> "SweepPoint":
        return cls(values, rep.results, rep.stats, n=rep.n,
                   engine=rep.engine, histograms=rep.histograms)


@dataclass
class SweepResult:
    name: str
    parameter_names: List[str]
    points: List[SweepPoint]

    def to_rows(self, columns: Sequence[str] = DEFAULT_STATS) -> List[Dict[str, Any]]:
        return [p.row(columns) for p in self.points]

    def write_csv(self, path: str, columns: Sequence[str] = DEFAULT_STATS) -> None:
        rows = self.to_rows(columns)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if rows:
            fieldnames = list(rows[0].keys())
        else:  # empty sweep: still emit a well-formed header-only file
            fieldnames = (list(self.parameter_names) + list(columns)
                          + ["total_time_ci95"])
        with open(path, "w", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=fieldnames)
            writer.writeheader()
            writer.writerows(rows)

    def write_json(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({
                "name": self.name,
                "parameters": self.parameter_names,
                "rows": self.to_rows(),
            }, f, indent=2)

    def column(self, metric: str) -> List[float]:
        return [p.stats[metric].mean for p in self.points]


class OneWaySweep:
    """Vary one parameter over a list of values (paper's OneWaySweep).

    Every grid point runs ``n_replications`` replications through the
    engine dispatch layer (``engine="auto"`` batches all fast-path
    points — exponential, Weibull, bathtub, and lognormal failure
    models with exponential or non-exponential repairs alike — into
    one compiled program per family combination; see docs/engines.md).
    Results come back as a :class:`SweepResult` whose points carry full
    :class:`repro.core.metrics.Stat` dicts, pooled histograms, and CSV
    writers.

    >>> from repro.core import OneWaySweep, Params
    >>> calm = Params(job_size=2, working_pool_size=3, spare_pool_size=1,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> res = OneWaySweep("demo", "job_length", [10.0, 20.0],
    ...                   n_replications=1, base_params=calm,
    ...                   engine="event").run()
    >>> [round(p.stats["total_time"].mean, 1) for p in res.points]
    [13.0, 23.0]
    >>> res.to_rows()[0]["job_length"]
    10.0
    """

    def __init__(self, title: str, parameter: str, values: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0, engine: str = "auto",
                 padded: bool = True, bucketed: bool = True):
        self.title = title
        self.parameter = parameter
        self.values = list(values)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed
        self.engine = engine
        self.padded = padded
        self.bucketed = bucketed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        grid = [_apply_param(self.base_params, self.parameter, v)
                for v in self.values]
        cb = (lambda i: progress(
            f"{self.title}: {self.parameter}={self.values[i]}")) \
            if progress else None
        # common random numbers across points: the event engine reuses
        # base_seed per replication; the batched CTMC engine tiles one
        # uniform draw per replica column across all points.
        reps = run_replications_batch(grid, self.n_replications,
                                      engine=self.engine,
                                      base_seed=self.base_seed, progress=cb,
                                      padded=self.padded,
                                      bucketed=self.bucketed)
        points = [SweepPoint.of({self.parameter: v}, rep)
                  for v, rep in zip(self.values, reps)]
        return SweepResult(self.title, [self.parameter], points)


class TwoWaySweep:
    """Cross two parameter ranges (the paper's evaluation design).

    The grid is the full cross product, points ordered with
    ``parameter_b`` varying fastest; everything else matches
    :class:`OneWaySweep`.

    >>> from repro.core import Params, TwoWaySweep
    >>> calm = Params(job_size=2, working_pool_size=3, spare_pool_size=1,
    ...               warm_standbys=0, job_length=10.0,
    ...               random_failure_rate=0.0, systematic_failure_rate=0.0,
    ...               histogram=None)
    >>> res = TwoWaySweep("demo", "job_length", [10.0, 20.0],
    ...                   "host_selection_time", [0.0, 5.0],
    ...                   n_replications=1, base_params=calm,
    ...                   engine="event").run()
    >>> [(p.values["job_length"], p.values["host_selection_time"],
    ...   round(p.stats["total_time"].mean, 1)) for p in res.points]
    [(10.0, 0.0, 10.0), (10.0, 5.0, 15.0), (20.0, 0.0, 20.0), (20.0, 5.0, 25.0)]
    """

    def __init__(self, title: str, parameter_a: str, values_a: Sequence[Any],
                 parameter_b: str, values_b: Sequence[Any],
                 n_replications: int = 5, base_params: Optional[Params] = None,
                 base_seed: int = 0, engine: str = "auto",
                 padded: bool = True, bucketed: bool = True):
        self.title = title
        self.parameter_a, self.values_a = parameter_a, list(values_a)
        self.parameter_b, self.values_b = parameter_b, list(values_b)
        self.n_replications = n_replications
        self.base_params = base_params or Params()
        self.base_seed = base_seed
        self.engine = engine
        self.padded = padded
        self.bucketed = bucketed

    def run(self, progress: Optional[Callable[[str], None]] = None) -> SweepResult:
        combos = [(va, vb) for va in self.values_a for vb in self.values_b]
        grid = [_apply_param(_apply_param(self.base_params,
                                          self.parameter_a, va),
                             self.parameter_b, vb)
                for va, vb in combos]
        cb = (lambda i: progress(
            f"{self.title}: {self.parameter_a}={combos[i][0]}, "
            f"{self.parameter_b}={combos[i][1]}")) if progress else None
        reps = run_replications_batch(grid, self.n_replications,
                                      engine=self.engine,
                                      base_seed=self.base_seed, progress=cb,
                                      padded=self.padded,
                                      bucketed=self.bucketed)
        points = [SweepPoint.of({self.parameter_a: va, self.parameter_b: vb},
                                rep)
                  for (va, vb), rep in zip(combos, reps)]
        return SweepResult(self.title,
                           [self.parameter_a, self.parameter_b], points)


def load_experiment(path: str, engine: Optional[str] = None) -> List[Any]:
    """Build sweeps from a yaml/json experiment file.

    Schema::

        base_params: {recovery_time: 20, ...}
        n_replications: 5
        engine: auto          # optional: auto | event | ctmc
        sweeps:
          - {title: ..., parameter: ..., values: [...]}                    # one-way
          - {title: ..., parameter_a: ..., values_a: [...],
             parameter_b: ..., values_b: [...]}                            # two-way

    ``engine`` (argument or file key; the argument wins) selects the
    execution engine for every sweep; the default ``auto`` batches all
    CTMC-compatible points into one compiled program.
    """
    with open(path) as f:
        if path.endswith((".yaml", ".yml")):
            import yaml
            spec = yaml.safe_load(f)
        else:
            spec = json.load(f)
    base = Params.from_dict(spec.get("base_params", {})) \
        if spec.get("base_params") else Params()
    n_rep = int(spec.get("n_replications", 5))
    eng = engine or spec.get("engine", "auto")
    sweeps: List[Any] = []
    for s in spec.get("sweeps", []):
        if "parameter" in s:
            sweeps.append(OneWaySweep(s.get("title", s["parameter"]),
                                      s["parameter"], s["values"],
                                      n_replications=n_rep, base_params=base,
                                      engine=eng))
        else:
            sweeps.append(TwoWaySweep(s.get("title", "two-way"),
                                      s["parameter_a"], s["values_a"],
                                      s["parameter_b"], s["values_b"],
                                      n_replications=n_rep, base_params=base,
                                      engine=eng))
    return sweeps
