"""Multi-job cluster simulation (paper assumption 6's extension point).

"Note that we assume there is only one AI job executing at any time in
the cluster. However, this can be easily modified in the simulator if
needed, e.g., to consider multiple concurrent AI jobs."  — §III-A(6)

This module does that modification: N jobs share one working pool, one
spare pool, and one repair shop.  Each job runs the same coordinator
state machine as the single-job simulator; contention appears exactly
where the paper predicts — replacement acquisition.  Pool hand-offs on
repair completion go to the *stalled* job that has waited longest
(FIFO), then to standby refills round-robin, then back to the pools.

Outputs: one RunResult per job plus cluster-level contention metrics.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .coordinator import Coordinator
from .engine import Environment
from .histograms import Histogram, HistogramSpec
from .metrics import RunResult, histograms_from_results
from .params import Params
from .pool import PoolManager
from .repair import RepairShop
from .scheduler import Scheduler
from .server import FailureSampler, Fleet, Server


@dataclass
class JobSpec:
    """Per-job overrides on top of the shared cluster Params."""
    job_size: int
    job_length: float
    warm_standbys: int = 16
    start_time: float = 0.0


@dataclass
class MultiJobResult:
    per_job: List[RunResult]
    makespan: float = 0.0               # last job completion
    stall_events: int = 0               # cross-job starvation hand-offs
    #: cluster-level counters that live on the *shared* repair shop, not
    #: on any one job: n_auto_repairs / n_manual_repairs /
    #: n_failed_repairs (and n_retired under retirement policies).
    #: Historically these were silently dropped — the shop wrote them to
    #: a RunResult nobody kept — so multi-job repair accounting summed
    #: to zero; the parity suite pins this merge.
    cluster: RunResult = field(default_factory=RunResult)
    #: submissions that found every repair-shop service slot busy
    #: (finite ``Params.repair_servers`` only; 0 with an unbounded shop)
    queue_events: int = 0

    @property
    def total_failures(self) -> int:
        return sum(r.n_failures for r in self.per_job)

    def per_job_histograms(self, spec: Optional[HistogramSpec],
                           ) -> List[Dict[str, Histogram]]:
        """Per-job distribution channels (run_duration/recovery/waiting).

        Each job's coordinator records its own per-run duration lists;
        binning them through the shared
        :class:`~repro.core.histograms.HistogramSpec` layout gives the
        per-job channels the cross-engine parity suite compares bin by
        bin against the CTMC engine's per-job streaming accumulators.
        """
        return [histograms_from_results([r], spec) for r in self.per_job]


class Dispatcher:
    """Routes repaired servers among jobs: longest-stalled job first,
    then the owning job's standby refill, then the pools."""

    def __init__(self, pools: PoolManager):
        self.pools = pools
        self.schedulers: List[Scheduler] = []
        self.stall_handoffs = 0

    def register(self, sched: Scheduler) -> None:
        self.schedulers.append(sched)

    def on_server_return(self, server: Server) -> None:
        # 1. longest-stalled job anywhere
        stalled = [s for s in self.schedulers
                   if s._stall_event is not None
                   and not s._stall_event.triggered]
        if stalled:
            target = min(stalled, key=lambda s: s._stall_since)
            self.stall_handoffs += 1
            target._stall_server = server
            target._stall_event.succeed(server)
            return
        # 2. the job that owned this server refills standbys
        for sched in self.schedulers:
            if (sched.job_active and server.sid in sched.job_members
                    and len(sched.standbys) < sched.params.warm_standbys):
                from .server import ServerState
                server.state = ServerState.STANDBY
                sched.standbys.append(server)
                return
        # 3. origin pool
        for sched in self.schedulers:
            sched.job_members.discard(server.sid)
        self.pools.push(server)

    def on_server_retired(self, server: Server) -> None:
        for sched in self.schedulers:
            sched.job_members.discard(server.sid)
        self.pools.retire(server)


class MultiJobSimulation:
    """N concurrent jobs over one shared fleet."""

    def __init__(self, cluster: Params, jobs: List[JobSpec],
                 seed: Optional[int] = None):
        total_needed = sum(j.job_size + j.warm_standbys for j in jobs)
        if cluster.working_pool_size < total_needed:
            raise ValueError(
                f"working pool {cluster.working_pool_size} cannot host "
                f"{len(jobs)} jobs needing {total_needed}")
        cluster.validate()
        self.cluster = cluster
        self.jobs = jobs
        self.rng = np.random.default_rng(
            cluster.seed if seed is None else seed)
        self.env = Environment()
        self.fleet = Fleet(cluster, self.rng)
        self.pools = PoolManager(cluster, self.fleet)
        self.dispatcher = Dispatcher(self.pools)
        self.results: List[RunResult] = [RunResult() for _ in jobs]
        # one shared repair shop feeding the dispatcher; repair counters
        # go to a cluster-level RunResult merged at the end
        self.repair_metrics = RunResult()
        self.repair_shop = RepairShop(
            self.env, cluster, self.rng, self.repair_metrics,
            on_return=self.dispatcher.on_server_return,
            on_retire=self.dispatcher.on_server_retired)
        self.coordinators: List[Coordinator] = []
        for spec, metrics in zip(jobs, self.results):
            job_params = cluster.replace(job_size=spec.job_size,
                                         job_length=spec.job_length,
                                         warm_standbys=spec.warm_standbys)
            sched = Scheduler(self.env, job_params, self.pools, metrics)
            sched._stall_since = 0.0
            self.dispatcher.register(sched)
            sampler = FailureSampler(job_params, self.rng)
            self.coordinators.append(Coordinator(
                self.env, job_params, self.rng, metrics, sched,
                self.repair_shop, sampler))

    def _run_job(self, idx: int, spec: JobSpec):
        if spec.start_time > 0:
            yield self.env.timeout(spec.start_time)
        sched = self.coordinators[idx].scheduler
        orig_stall = sched._stall_until_available

        def tracked_stall():
            sched._stall_since = self.env.now
            return orig_stall()

        sched._stall_until_available = tracked_stall
        yield from self.coordinators[idx].run_job()

    def run(self) -> MultiJobResult:
        procs = [self.env.process(self._run_job(i, spec), name=f"job{i}")
                 for i, spec in enumerate(self.jobs)]
        for proc in procs:
            self.env.run_until_process(proc)
        # repair counters live on the shared shop (repair_metrics);
        # per-job results carry the failure/replacement/stall accounting
        makespan = max(r.total_time for r in self.results)
        out = MultiJobResult(per_job=self.results, makespan=makespan,
                             stall_events=self.dispatcher.stall_handoffs,
                             cluster=self.repair_metrics,
                             queue_events=self.repair_shop.n_queued_events)
        return out


def simulate_multijob(cluster: Params, jobs: List[JobSpec],
                      n_replications: int = 1,
                      base_seed: int = 0) -> List[MultiJobResult]:
    return [MultiJobSimulation(cluster, list(jobs),
                               seed=base_seed + 7919 * rep).run()
            for rep in range(n_replications)]
