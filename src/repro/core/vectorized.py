"""Vectorized JAX CTMC engine: thousands of AIReSim replicas per device.

TPU adaptation of the paper's DES (DESIGN.md §2.2): under the paper's
default exponential assumption the cluster is a continuous-time Markov
chain over server *compartments* — servers are exchangeable within
(origin x health) classes, so counts are sufficient state.  Each step
races the exponential clock families against the deterministic timers
(recovery / host-selection / completion) with the kernels.ops.event_race
Pallas kernel, then applies the winning transition with masked updates.
``lax.scan`` over events x vectorization over replicas turns a whole
replication study into a single XLA program; parameter sweeps stack one
level higher: :func:`simulate_ctmc_sweep` flattens a (points x replicas)
grid into one batch axis so an entire sweep — including *structural*
sweeps over job_size / pool sizes / warm_standbys — is a single compiled
program, and the scan runs in chunks inside a ``lax.while_loop`` that
stops as soon as every replica reaches DONE — the ``default_max_steps``
head-room is only paid when a trajectory actually needs it.

Structure padding: every point shares one compartment layout (4 classes
x 4 pools + the two repair shops), so differing pool structures differ
only in the *initial occupancy values*, which are traced inputs.  A
point with smaller pools leaves the surplus compartments at zero
occupancy; zero-count compartments contribute zero rates and are inert
in the event race.  ``simulate_ctmc_sweep(padded=True)`` (the default)
exploits this to run a mixed-structure grid as one flat ``(P*R,)`` batch
with exactly one XLA compilation; ``padded=False`` keeps the legacy
one-program-per-:func:`_struct_key` grouping for A/B benchmarking.

Exact run durations: the scan carries a per-replica ring buffer of the
last ``max_runs`` failure-to-failure useful-compute intervals (the event
engine's ``run_durations``), plus the total attempt count and the
in-flight interval, so ``metrics.aggregate_arrays`` reports
``run_duration_pooled`` / ``mean_run_duration`` exactly instead of the
former total_time/(n_failures+1) approximation.

Streaming histograms: alongside the ring buffer the scan accumulates
per-replica log-spaced histograms (``Params.histogram``, a
:class:`repro.core.histograms.HistogramSpec`) of run durations, recovery
downtime (ETTR), and replacement waiting — O(bins) memory with **no**
run-count bound, so distribution percentiles survive multi-year horizons
where the ring buffer truncates.  The bin layout matches the pure-numpy
reference accumulator in :mod:`repro.core.histograms` (left-closed /
right-open, under/overflow slots), so both engines emit comparable
distributions; ``histogram=None`` compiles the accumulator out.

Checkpoint rollback + write cost: when ``Params.checkpoint_interval``
is positive, the scan tracks work-since-last-checkpoint in a dedicated
lane (no lossy ``mod`` arithmetic), charges it back on every failure
(``lost_work``), and races a deterministic checkpoint-write residual:
every ``checkpoint_interval`` minutes of phase work the replica enters
a ``checkpoint_cost``-minute OVERHEAD write (``checkpoint_overhead``),
during which the failure clock is frozen — the hazard age neither
advances nor resets, exactly the event engine's segment-loop timing.  A
checkpoint is durable from write start.  Both knobs are *traced*
columns: a (checkpoint_interval x checkpoint_cost x anything) grid is
one XLA program, and ``checkpoint_interval=0`` leaves the residual at
+inf — the same program, bit-identical trajectories and uniform stream.

Shape bucketing: on top of structure padding, ``simulate_ctmc_sweep``
(``bucketed=True``, the default on the padded path) rounds the point
count P and replica count R up to powers of two with *inert* padding
rows (phase DONE from step 0, zero rates, masked out of extraction) and
rounds the step budget up to a whole number of chunks with the chunk
count passed as a traced scalar — so repeated sweeps of different
(P, R, step-budget) signatures inside one bucket share a single XLA
program.  Uniform draws are always generated at the power-of-two replica
width and sliced, which keeps bucketed results bit-identical to
unbucketed on the real rows.

Compartment classes: c = 2*origin + bad, i.e.
  0: working-origin good   1: working-origin bad
  2: spare-origin good     3: spare-origin bad

Event families (K_exp = 16): random failure x4 classes, systematic
failure x4, auto-repair completion x4, manual completion x4.
Deterministic (K_det = 2): job completion, recovery/host-selection timer.

Non-exponential hazards: Weibull, bathtub, and lognormal failure
processes run on this same fast path (``supports`` says yes;
``engine=auto`` dispatches here).  The scan carries a per-replica *phase
age* — failure clocks restart whenever the job (re)starts, so every
running server shares one age and the fleet's first failure is a single
age-indexed intensity per health class (see :mod:`repro.core.hazards`).
Weibull failures are sampled by exact closed-form conditional inversion
entering the event race as a deterministic residual; bathtub and
lognormal failures use hazard majorization with Ogata-style thinning
(accept/reject inside the compiled step, plus a window-expiry phantom
timer) — bathtub bounds its convex shape at the window endpoints,
lognormal bounds its unimodal hazard at the numerically-located mode
clipped into the window.  The hazard family is a static compile switch:
exponential grids keep the exact pre-existing program (same state, same
uniform stream), and each family compiles one program per shape bucket.

Non-exponential repairs: Weibull / lognormal / deterministic repair
distributions run here too, via a per-replica *repair-slot* lane.
Repair clocks differ from failure clocks in both ways that matter: they
do NOT reset when the job restarts, and servers enter the shop at
different times, so there is no shared age.  Each slot carries one
in-repair server's (class, stage, remaining duration); the duration is
sampled *at entry* by exact inverse CDF (the same family machinery the
failure race uses — :class:`repro.core.hazards.HazardSampler`), exactly
mirroring the event engine's ``RepairShop`` which draws the stage
duration when the stage begins.  The minimum remaining time enters the
event race as one more deterministic residual — placed FIRST so that an
exact tie with job completion resolves repair-first, matching the event
engine's heap order (the repair timeout was scheduled before the final
phase's completion timeout).  Escalation re-arms the winning slot with
a manual-stage draw.  The slot lane is auto-sized from the expected
shop occupancy (``Params.repair_slots`` overrides); a full lane
surfaces as the ``n_repair_overflow`` metric and a RuntimeWarning.
Exponential repairs keep the original count-based compartments
bit-for-bit (memoryless repairs need no per-server state).

Correlated failure domains + campaigns: when ``Params.fault_domains`` /
``Params.campaign`` are set (see :mod:`repro.core.faultdomains` and
docs/scenarios.md), the race grows one extra exponential lane per fault
domain — a shared *shock* clock that is live in every non-DONE phase —
and the flattened campaign schedule races as one more deterministic
residual (placed first, so a campaign entry beats a same-instant timer
on both engines).  A shock or scripted kill removes ``fraction x count``
servers from every pool at once (stochastically rounded, class-
proportional), sends them through the auto-repair compartment, and
bulk-replaces the running block through the standby -> working ->
spare waterfall; the replacement shortfall accumulates in a ``deficit``
lane so the job only unstalls when the whole block is restored.
Maintenance windows gate the exponential repair rates to zero — exact
pause/resume by memorylessness.  The scenario *structure* (domain count,
schedule codes) is a static compile switch; every rate, fraction, time,
and target domain is traced, so a shock-rate grid compiles once.
Scenarios require exponential repairs on this path (``supports`` routes
non-exponential-repair scenarios to the event engine); in-shop servers
struck by a shock re-break, which is exact-in-law a no-op for
exponential stages and is therefore only counted.

Known approximations vs the event-driven oracle (validated statistically
in tests/test_vectorized.py, tests/test_nonexp.py, and
tests/test_repair_dist.py):
  * class-proportional sampling everywhere (exact under exchangeability);
  * misdiagnosis picks the wrong server proportionally over ALL running
    servers (the oracle excludes the failed one: O(1/4096) difference);
  * the initial bad-server split across pools uses its expectation;
  * a domain shock kills stochastically-rounded class-proportional
    counts per pool rather than a fixed member set (exact in
    expectation under round-robin striping), and a bulk replacement
    that partially stalls drops its host-selection surcharge (the
    stall interval dominates it on both engines).

Out of scope (routed to core.simulation): retirement, bad-set
regeneration, deterministic/user-registered failure distributions,
user-registered repair distributions, failing standbys, and fault
domains / campaigns combined with non-exponential repairs.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.kernels import ops
from repro.parallel import sharding as rsharding
from . import faultdomains, hazards
from .histograms import HIST_CHANNELS
from .params import Params

COMPUTE, OVERHEAD, STALL, DONE = 0, 1, 2, 3
K_EXP = 16

_METRICS = ("total_time", "n_failures", "n_random_failures",
            "n_systematic_failures", "n_preemptions", "n_auto_repairs",
            "n_manual_repairs", "n_failed_repairs", "n_host_selections",
            "n_standby_swaps", "n_undiagnosed", "n_misdiagnosed",
            "stall_time", "recovery_overhead", "lost_work", "useful_work",
            "checkpoint_overhead", "n_repair_overflow", "n_domain_shocks",
            "n_shock_killed", "n_campaign_events")


def unsupported_reasons(params: Params) -> list:
    """Why these params are outside the CTMC envelope (empty = inside).

    The single source of truth for :func:`supports` and for the
    ``engine="ctmc"`` refusal message in :mod:`repro.core.backend` —
    hand-maintained reason lists there went stale (PR 6 routed the
    fault-domain x non-exponential-repair combination to the event
    engine but the message never learned it), so the message is now
    *built* from this list.

    >>> from repro.core import Params
    >>> unsupported_reasons(Params())
    []
    >>> unsupported_reasons(Params(failure_distribution="deterministic"))
    ['failure distribution has no fast-path hazard family (closed-form \
exponential/weibull/bathtub/lognormal, an empirical fit, or a \
registered distribution with valid hazard_segments())']
    >>> from repro.core.faultdomains import FaultTopology
    >>> topo = FaultTopology(n_racks=8, rack_shock_rate=1e-5)
    >>> unsupported_reasons(Params(fault_domains=topo,
    ...                            repair_distribution="weibull"))
    ['fault domains / campaigns require exponential repairs on the \
fast path (a struck in-shop server would need a per-slot redraw)']
    """
    reasons = []
    if hazards.hazard_kind(params) is None:
        reasons.append(
            "failure distribution has no fast-path hazard family "
            "(closed-form exponential/weibull/bathtub/lognormal, an "
            "empirical fit, or a registered distribution with valid "
            "hazard_segments())")
    if hazards.repair_kind(params) is None:
        reasons.append(
            "repair distribution has no fast-path repair family "
            "(exponential/weibull/lognormal/deterministic, an empirical "
            "fit, or a registered distribution with valid "
            "hazard_segments())")
    if ((params.fault_domains is not None or params.campaign is not None)
            and hazards.repair_kind(params) != "exponential"):
        reasons.append(
            "fault domains / campaigns require exponential repairs on "
            "the fast path (a struck in-shop server would need a "
            "per-slot redraw)")
    if params.repair_servers != 0:
        reasons.append(
            "finite repair-shop capacity (repair_servers > 0) — the "
            "multi-job CTMC engine models it; the single-job program "
            "has no queue compartment")
    if params.retirement_threshold != 0:
        reasons.append("retirement policies are event-engine-only")
    if params.bad_set_regeneration_period != 0:
        reasons.append("bad-set regeneration is event-engine-only")
    if params.standbys_can_fail:
        reasons.append("failing warm standbys are event-engine-only")
    return reasons


def supports(params: Params) -> bool:
    """Can the CTMC engine simulate these params exactly?

    True for the paper's exponential baseline *and* the age-dependent
    Weibull / bathtub / lognormal failure families (sampled on the fast
    path via conditional inversion / hazard thinning) combined with
    exponential / Weibull / lognormal / deterministic repair
    distributions (sampled at shop entry via inverse CDF through the
    repair-slot lane), plus trace-driven ``empirical`` piecewise-
    constant hazards on both sides — see :mod:`repro.core.hazards`.
    Checkpoint rollback (``checkpoint_interval`` / ``checkpoint_cost``)
    runs on the fast path too, as traced knobs.  The event-engine-only
    extensions (retirement, bad-set regeneration, failing standbys)
    must be off.  ``engine="auto"`` falls back to the event engine
    whenever this returns False.

    >>> from repro.core import Params
    >>> supports(Params())                                    # Table-I default
    True
    >>> supports(Params(failure_distribution="weibull",
    ...                 distribution_kwargs={"k": 1.5}))      # wear-out
    True
    >>> supports(Params(failure_distribution="lognormal"))    # heavy tail
    True
    >>> supports(Params(repair_distribution="weibull",
    ...                 distribution_kwargs={"k": 0.7}))      # slow repairs
    True
    >>> supports(Params(failure_distribution="empirical",     # trace-driven
    ...                 distribution_kwargs={"edges": [24.0, 120.0],
    ...                                      "rates": [3.0, 1.0, 0.4]}))
    True
    >>> supports(Params(failure_distribution="deterministic"))  # event engine
    False
    >>> supports(Params(retirement_threshold=3))
    False

    Finite repair-shop capacity (``Params.repair_servers``) is modeled
    by the *multi-job* CTMC engine (:mod:`repro.core.vectorized_multijob`,
    which partitions the shop by owning job and carries a queued-server
    lane); the single-job program has no queue compartment, so such
    params route to the event engine here:

    >>> supports(Params(repair_servers=8))
    False

    Correlated fault domains and injection campaigns
    (:mod:`repro.core.faultdomains`) stay on the fast path under
    exponential repairs — a struck in-shop server's stage restart is
    exact-in-law a no-op there.  Non-exponential repairs would need
    per-slot redraws, so that combination routes to the event engine:

    >>> from repro.core.faultdomains import FaultTopology
    >>> topo = FaultTopology(n_racks=8, rack_shock_rate=1e-5)
    >>> supports(Params(fault_domains=topo))
    True
    >>> supports(Params(fault_domains=topo, repair_distribution="weibull"))
    False
    """
    return not unsupported_reasons(params)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def _initial_counts(p: Params):
    total = p.working_pool_size + p.spare_pool_size
    n_bad = int(round(p.systematic_failure_fraction * total))
    bad_w = round(n_bad * p.working_pool_size / total)
    bad_s = n_bad - bad_w

    def split(n_take, pool_good, pool_bad):
        frac_bad = pool_bad / max(pool_good + pool_bad, 1)
        take_bad = int(round(n_take * frac_bad))
        return n_take - take_bad, take_bad

    w_good, w_bad = p.working_pool_size - bad_w, bad_w
    run_g, run_b = split(p.job_size, w_good, w_bad)
    w_good -= run_g
    w_bad -= run_b
    n_sb = min(p.warm_standbys, w_good + w_bad)
    sb_g, sb_b = split(n_sb, w_good, w_bad)
    w_good -= sb_g
    w_bad -= sb_b
    return {
        "run": [run_g, run_b, 0, 0],
        "sb": [sb_g, sb_b, 0, 0],
        "fw": [w_good, w_bad, 0, 0],
        "fs": [0, 0, p.spare_pool_size - bad_s, bad_s],
    }


def _age_dtype(p: Params):
    """Dtype of the hazard-age / repair-countdown lanes.

    The float64 carve-out (``Params.age_dtype``) needs the jax x64 flag;
    without it jnp would silently downcast to float32, so requesting it
    unenabled is a hard error rather than a quiet no-op.
    """
    if p.age_dtype == "float64":
        if not jax.config.jax_enable_x64:
            raise ValueError(
                "Params.age_dtype='float64' requires the jax x64 flag: "
                "set JAX_ENABLE_X64=1 or "
                'jax.config.update("jax_enable_x64", True) before '
                "simulating (float64 arrays silently degrade to float32 "
                "otherwise)")
        return jnp.float64
    return jnp.float32


def _initial_state_batch(pts, R: int, max_runs: int,
                         rkind: str = "exponential",
                         n_slots: int = 0,
                         scen=None) -> Dict[str, jnp.ndarray]:
    """Padded initial state for a structural grid, point-major (P*R, ...).

    All points share one compartment layout, so structural parameters
    (job_size, pool sizes, warm_standbys, systematic fraction, job_length,
    host-selection offset) enter purely as per-point initial *values*:
    compartments a small point does not populate sit at zero occupancy and
    therefore carry zero rates — inert in the event race.  That padding is
    what lets one compiled program cover every structure in the grid.

    ``rkind`` / ``n_slots`` size the repair-slot lane (non-exponential
    repairs only): ``repair_rem`` +inf marks a free slot.

    ``scen`` is the static scenario key ``(D, codes)`` from
    :func:`repro.core.faultdomains.scenario_key` — it adds the
    replacement-deficit lane, the per-domain shock counters, and (when
    the flattened campaign schedule is non-empty) the schedule pointer
    and maintenance flag.
    """
    P = len(pts)
    B = P * R
    counts = [_initial_counts(p) for p in pts]
    adt = _age_dtype(pts[0])

    def tile(key):
        arr = np.asarray([c[key] for c in counts], np.float32)   # (P, 4)
        return jnp.asarray(np.repeat(arr, R, axis=0))            # (P*R, 4)

    def per_point(vals):
        return jnp.asarray(np.repeat(np.asarray(vals, np.float32), R))

    state = {k: tile(k) for k in ("run", "sb", "fw", "fs")}
    state["auto"] = jnp.zeros((B, 4), jnp.float32)
    state["man"] = jnp.zeros((B, 4), jnp.float32)
    state["t"] = per_point([p.host_selection_time for p in pts])
    state["work_left"] = per_point([p.job_length for p in pts])
    state["timer"] = jnp.full((B,), jnp.inf, jnp.float32)
    state["stall_start"] = jnp.zeros((B,), jnp.float32)
    state["phase"] = jnp.full((B,), COMPUTE, jnp.int32)
    #: phase age: compute minutes since the job last (re)started — the
    #: hazard clock of the non-exponential families (inert for
    #: exponential, where the process is memoryless)
    state["age"] = jnp.zeros((B,), adt)
    if rkind != "exponential":
        # repair-slot lane: one (class, stage, remaining) triple per
        # in-repair server; remaining counts down in wall-clock time and
        # never resets with the job (unlike the failure age above)
        state["repair_rem"] = jnp.full((B, n_slots), jnp.inf, adt)
        state["repair_cls"] = jnp.zeros((B, n_slots), jnp.int32)
        state["repair_stage"] = jnp.zeros((B, n_slots), jnp.int32)
    state["cur_run"] = jnp.zeros((B,), jnp.float32)
    #: compute minutes since the last durable checkpoint (resets at every
    #: write, restart, and completion); the failure's rollback charge and
    #: the write residual both read it — a dedicated lane instead of
    #: ``mod(phase_work, interval)``, which drifts under fp accumulation.
    #: Inert (stays 0-cost) when checkpoint_interval == 0.
    state["ckpt_work"] = jnp.zeros((B,), jnp.float32)
    #: 1.0 while the OVERHEAD phase is a checkpoint *write* (whose expiry
    #: resumes compute without resetting the hazard age), 0.0 otherwise
    state["in_ckpt"] = jnp.zeros((B,), jnp.float32)
    state["n_runs"] = jnp.zeros((B,), jnp.int32)
    state["run_durations"] = jnp.zeros((B, max_runs), jnp.float32)
    spec = pts[0].histogram
    sel = _selected_channels(spec)
    if sel:
        # only the channels the spec selects are carried through the
        # scan — unselected channels are compiled out of the state
        # entirely (smaller carry + one fewer scatter lane).  The grid
        # shares the first point's bin layout.
        state["hist"] = jnp.zeros((B, len(sel), spec.n_counts),
                                  jnp.float32)
        state["hist_edges"] = jnp.asarray(spec.edges(), jnp.float32)
    if scen is not None:
        D_dom, camp_codes = scen
        # outstanding replacements after bulk kills: the job unstalls
        # only when the whole struck block has been restored
        state["deficit"] = jnp.zeros((B,), jnp.float32)
        if D_dom:
            state["domain_shocks"] = jnp.zeros((B, D_dom), jnp.float32)
        if len(camp_codes):
            state["camp_idx"] = jnp.zeros((B,), jnp.int32)
        if faultdomains.MAINT_START in camp_codes:
            state["maint"] = jnp.zeros((B,), jnp.float32)
    for m in _METRICS:
        state[m] = jnp.zeros((B,), jnp.float32)
    return state


#: state entries with no leading replica axis (scan-invariant constants)
_UNBATCHED_STATE = ("hist_edges",)


def _selected_channels(spec) -> tuple:
    """Channels carried through the scan, in fixed HIST_CHANNELS order.

    The tuple is part of the compiled program (it sizes the in-scan
    accumulator), so it must be derived deterministically from the spec,
    never from dict/set iteration order.
    """
    if spec is None:
        return ()
    return tuple(ch for ch in HIST_CHANNELS if ch in spec.channels)


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _bucket_pad_state(state: Dict[str, jnp.ndarray], P: int, R: int,
                      P_pad: int, R_pad: int) -> Dict[str, jnp.ndarray]:
    """Pad a (P*R, ...) point-major state to (P_pad*R_pad, ...).

    Padding rows start in phase DONE with zero occupancies, so they carry
    zero rates and are inert for the entire scan — including the global
    early-exit check.  Extraction masks them out; only the shared shape
    signature (and therefore the compiled program) sees them.
    """
    out: Dict[str, jnp.ndarray] = {}
    for k, v in state.items():
        if k in _UNBATCHED_STATE:
            out[k] = v
            continue
        v = v.reshape((P, R) + v.shape[1:])
        pad = [(0, P_pad - P), (0, R_pad - R)] + [(0, 0)] * (v.ndim - 2)
        out[k] = jnp.pad(v, pad).reshape((P_pad * R_pad,) + v.shape[2:])
    real = ((jnp.arange(P_pad * R_pad) // R_pad < P)
            & (jnp.arange(P_pad * R_pad) % R_pad < R))
    out["phase"] = jnp.where(real, out["phase"], DONE)
    return out


def _initial_state(p: Params, R: int,
                   max_runs: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    rkind = hazards.repair_kind(p) or "exponential"
    return _initial_state_batch(
        [p], R, _max_runs_for([p]) if max_runs is None else max_runs,
        rkind, _repair_slots_for([p], rkind), faultdomains.scenario_key(p))


def _max_runs_for(pts) -> int:
    return max(p.max_run_records for p in pts)


def _repair_slots_for(pts, rkind: str) -> int:
    """Repair-slot lane width for a batched group (host-side, static).

    Auto-sizing keeps the overflow probability astronomically small:
    twice the expected shop occupancy (Little's law via the hazard-aware
    event-rate estimate) plus eight standard deviations of the Poisson
    in-shop count.  Rounded up to a power of two so repair-parameter
    grids of similar scale share one compiled program — but never past
    the physical bound (every server in repair at once), where overflow
    is impossible and extra width is pure per-step cost: the slot
    min/argmin/scatter ops are the lane's whole overhead.
    ``Params.repair_slots > 0`` overrides per point.
    """
    if rkind == "exponential":
        return 0
    n = 1
    for p in pts:
        total = p.working_pool_size + p.spare_pool_size
        if p.repair_slots > 0:
            want = min(p.repair_slots, total)
        else:
            occ = hazards.expected_repair_occupancy(p)
            # an infinite-mean repair stage (a disabled clock: the
            # server never returns) drives the Little's-law estimate to
            # inf/NaN; the physical cap is the honest answer there
            if not math.isfinite(occ):
                occ = float(total)
            want = min(int(2.0 * occ + 8.0 * math.sqrt(max(occ, 1.0)) + 8.0),
                       total)
        n = max(n, min(_next_pow2(want), total))
    return n


def _pick_classes(counts: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Categorical draws proportional to counts: (R, G, 4) x (R, G) -> (R, G).

    One cumsum/reduction pass covers all G per-pool picks of a step —
    the scan body is op-dispatch-bound on CPU, so fusing the four pool
    draws keeps step latency down.
    """
    total = jnp.maximum(counts.sum(-1), 1e-30)
    cdf = jnp.cumsum(counts, axis=-1) / total[..., None]
    return jnp.minimum(
        jnp.sum((u[..., None] >= cdf).astype(jnp.int32), -1), 3)


def _onehot(c: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.one_hot(c, 4, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# one transition
# ---------------------------------------------------------------------------

def _n_uniforms(kind: str, rkind: str = "exponential") -> int:
    """Uniform draws per step: the exponential program keeps its
    original 8-wide stream bit-for-bit; a non-exponential hazard family
    adds one lane (Exp(1) inversion draw for weibull, accept/reject for
    bathtub/lognormal) and a non-exponential repair family adds one
    more (the entry/escalation duration draw)."""
    return 8 + (kind != "exponential") + (rkind != "exponential")


def _step(s: Dict[str, jnp.ndarray], key_t: jax.Array, pv: jnp.ndarray,
          impl: Optional[str], kind: str = "exponential",
          rkind: str = "exponential",
          hist_channels: tuple = HIST_CHANNELS,
          scen=None, n_seg: int = 0,
          n_rseg: int = 0) -> Dict[str, jnp.ndarray]:
    R = s["t"].shape[0]
    u = jax.random.uniform(key_t, (R, _n_uniforms(kind, rkind)),
                           dtype=jnp.float32, minval=1e-12, maxval=1.0)
    return _step_u(s, u, pv, impl, kind, rkind, hist_channels, scen,
                   n_seg, n_rseg)


def _step_u(s: Dict[str, jnp.ndarray], u: jnp.ndarray, pv: jnp.ndarray,
            impl: Optional[str], kind: str = "exponential",
            rkind: str = "exponential",
            hist_channels: tuple = HIST_CHANNELS,
            scen=None, n_seg: int = 0,
            n_rseg: int = 0) -> Dict[str, jnp.ndarray]:
    """One CTMC transition for a batch of replicas.

    ``pv`` is either a single parameter vector shared by the whole batch
    or a (B, n_cols) matrix with one parameter row per replica — the
    layout the batched sweep uses after flattening the (points x
    replicas) grid.  Columns 0..15 are the base model parameters;
    the next ``hazards.hazard_col_count(kind, n_seg)`` columns are the
    failure-hazard block and the ``hazards.repair_col_count(rkind,
    n_rseg)`` after that the repair block, whose interpretations the
    *static* ``kind`` / ``rkind`` select (see :mod:`repro.core.hazards`).
    The closed-form families use the fixed 5 + 3 layout; the empirical
    family's blocks are ``[edges_a, rates_a, edges_b, rates_b]`` with
    the *static* segment counts ``n_seg`` / ``n_rseg`` sizing them —
    edge positions and rates stay traced, so a grid over fitted hazards
    from different log slices shares one compiled program.

    ``hist_channels`` is the static tuple of histogram channels the scan
    state carries (must match ``s["hist"].shape[1]``).

    ``scen`` is the static scenario key ``(D, codes)`` — when set, 2D +
    3L trailing scenario columns follow the repair columns (see
    :func:`repro.core.faultdomains.scenario_columns`) and the race gains
    D shock lanes plus (for a non-empty schedule) a campaign residual.
    Scenarios only reach this path with exponential repairs
    (``supports``), so ``scen`` and the repair-slot lane never co-exist.
    """
    n_hc = hazards.hazard_col_count(kind, n_seg)
    n_rc = hazards.repair_col_count(rkind, n_rseg)
    n_cols = 16 + n_hc + n_rc
    if pv.ndim == 1:
        cols = [pv[i] for i in range(16)]
        _c = lambda x: x            # param vs (B, 4) class arrays
    else:
        cols = [pv[:, i] for i in range(16)]
        _c = lambda x: x[:, None]
    (r_rand, r_sys, recovery, host_sel, waiting, auto_t, man_t,
     auto_fail, man_fail, p_auto, dp, du, ckpt, preempt_cost,
     warm_standbys, ckpt_cost) = cols

    def _vcol(lo, n):
        # contiguous column block (shared row or per-replica matrix);
        # the empirical segment arrays stay 1-/2-D instead of joining
        # the scalar unpack above
        return pv[lo:lo + n] if pv.ndim == 1 else pv[:, lo:lo + n]

    if kind == "empirical":
        # [rand edges (m-1), rand rates (m), sys edges (m-1), sys rates
        # (m)] — per-clock piecewise-constant hazards (hazard_columns)
        e_re = _vcol(16, n_seg - 1)
        e_rr = _vcol(16 + n_seg - 1, n_seg)
        e_se = _vcol(16 + 2 * n_seg - 1, n_seg - 1)
        e_sr = _vcol(16 + 3 * n_seg - 2, n_seg)
        hz = None
    else:
        hz = [pv[i] if pv.ndim == 1 else pv[:, i]
              for i in range(16, 16 + n_hc)]
    if rkind == "empirical":
        # [auto edges, auto rates, manual edges, manual rates] — stage
        # selection happens at slot entry below (repair_columns)
        r_ae = _vcol(16 + n_hc, n_rseg - 1)
        r_ar = _vcol(16 + n_hc + n_rseg - 1, n_rseg)
        r_me = _vcol(16 + n_hc + 2 * n_rseg - 1, n_rseg - 1)
        r_mr = _vcol(16 + n_hc + 3 * n_rseg - 2, n_rseg)
        rz = None
    else:
        rz = [pv[i] if pv.ndim == 1 else pv[:, i]
              for i in range(16 + n_hc, n_cols)]

    if scen is not None:
        # scenario columns: [rates (D), fractions (D), times (L),
        # kill fracs (L), target domains (L)] — all traced; only the
        # counts D / L and the schedule codes are static
        D_dom, camp_codes = scen
        Lc = len(camp_codes)
        has_maint = faultdomains.MAINT_START in camp_codes

        def _scol(lo, n):
            if not n:
                return None
            return pv[lo:lo + n] if pv.ndim == 1 else pv[:, lo:lo + n]

        shock_rate = _scol(n_cols, D_dom)
        dom_frac = _scol(n_cols + D_dom, D_dom)
        camp_t = _scol(n_cols + 2 * D_dom, Lc)
        camp_frac = _scol(n_cols + 2 * D_dom + Lc, Lc)
        camp_dom = _scol(n_cols + 2 * D_dom + 2 * Lc, Lc)

    u_time, u_pick, u_diag, u_wrong, u_cls, u_esc, u_succ, u_pool = (
        u[:, 0], u[:, 1], u[:, 2], u[:, 3], u[:, 4], u[:, 5], u[:, 6],
        u[:, 7])
    lane = 8
    u_haz = None
    if kind != "exponential":
        u_haz = u[:, lane]
        lane += 1
    u_dur = u[:, lane] if rkind != "exponential" else None

    computing = s["phase"] == COMPUTE
    in_overhead = s["phase"] == OVERHEAD
    stalled = s["phase"] == STALL
    active = s["phase"] != DONE
    # OVERHEAD flavor: a checkpoint *write* (timer expiry resumes compute
    # without resetting the hazard age) vs a recovery/restart (which does)
    in_ckpt_flag = s["in_ckpt"] > 0
    age = s["age"]
    # thinning families evaluate hazards on the float32 view: the
    # float64 age carve-out targets the weibull inversion / repair
    # countdown cancellations, not the (well-conditioned) hazard ratios
    age32 = age.astype(jnp.float32)

    # ---- rates (R, 16) ------------------------------------------------
    run = s["run"]
    # explicit f32: under the x64 flag (age_dtype carve-out) an
    # unannotated literal array would promote the whole rate matrix
    bad_mask = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    haz_weights = g_bar = hbar_r = hbar_s = None
    if kind == "weibull":
        # exact conditional inversion: the fleet's combined cumulative
        # hazard is C * age**k (all clocks share the shape k), so the
        # time-to-first-failure enters the race as a deterministic
        # residual and the failure channels carry no exponential rate.
        # haz_weights holds the per-channel hazard shares (age-invariant
        # because every clock shares the t**(k-1) profile) for the
        # failing-class pick below.
        c_rand, c_sys, w_k = hz[0], hz[1], hz[2]
        w_rand = run * _c(c_rand) * computing[:, None]
        w_sys = run * bad_mask[None, :] * _c(c_sys) * computing[:, None]
        haz_weights = jnp.concatenate([w_rand, w_sys], axis=-1)  # (B, 8)
        haz_resid = hazards.FAILURE_SAMPLERS["weibull"].conditional_residual(
            age, haz_weights.sum(-1), w_k, -jnp.log(u_haz))
        fail_rand = jnp.zeros_like(run)
        fail_sys = jnp.zeros_like(run)
    elif kind == "bathtub":
        # Ogata thinning: scale the exponential failure propensities by
        # the window majorant g_bar = max(g(age), g(age + W)) (valid by
        # convexity of g) and race a window-expiry phantom timer W; a
        # winning candidate is accepted below with prob g(age + dt)/g_bar.
        b_if, b_ti, b_ws, b_tw, b_win = hz[0], hz[1], hz[2], hz[3], hz[4]
        bt = hazards.FAILURE_SAMPLERS["bathtub"]
        g_bar = bt.majorant(age32, b_win, (b_if, b_ti, b_ws, b_tw))
        fail_rand = run * _c(r_rand) * g_bar[..., None] * computing[:, None]
        fail_sys = run * bad_mask[None, :] * _c(r_sys) * g_bar[..., None] \
            * computing[:, None]
        haz_resid = jnp.where(computing, b_win * jnp.ones_like(age32),
                              jnp.inf)
    elif kind == "lognormal":
        # Ogata thinning with the mode-located majorant: the lognormal
        # hazard is unimodal, so sup h over [age, age + W] is h at the
        # (numerically pre-located, traced) mode clipped into the
        # window.  Random and systematic clocks have different scales
        # and therefore different hazard *shapes* over age — each
        # family carries its own majorant and acceptance ratio
        # (thinning two independent NHPPs separately is exact).
        ln = hazards.FAILURE_SAMPLERS["lognormal"]
        l_sr, l_ss, l_sig, l_mode, l_win = hz[0], hz[1], hz[2], hz[3], hz[4]
        hbar_r = ln.majorant(age32, l_win, (l_sr, l_sig, l_mode))   # (B,)
        hbar_s = ln.majorant(age32, l_win, (l_ss, l_sig, l_mode))
        fail_rand = run * hbar_r[:, None] * computing[:, None]
        fail_sys = run * bad_mask[None, :] * hbar_s[:, None] \
            * computing[:, None]
        # both clocks disabled => zero window; disarm the expiry timer
        # instead of racing a zero residual forever
        win_eff = jnp.where(l_win > 0, l_win, jnp.inf)
        haz_resid = jnp.where(computing, win_eff * jnp.ones_like(age32),
                              jnp.inf)
    elif kind == "empirical":
        # Ogata thinning with the *exact* majorant: the window runs to
        # the nearest segment edge of either clock, over which both
        # hazards are constant — so the majorant is the current segment
        # rate and every in-window candidate is accepted (the accept
        # step below only guards fp edge crossings).  Phantom steps
        # occur only when a window-expiry timer re-anchors the race at
        # a segment boundary.  Random and systematic clocks carry their
        # own (edges, rates) columns and thin independently (exact for
        # two independent NHPPs).
        pe = hazards.FAILURE_SAMPLERS["empirical"]
        hbar_r = pe.hazard(age32, (e_re, e_rr))                     # (B,)
        hbar_s = pe.hazard(age32, (e_se, e_sr))
        fail_rand = run * hbar_r[:, None] * computing[:, None]
        fail_sys = run * bad_mask[None, :] * hbar_s[:, None] \
            * computing[:, None]
        win = jnp.minimum(hazards.piecewise_next_edge(age32, e_re),
                          hazards.piecewise_next_edge(age32, e_se))
        haz_resid = jnp.where(computing, win, jnp.inf)
    else:
        fail_rand = run * _c(r_rand) * computing[:, None]
        fail_sys = run * bad_mask[None, :] * _c(r_sys) * computing[:, None]
        haz_resid = None
    if rkind == "exponential":
        auto_rate = s["auto"] / jnp.maximum(_c(auto_t), 1e-9)
        man_rate = s["man"] / jnp.maximum(_c(man_t), 1e-9)
    else:
        # non-exponential repairs complete through the slot lane's
        # deterministic residual; the exponential repair channels carry
        # no rate (the auto/man compartment counts remain bookkeeping)
        auto_rate = jnp.zeros_like(run)
        man_rate = jnp.zeros_like(run)
    rate_parts = [fail_rand, fail_sys, auto_rate, man_rate]
    kx = K_EXP
    if scen is not None:
        if has_maint:
            # maintenance window: the repair shop is dark — gating the
            # exponential repair rates to zero is an exact pause/resume
            # (memorylessness); jnp.where keeps any inf in the rate
            # math from turning into 0*inf = NaN
            repair_on = (s["maint"] == 0.0)[:, None]
            auto_rate = jnp.where(repair_on, auto_rate, 0.0)
            man_rate = jnp.where(repair_on, man_rate, 0.0)
            rate_parts = [fail_rand, fail_sys, auto_rate, man_rate]
        if D_dom:
            # shared-shock lanes: one exponential clock per fault
            # domain, live in every non-DONE phase (a rack PDU does not
            # care whether the job is computing) — only the trailing
            # * active masks them
            sr = shock_rate if pv.ndim == 2 else jnp.broadcast_to(
                shock_rate, (run.shape[0], D_dom))
            rate_parts.append(sr)
            kx = K_EXP + D_dom
    rates = jnp.concatenate(rate_parts, axis=-1) * active[:, None]

    # residual column order matters for exact ties (argmin takes the
    # first): the repair-slot residual comes FIRST so a repair completing
    # exactly at job completion resolves repair-first — the event
    # engine's heap semantics (the repair timeout was scheduled before
    # the final phase's completion timeout, so it pops first at equal
    # timestamps).  The job then completes in the next step at dt=0.
    resid_cols = []
    coff = 0
    if scen is not None and Lc:
        # campaign schedule residual: time to the next scheduled entry.
        # Placed before every other residual so a scripted kill at the
        # exact instant of a timer/completion resolves campaign-first —
        # the event engine's ShockInjector breaks the same tie the same
        # way.  Entries fire one per step (same-time entries burn
        # successive dt=0 steps in schedule order).
        brows0 = jnp.arange(run.shape[0])
        ci = jnp.clip(s["camp_idx"], 0, Lc - 1)
        ct = camp_t[ci] if camp_t.ndim == 1 else camp_t[brows0, ci]
        camp_pending = active & (s["camp_idx"] < Lc)
        resid_cols.append(jnp.where(
            camp_pending, jnp.maximum(ct - s["t"], 0.0), jnp.inf))
        coff = 1
    roff = 0
    if rkind != "exponential":
        rep_rem = s["repair_rem"]
        resid_cols.append(jnp.where(
            active, rep_rem.min(-1).astype(jnp.float32), jnp.inf))
        roff = 1
    resid_cols += [
        jnp.where(computing, s["work_left"], jnp.inf),
        jnp.where(in_overhead, s["timer"], jnp.inf),
    ]
    if haz_resid is not None:
        resid_cols.append(haz_resid)
    # checkpoint-write residual, appended LAST so no existing event index
    # shifts and an exact tie with completion resolves completion-first
    # (a finished job does not pay a final write on either engine).  At
    # checkpoint_interval == 0 the column is identically +inf — the race
    # never picks it and trajectories match the interval-free program
    # bit for bit.
    resid_cols.append(jnp.where(
        computing & (ckpt > 0),
        jnp.maximum(ckpt - s["ckpt_work"], 0.0), jnp.inf))
    residuals = jnp.stack(resid_cols, axis=-1)

    dt, ev = ops.event_race(rates, residuals, u_time, u_pick, impl=impl)
    dt = jnp.where(active & jnp.isfinite(dt), dt, 0.0)

    cls = (ev % 4).astype(jnp.int32)
    is_fail = active & (ev < 8)
    is_sys = active & (ev >= 4) & (ev < 8)
    if kind == "weibull":
        # the failure arrives on the hazard residual (kx + coff + roff
        # + 2); pick the failing channel from the hazard shares.  u_pick
        # is only consumed by the race when an *exponential* channel
        # wins, so it is fresh (and independent of dt) here.
        total_w = jnp.maximum(haz_weights.sum(-1), 1e-30)
        cdf8 = jnp.cumsum(haz_weights, axis=-1) / total_w[:, None]
        pick8 = jnp.minimum(
            jnp.sum((u_pick[:, None] >= cdf8).astype(jnp.int32), -1), 7)
        haz_fail = active & (ev == kx + coff + roff + 2)
        is_fail = haz_fail
        is_sys = haz_fail & (pick8 >= 4)
        cls = jnp.where(haz_fail, pick8 % 4, cls).astype(jnp.int32)
    elif kind == "bathtub":
        # accept/reject: a rejected candidate (and the window-expiry
        # event ev == kx + coff + roff + 2) is a phantom — time and work
        # advance, no state transition fires.
        g_at = hazards.FAILURE_SAMPLERS["bathtub"].hazard(
            age32 + dt, (hz[0], hz[1], hz[2], hz[3]))
        accept = u_haz * g_bar < g_at
        is_fail = is_fail & accept
        is_sys = is_sys & accept
    elif kind == "lognormal":
        # accept a candidate with prob h_family(age + dt) / h_bar_family
        ln = hazards.FAILURE_SAMPLERS["lognormal"]
        h_r = ln.hazard(age32 + dt, (hz[0], hz[2]))
        h_s = ln.hazard(age32 + dt, (hz[1], hz[2]))
        cand_sys = (ev >= 4) & (ev < 8)
        h_at = jnp.where(cand_sys, h_s, h_r)
        h_bar = jnp.where(cand_sys, hbar_s, hbar_r)
        accept = u_haz * h_bar < h_at
        is_fail = is_fail & accept
        is_sys = is_sys & accept
    elif kind == "empirical":
        # inside the window the hazard equals the majorant, so this
        # accepts (u < 1 always); it only bites when fp rounding lands
        # age + dt across a segment edge, where comparing against the
        # *new* segment's rate keeps the thinned process exact
        pe = hazards.FAILURE_SAMPLERS["empirical"]
        h_r = pe.hazard(age32 + dt, (e_re, e_rr))
        h_s = pe.hazard(age32 + dt, (e_se, e_sr))
        cand_sys = (ev >= 4) & (ev < 8)
        h_at = jnp.where(cand_sys, h_s, h_r)
        h_bar = jnp.where(cand_sys, hbar_s, hbar_r)
        accept = u_haz * h_bar <= h_at
        is_fail = is_fail & accept
        is_sys = is_sys & accept
    if rkind == "exponential":
        is_auto = active & (ev >= 8) & (ev < 12)
        is_man = active & (ev >= 12) & (ev < 16)
    else:
        # a slot repair completed: the winning slot's stage and class
        # drive the same downstream completion logic the exponential
        # channels feed (channels 8..16 are rateless here)
        rows = jnp.arange(rep_rem.shape[0])
        won_slot = jnp.argmin(rep_rem, axis=-1)
        is_rep = active & (ev == kx + coff)
        done_stage = s["repair_stage"][rows, won_slot]
        cls = jnp.where(is_rep, s["repair_cls"][rows, won_slot],
                        cls).astype(jnp.int32)
        is_auto = is_rep & (done_stage == 0)
        is_man = is_rep & (done_stage == 1)
    is_complete = active & (ev == kx + coff + roff)
    is_timer = active & (ev == kx + coff + roff + 1)
    # checkpoint-write event: the last residual column (after the
    # hazard-window column when the family has one)
    ckpt_ev = kx + coff + roff + 2 + (1 if haz_resid is not None else 0)
    is_ckpt = active & (ev == ckpt_ev)

    if scen is not None:
        # ---- correlated shock / campaign event sizing -------------------
        # Shock events arrive on lanes [K_EXP, kx); campaign entries on
        # the first residual (ev == kx).  A shock or scripted kill is
        # mutually exclusive with every other event this step, so the
        # idle failure-path uniforms (u_diag/u_wrong/u_cls/u_esc/u_succ)
        # are free to stochastically round the per-pool kill counts
        # without widening the per-step stream — which is what keeps the
        # rate->0 / empty-campaign programs bit-identical to the
        # scenario-free ones.
        brows = jnp.arange(run.shape[0])
        false_b = jnp.zeros_like(active)
        if D_dom:
            is_shock = active & (ev >= K_EXP) & (ev < kx)
            shock_dom = jnp.clip(ev - K_EXP, 0, D_dom - 1)
        else:
            is_shock = false_b
            shock_dom = jnp.zeros_like(ev)
        if Lc:
            is_camp = camp_pending & (ev == kx)
            code_arr = jnp.asarray(camp_codes, jnp.int32)
            cur_code = code_arr[ci]
            is_kill = is_camp & (cur_code == faultdomains.KILL)
            is_m_on = is_camp & (cur_code == faultdomains.MAINT_START)
            is_m_off = is_camp & (cur_code == faultdomains.MAINT_END)
            kdom = (camp_dom[ci] if camp_dom.ndim == 1
                    else camp_dom[brows, ci]).astype(jnp.int32)
            kfrac = (camp_frac[ci] if camp_frac.ndim == 1
                     else camp_frac[brows, ci])
        else:
            is_camp = is_kill = is_m_on = is_m_off = false_b
            kdom = jnp.zeros_like(ev)
            kfrac = jnp.zeros_like(u_time)
        struck = is_shock | is_kill
        dom = jnp.where(is_shock, shock_dom, kdom)
        if D_dom:
            dfrac = (dom_frac[dom] if dom_frac.ndim == 1
                     else dom_frac[brows, dom])
        else:
            dfrac = jnp.zeros_like(u_time)
        frac = jnp.where(is_kill, kfrac, dfrac)

        def _syscomp(cnt, tgt, uu):
            # systematic (stratified) rounding of a fractional per-class
            # target composition ``tgt`` (B, 4): returns integer
            # per-class counts n_c in {floor(tgt_c), ceil(tgt_c)} that
            # sum to the stochastic rounding of tgt.sum() — one uniform
            # drives both the total and its split.  With integer
            # occupancies and tgt_c <= cnt_c, n_c <= cnt_c always, so
            # compartments keep the whole-server invariant the repair
            # race's one-hot removals rely on.
            C = jnp.cumsum(tgt, axis=-1)
            Cm = jnp.concatenate([jnp.zeros_like(C[:, :1]), C[:, :-1]],
                                 axis=-1)
            up = jnp.maximum(jnp.ceil(C - uu[:, None]), 0.0)
            lo = jnp.maximum(jnp.ceil(Cm - uu[:, None]), 0.0)
            return up - lo

        def _sround(x, uu):
            fl = jnp.floor(x)
            return fl + (uu < x - fl).astype(jnp.float32)

        fr = frac[:, None]
        rm_run = _syscomp(run, run * fr, u_diag) * struck[:, None]
        rm_sb = _syscomp(s["sb"], s["sb"] * fr, u_wrong) * struck[:, None]
        rm_fw = _syscomp(s["fw"], s["fw"] * fr, u_cls) * struck[:, None]
        rm_fs = _syscomp(s["fs"], s["fs"] * fr, u_esc) * struck[:, None]
        k_run = rm_run.sum(-1)
        k_sb = rm_sb.sum(-1)
        k_fw = rm_fw.sum(-1)
        k_fs = rm_fs.sum(-1)
        # in-shop members re-break: exact-in-law a no-op under the
        # exponential stages this path guarantees — counted, not moved
        shop_tot0 = jnp.maximum(s["auto"].sum(-1) + s["man"].sum(-1), 0.0)
        k_shop = jnp.where(struck,
                           _sround(shop_tot0 * frac, u_succ), 0.0)
        # bulk replacement through the same standby -> working -> spare
        # waterfall a single failure uses, sized against the post-kill
        # pool occupancies (all integers, so the min-chain is exact)
        sb_rem = jnp.maximum(s["sb"].sum(-1) - k_sb, 0.0)
        fw_rem = jnp.maximum(s["fw"].sum(-1) - k_fw, 0.0)
        fs_rem = jnp.maximum(s["fs"].sum(-1) - k_fs, 0.0)
        t_sb = jnp.minimum(k_run, sb_rem)
        t_fw = jnp.minimum(k_run - t_sb, fw_rem)
        t_fs = jnp.minimum(k_run - t_sb - t_fw, fs_rem)
        shortfall = jnp.maximum(k_run - t_sb - t_fw - t_fs, 0.0)

        def _take(cnt, t, tot, uu):
            ratio = (t / jnp.maximum(tot, 1.0))[:, None]
            return _syscomp(cnt, cnt * ratio, uu)

        # the take compositions reuse u_pool (idle on shock steps) with
        # golden-ratio decorrelation shifts — correlated rounding across
        # pools is harmless (totals are exact; only the class split of a
        # single bulk event is approximated)
        PHI = 0.6180339887498949
        mv_sb = _take(s["sb"] - rm_sb, t_sb, sb_rem, u_pool)
        mv_fw = _take(s["fw"] - rm_fw, t_fw, fw_rem,
                      jnp.mod(u_pool + PHI, 1.0))
        mv_fs = _take(s["fs"] - rm_fs, t_fs, fs_rem,
                      jnp.mod(u_pool + 2.0 * PHI, 1.0))
        sh_affects = struck & (k_run > 0)
        # full replacements while already stalled must not clobber the
        # STALL — the original deficit is still outstanding
        sh_resolves = sh_affects & (shortfall <= 1e-6) & ~stalled
        sh_stalls = sh_affects & ~sh_resolves
        # one concurrent group restart: host selection / preemption
        # waits overlap across the block, so the overhead is charged
        # once per event, not per server
        shock_timer = (recovery
                       + jnp.where(t_fw + t_fs > 1e-6, host_sel, 0.0)
                       + jnp.where(t_fs > 1e-6, waiting + preempt_cost,
                                   0.0))

    ns = dict(s)
    ns["t"] = s["t"] + dt

    # ---- progress accounting -------------------------------------------
    # work accrues during every COMPUTE interval regardless of which event
    # ends it (failures, repair completions, job completion); failures —
    # and bulk shocks that gut the running block — roll back to the last
    # durable checkpoint.  The rollback charge is the dedicated
    # ``ckpt_work`` lane (work since the last write), so ``banked`` can go
    # negative on a failing step: it restores the already-banked portion
    # of the doomed interval, keeping the running sums algebraically
    # exact (sum(banked) = progress_total - lost_total) with no mod
    # arithmetic.  checkpoint_interval == 0 keeps the historical model:
    # nothing is ever lost.
    progress = jnp.where(computing, dt, 0.0)
    rollback = is_fail
    if scen is not None:
        rollback = rollback | (sh_affects & (computing | in_ckpt_flag))
    new_ckpt_work = s["ckpt_work"] + progress
    lost = jnp.where(rollback & (ckpt > 0), new_ckpt_work, 0.0)
    banked = progress - lost
    ns["work_left"] = s["work_left"] - banked
    ns["useful_work"] = s["useful_work"] + banked
    ns["lost_work"] = s["lost_work"] + lost
    # reset at every rollback, write start (durable from write start),
    # and completion; a paid write freezes the lane at 0 until compute
    # resumes (progress == 0 through OVERHEAD)
    ns["ckpt_work"] = jnp.where(rollback | is_ckpt | is_complete,
                                0.0, new_ckpt_work)

    # ---- completion / timer ----------------------------------------------
    # deterministic timers advance with the clock even when a concurrent
    # (repair) event ends the step first
    timer_dec = jnp.where(in_overhead, s["timer"] - dt, s["timer"])
    ns["phase"] = jnp.where(is_complete, DONE, s["phase"])
    ns["phase"] = jnp.where(is_timer, COMPUTE, ns["phase"])
    ns["timer"] = jnp.where(is_timer, jnp.inf, timer_dec)
    ns["total_time"] = jnp.where(is_complete, ns["t"], s["total_time"])

    # ---- checkpoint writes ----------------------------------------------
    # a paid write runs as an OVERHEAD interval flagged in_ckpt (its
    # expiry must NOT reset the hazard age: the failure clock is frozen
    # during the write, not restarted); a free write (checkpoint_cost ==
    # 0) banks the checkpoint without leaving COMPUTE.  Overhead wall
    # time accrues as it elapses, so a shock interrupting a write
    # charges only the partial write actually performed.
    paid_ckpt = is_ckpt & (ckpt_cost > 0)
    ns["phase"] = jnp.where(paid_ckpt, OVERHEAD, ns["phase"])
    ns["timer"] = jnp.where(paid_ckpt, ckpt_cost, ns["timer"])
    ns["in_ckpt"] = jnp.where(is_timer, 0.0,
                              jnp.where(paid_ckpt, 1.0, s["in_ckpt"]))
    ns["checkpoint_overhead"] = s["checkpoint_overhead"] \
        + jnp.where(in_ckpt_flag, dt, 0.0)

    # ---- exact run durations -------------------------------------------
    # a "run" is one useful-compute interval between restarts (start or
    # post-failure restart -> next failure or job completion), matching
    # the event engine's RunResult.run_durations (gross of checkpoint
    # rollback).  Repair completions during COMPUTE do not end a run.
    # Records land in a fixed ring buffer: slot n_runs % max_runs, so
    # overflow overwrites the oldest record; the overwrite count surfaces
    # downstream as the run_duration_truncated stat, and per-replica
    # means stay exact via sum(records) = useful + lost - cur_run.
    record = is_fail | is_complete
    if scen is not None:
        # a shock gutting the running set ends the in-flight compute
        # interval exactly like a failure would — including when it
        # lands mid-checkpoint-write (the compute interval is still the
        # one the interrupted write belongs to)
        record = record | (sh_affects & (computing | in_ckpt_flag))
    run_val = s["cur_run"] + progress
    max_runs = s["run_durations"].shape[1]
    if max_runs:    # static shape: max_runs=0 compiles the buffer out
        rows = jnp.arange(run_val.shape[0])
        slot = jnp.mod(s["n_runs"], max_runs)
        kept = s["run_durations"][rows, slot]
        ns["run_durations"] = s["run_durations"].at[rows, slot].set(
            jnp.where(record, run_val, kept))
    ns["n_runs"] = s["n_runs"] + record.astype(jnp.int32)
    ns["cur_run"] = jnp.where(record, 0.0, run_val)

    # ---- phase age (hazard clock) ---------------------------------------
    # advances only through COMPUTE time (phantoms included) and resets
    # when the recovery timer restarts the job — the event engine's
    # "failure clocks restart when the job restarts" semantics.  After a
    # failure the phase is OVERHEAD/STALL, so the frozen age is never
    # read before the reset.  A checkpoint-WRITE expiry resumes compute
    # with the age it froze at — the write suspends the failure clock,
    # it does not restart the fleet.
    ns["age"] = jnp.where(is_timer & ~in_ckpt_flag, 0.0, age + progress)

    # ---- failure handling ---------------------------------------------------
    f = is_fail.astype(jnp.float32)
    ns["n_failures"] = s["n_failures"] + f
    ns["n_systematic_failures"] = s["n_systematic_failures"] \
        + is_sys.astype(jnp.float32)
    ns["n_random_failures"] = s["n_random_failures"] \
        + (is_fail & ~is_sys).astype(jnp.float32)

    diagnosed = is_fail & (u_diag < dp)
    wrong = diagnosed & (u_wrong < du)
    ns["n_undiagnosed"] = s["n_undiagnosed"] \
        + (is_fail & ~diagnosed).astype(jnp.float32)
    ns["n_misdiagnosed"] = s["n_misdiagnosed"] + wrong.astype(jnp.float32)

    # one stacked categorical draw for all four pools; rep1h (the one-hot
    # of the raced class) doubles as the right-diagnosis removal mask
    picks = _pick_classes(
        jnp.stack([run, s["sb"], s["fw"], s["fs"]], axis=1),
        jnp.stack([u_cls, u_cls, u_pool, u_pool], axis=1))     # (R, 4)
    pick1h = jax.nn.one_hot(picks, 4, dtype=jnp.float32)       # (R, 4, 4)
    rep1h = _onehot(cls)
    rm1h = jnp.where(wrong[:, None], pick1h[:, 0], rep1h) \
        * diagnosed[:, None]
    ns["run"] = ns["run"] - rm1h
    ns["auto"] = ns["auto"] + rm1h

    # replacement waterfall (only when a server was removed)
    sb_tot = s["sb"].sum(-1)
    fw_tot = s["fw"].sum(-1)
    fs_tot = s["fs"].sum(-1)
    use_sb = diagnosed & (sb_tot > 0)
    use_fw = diagnosed & ~use_sb & (fw_tot > 0)
    use_fs = diagnosed & ~use_sb & ~use_fw & (fs_tot > 0)
    goes_stall = diagnosed & ~use_sb & ~use_fw & ~use_fs

    take = (pick1h[:, 1] * use_sb[:, None]
            + pick1h[:, 2] * use_fw[:, None]
            + pick1h[:, 3] * use_fs[:, None])
    ns["sb"] = ns["sb"] - pick1h[:, 1] * use_sb[:, None]
    ns["fw"] = ns["fw"] - pick1h[:, 2] * use_fw[:, None]
    ns["fs"] = ns["fs"] - pick1h[:, 3] * use_fs[:, None]
    ns["run"] = ns["run"] + take
    ns["n_standby_swaps"] = s["n_standby_swaps"] + use_sb.astype(jnp.float32)
    ns["n_host_selections"] = s["n_host_selections"] \
        + (use_fw | use_fs).astype(jnp.float32)
    ns["n_preemptions"] = s["n_preemptions"] + use_fs.astype(jnp.float32)

    fail_timer = (recovery
                  + jnp.where(use_fw | use_fs, host_sel, 0.0)
                  + jnp.where(use_fs, waiting + preempt_cost, 0.0))
    resolves = is_fail & ~goes_stall
    ns["timer"] = jnp.where(resolves, fail_timer, ns["timer"])
    ns["phase"] = jnp.where(resolves, OVERHEAD, ns["phase"])
    ns["phase"] = jnp.where(goes_stall, STALL, ns["phase"])
    ns["stall_start"] = jnp.where(goes_stall, ns["t"], s["stall_start"])
    ns["recovery_overhead"] = s["recovery_overhead"] \
        + jnp.where(resolves, recovery, 0.0)

    # ---- repair completions ----------------------------------------------
    ns["auto"] = ns["auto"] - rep1h * is_auto[:, None]
    ns["n_auto_repairs"] = s["n_auto_repairs"] + is_auto.astype(jnp.float32)
    escalate = is_auto & (u_esc >= p_auto)
    ns["man"] = ns["man"] + rep1h * escalate[:, None]
    ns["man"] = ns["man"] - rep1h * is_man[:, None]
    ns["n_manual_repairs"] = s["n_manual_repairs"] + is_man.astype(jnp.float32)

    finishes = (is_auto & ~escalate) | is_man
    fail_prob = jnp.where(is_man, man_fail, auto_fail)
    healed = finishes & (u_succ >= fail_prob)
    ns["n_failed_repairs"] = s["n_failed_repairs"] \
        + (finishes & ~healed).astype(jnp.float32)
    out_cls = jnp.where(healed, cls - (cls % 2), cls)  # bad -> good
    out1h = _onehot(out_cls)

    # returning server: stalled job > standby refill > origin pool
    to_stalled = finishes & stalled
    to_sb = finishes & ~to_stalled & (ns["sb"].sum(-1) < warm_standbys)
    to_pool = finishes & ~to_stalled & ~to_sb
    spare_origin = out_cls >= 2
    ns["run"] = ns["run"] + out1h * to_stalled[:, None]
    ns["sb"] = ns["sb"] + out1h * to_sb[:, None]
    ns["fw"] = ns["fw"] + out1h * (to_pool & ~spare_origin)[:, None]
    ns["fs"] = ns["fs"] + out1h * (to_pool & spare_origin)[:, None]
    if scen is None:
        unstall = to_stalled
    else:
        # outstanding-replacement deficit: a bulk kill can leave the
        # stalled job short several servers; each returning repair
        # retires one unit and the job only restarts once the whole
        # block is restored (struck / goes_stall / finishes are
        # mutually exclusive per step, so the chain is race-free)
        deficit = (s["deficit"]
                   + jnp.where(goes_stall, 1.0, 0.0)
                   + jnp.where(struck, shortfall, 0.0))
        deficit = jnp.where(to_stalled,
                            jnp.maximum(deficit - 1.0, 0.0), deficit)
        unstall = to_stalled & (deficit <= 1e-6)
        ns["deficit"] = deficit
    ns["phase"] = jnp.where(unstall, OVERHEAD, ns["phase"])
    ns["timer"] = jnp.where(unstall, recovery, ns["timer"])
    ns["stall_time"] = s["stall_time"] \
        + jnp.where(unstall, ns["t"] - s["stall_start"], 0.0)
    ns["recovery_overhead"] = ns["recovery_overhead"] \
        + jnp.where(unstall, recovery, 0.0)

    if scen is not None:
        # ---- correlated shock / campaign execution ----------------------
        # the struck block leaves every compartment at once and enters
        # the automated-repair stage; replacements drawn above through
        # the standard waterfall join the run set in the same step.
        # In-shop casualties (k_shop) re-break in place: under the
        # exponential stages this path guarantees, a restarted repair is
        # distributed exactly like the remaining one (memorylessness),
        # so they are counted but not moved.
        w = struck[:, None]
        ns["run"] = jnp.where(
            w, ns["run"] - rm_run + mv_sb + mv_fw + mv_fs, ns["run"])
        ns["sb"] = jnp.where(w, ns["sb"] - rm_sb - mv_sb, ns["sb"])
        ns["fw"] = jnp.where(w, ns["fw"] - rm_fw - mv_fw, ns["fw"])
        ns["fs"] = jnp.where(w, ns["fs"] - rm_fs - mv_fs, ns["fs"])
        ns["auto"] = jnp.where(
            w, ns["auto"] + rm_run + rm_sb + rm_fw + rm_fs, ns["auto"])
        ns["n_domain_shocks"] = s["n_domain_shocks"] \
            + is_shock.astype(jnp.float32)
        ns["n_campaign_events"] = s["n_campaign_events"] \
            + is_camp.astype(jnp.float32)
        ns["n_shock_killed"] = s["n_shock_killed"] \
            + jnp.where(struck, k_run + k_sb + k_fw + k_fs + k_shop, 0.0)
        ns["n_standby_swaps"] = ns["n_standby_swaps"] \
            + jnp.where(struck, t_sb, 0.0)
        ns["n_host_selections"] = ns["n_host_selections"] \
            + jnp.where(struck, t_fw + t_fs, 0.0)
        ns["n_preemptions"] = ns["n_preemptions"] \
            + jnp.where(struck, t_fs, 0.0)
        if D_dom:
            ns["domain_shocks"] = s["domain_shocks"].at[brows, dom].add(
                is_shock.astype(jnp.float32))
        if Lc:
            ns["camp_idx"] = s["camp_idx"] + is_camp.astype(jnp.int32)
        if has_maint:
            ns["maint"] = jnp.where(
                is_m_on, 1.0, jnp.where(is_m_off, 0.0, s["maint"]))
        ns["timer"] = jnp.where(sh_resolves, shock_timer, ns["timer"])
        ns["phase"] = jnp.where(sh_resolves, OVERHEAD, ns["phase"])
        ns["phase"] = jnp.where(sh_stalls, STALL, ns["phase"])
        # a shock aborts any in-flight checkpoint write: the ensuing
        # OVERHEAD is a recovery (age resets when it expires)
        ns["in_ckpt"] = jnp.where(sh_affects, 0.0, ns["in_ckpt"])
        ns["stall_start"] = jnp.where(sh_stalls & ~stalled, ns["t"],
                                      ns["stall_start"])
        ns["recovery_overhead"] = ns["recovery_overhead"] \
            + jnp.where(sh_resolves, recovery, 0.0)

    # ---- repair-slot lane (non-exponential repairs) ----------------------
    # repairs run on wall-clock time: every occupied slot counts down by
    # dt through COMPUTE, OVERHEAD, and STALL alike, never resetting with
    # the job.  A completion frees the winning slot (escalation re-arms
    # it with a fresh manual-stage draw); a diagnosed failure claims the
    # first free slot with an auto-stage draw.  Durations are sampled at
    # entry by exact inverse CDF — precisely when the event engine's
    # RepairShop samples them — through the shared HazardSampler
    # machinery.  Entry and completion are mutually exclusive in one
    # step (single event), so one duration lane (u_dur) serves both.
    if rkind != "exponential":
        rsampler = hazards.REPAIR_SAMPLERS[rkind]
        adt = rep_rem.dtype
        srows = jnp.arange(rep_rem.shape[0])
        rem = jnp.where(active[:, None], rep_rem - dt.astype(adt)[:, None],
                        rep_rem)
        # completion (won_slot) and entry (first free slot) are mutually
        # exclusive per step — a single event ended it — so one fused
        # scatter per slot array covers both; the per-step slot cost is
        # this min/argmin/scatter traffic, so fusing matters
        free = jnp.isinf(rem)
        any_free = free.any(-1)
        fslot = jnp.argmax(free, axis=-1)
        entered = diagnosed & any_free
        rm_cls = jnp.where(wrong, picks[:, 0], cls).astype(jnp.int32)
        # entry and escalation are mutually exclusive, so one quantile
        # evaluation with the stage-selected scale column serves both
        # (a second ndtri/pow per step is pure waste in the hot scan)
        if rkind == "empirical":
            # stage-select whole (edges, rates) blocks, then one
            # segment-inversion quantile; broadcast shared rows to the
            # batch so jnp.where can mix stages per replica
            B = run.shape[0]

            def _brow(x):
                return x if x.ndim == 2 else jnp.broadcast_to(
                    x, (B,) + x.shape)

            esc2 = escalate[:, None]
            q_dur = rsampler.quantile(
                u_dur, jnp.where(esc2, _brow(r_me), _brow(r_ae)),
                jnp.where(esc2, _brow(r_mr), _brow(r_ar))).astype(adt)
        else:
            q_dur = rsampler.quantile(
                u_dur, jnp.where(escalate, rz[1], rz[0]), rz[2]).astype(adt)
        idx = jnp.where(is_rep, won_slot, fslot)
        cur_rem = rem[srows, idx]
        cur_stage = s["repair_stage"][srows, idx]
        ns["repair_rem"] = rem.at[srows, idx].set(
            jnp.where(finishes, jnp.inf,
                      jnp.where(escalate | entered, q_dur, cur_rem)))
        ns["repair_stage"] = s["repair_stage"].at[srows, idx].set(
            jnp.where(escalate, 1, jnp.where(entered, 0, cur_stage)))
        ns["repair_cls"] = s["repair_cls"].at[srows, idx].set(
            jnp.where(entered, rm_cls, s["repair_cls"][srows, idx]))
        # a full lane: the incoming server stays in the shop forever
        # (bookkeeping-consistent but wrong); surfaced as a metric and a
        # RuntimeWarning downstream — raise Params.repair_slots
        ns["n_repair_overflow"] = s["n_repair_overflow"] \
            + (diagnosed & ~any_free).astype(jnp.float32)

    # ---- streaming histograms -------------------------------------------
    # O(bins) distribution accumulators with no run-count bound (the ring
    # buffer above truncates; these do not).  Bin layout mirrors
    # histograms.Histogram: searchsorted(side="right") over log-spaced
    # edges with under/overflow slots.  A failure resolved through the
    # waterfall records its downtime (ETTR) immediately; a stalled
    # failure records when the repaired server restarts the job, so the
    # stall interval is included — matching the event engine's
    # failure-to-restart timing.
    if "hist" in s:
        stall_wait = ns["t"] - s["stall_start"]
        ended = resolves | unstall
        downtime = jnp.where(resolves, fail_timer, stall_wait + recovery)
        acquire_wait = jnp.where(resolves, fail_timer - recovery, stall_wait)
        if scen is not None:
            # a shock resolved through the waterfall records its planned
            # downtime at the resolve instant, like a plain failure
            ended = ended | sh_resolves
            downtime = jnp.where(sh_resolves, shock_timer, downtime)
            acquire_wait = jnp.where(sh_resolves, shock_timer - recovery,
                                     acquire_wait)
        # one fused searchsorted + scatter-add across the selected
        # channels (static ``hist_channels``, HIST_CHANNELS order) —
        # per-channel scatters multiply the per-step accumulator cost,
        # and unselected channels are compiled out entirely
        channel_vals = {"run_duration": (run_val, record),
                        "recovery": (downtime, ended),
                        "waiting": (acquire_wait, ended),
                        # one record per finished job: the realized
                        # useful-work fraction of its wall clock (pair
                        # with a (0.01, 1.0) bin range)
                        "goodput": (ns["useful_work"]
                                    / jnp.maximum(ns["t"], 1e-9),
                                    is_complete)}
        vals = jnp.stack([channel_vals[ch][0] for ch in hist_channels],
                         axis=1)
        masks = jnp.stack([channel_vals[ch][1] for ch in hist_channels],
                          axis=1)                       # (B, n_sel)
        idx = jnp.searchsorted(s["hist_edges"], vals, side="right")
        rows = jnp.arange(vals.shape[0])[:, None]
        chan = jnp.arange(vals.shape[1])[None, :]
        ns["hist"] = s["hist"].at[rows, chan, idx].add(
            masks.astype(jnp.float32))
    return ns


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _params_vector(p: Params) -> jnp.ndarray:
    base = np.asarray([
        p.random_failure_rate, p.systematic_failure_rate, p.recovery_time,
        p.host_selection_time, p.waiting_time, p.auto_repair_time,
        p.manual_repair_time, p.auto_repair_failure_probability,
        p.manual_repair_failure_probability, p.automated_repair_probability,
        p.diagnosis_probability, p.diagnosis_uncertainty,
        p.checkpoint_interval, p.preemption_cost, float(p.warm_standbys),
        p.checkpoint_cost,
    ], np.float32)
    parts = [base, hazards.hazard_columns(p), hazards.repair_columns(p)]
    if faultdomains.scenario_key(p) is not None:
        # trailing scenario columns (2D + 3L) — traced, so a shock-rate
        # or campaign-time grid shares one compiled program
        parts.append(faultdomains.scenario_columns(p).astype(np.float32))
    return jnp.asarray(np.concatenate(parts))


def default_max_steps(p: Params, safety: float = 2.0) -> int:
    """Expected events (failures x ~3 repair/replace hops) + head-room.

    Hazard-aware: the event rate comes from
    :func:`repro.core.hazards.effective_event_rate` (the age-zero-ish
    hazard governs short restart-reset phases, so bathtub infant
    mortality or Weibull wear-in can multiply the exponential estimate),
    and bathtub thinning additionally budgets its window-expiry phantom
    steps.
    """
    lam = hazards.effective_event_rate(p)
    horizon = p.job_length * (1.0 + lam * (p.recovery_time + 2.0))
    extra = 0.0
    if p.fault_domains is not None or p.campaign is not None:
        # shocks + campaign entries + their bulk repair traffic, and the
        # horizon stretch of maintenance windows / shock recoveries
        extra, extra_h = faultdomains.scenario_budget(p, horizon)
        horizon += extra_h
    steps = max(128, int((lam * horizon + extra) * 3.2 * safety))
    if p.checkpoint_interval > 0:
        # every checkpoint_interval minutes of compute burns one
        # write-event step (plus its expiry step when the write is paid)
        writes = p.job_length / max(p.checkpoint_interval, 1e-9)
        steps += int(writes * (2.0 if p.checkpoint_cost > 0 else 1.0)
                     * safety)
    return steps + int(hazards.phantom_steps(p) * safety)


#: steps simulated per early-exit check (one compiled scan per chunk);
#: small chunks exit closer to the true max event count — the while-loop
#: bookkeeping per chunk is noise next to 64 scan steps
DEFAULT_CHUNK_STEPS = 64


def _struct_key(p: Params):
    """Hashable identity of a point's pool *structure*.

    With structure padding the compiled program no longer depends on any
    of this — initial occupancies are traced inputs — so the padded sweep
    path ignores it (``struct_key=None`` -> one compile).  It remains the
    grouping key of the legacy ``padded=False`` path, where it is passed
    as a static jit argument precisely to force one XLA program per
    structure (the behavior the structural-sweep benchmark A/Bs against).
    """
    return (p.job_size, p.working_pool_size, p.spare_pool_size,
            p.warm_standbys, round(p.systematic_failure_fraction, 6),
            round(p.job_length, 3), round(p.host_selection_time, 3))


def _chunk_loop(pv: jnp.ndarray, key: jax.Array, P: int, R: int,
                chunk: int, n_chunks, rem: int, impl: Optional[str],
                early_exit: bool, kind: str, rkind: str,
                hist_channels: tuple, scen,
                init_state: Dict[str, jnp.ndarray],
                n_seg: int = 0, n_rseg: int = 0):
    """Chunked scan with early exit; batch axis is B = P * R (point-major).

    The shared compute core of :func:`_run_chunked` (single-device jit)
    and :func:`_run_chunked_sharded` (per-shard body under shard_map,
    where R is the shard-local replica count and ``key`` the shard's
    folded key).  Runs exactly ``n_chunks * chunk + rem`` steps (minus
    chunks skipped by early exit).  ``n_chunks`` is a *traced* scalar —
    the while-loop trip count — so any two budgets with the same chunk
    size and remainder share one compiled program (the bucketed sweep
    path rounds the budget so ``rem == 0`` always).  Uniforms are drawn
    per *replica column* at the power-of-two width ``next_pow2(R)`` and
    sliced to R, then tiled across the P points: every sweep point sees
    common random numbers (the batched analogue of the event engine's
    same-seed-per-replication policy), and a bucket-padded run draws the
    identical stream for its real replica columns.
    """
    R_draw = _next_pow2(R)

    def scan_body(state, u):
        if P > 1:
            u = jnp.tile(u, (P, 1))
        return _step_u(state, u, pv, impl, kind, rkind, hist_channels,
                       scen, n_seg, n_rseg), None

    def run_chunk(state, i, n_steps):
        # one batched threefry call per chunk (a per-step split + draw is
        # the dominant scan cost on CPU); the non-exponential hazard /
        # repair families draw extra uniform lanes per step
        us = jax.random.uniform(jax.random.fold_in(key, i),
                                (n_steps, R_draw, _n_uniforms(kind, rkind)),
                                dtype=jnp.float32, minval=1e-12, maxval=1.0)
        if R_draw != R:
            us = us[:, :R]
        state, _ = jax.lax.scan(scan_body, state, us)
        return state

    def chunk_body(carry):
        i, state = carry
        return i + 1, run_chunk(state, i, chunk)

    def cond(carry):
        i, state = carry
        not_done = i < n_chunks
        if early_exit:
            not_done &= jnp.any(state["phase"] != DONE)
        return not_done

    _, state = jax.lax.while_loop(cond, chunk_body,
                                  (jnp.int32(0), init_state))
    if rem:
        # partial final chunk so an explicit max_steps is honored exactly.
        # Finished replicas are inert, so under early_exit skipping the
        # remainder once everything is DONE is bit-identical and free.
        def do_rem(s):
            return run_chunk(s, n_chunks, rem)

        if early_exit:
            state = jax.lax.cond(jnp.any(state["phase"] != DONE),
                                 do_rem, lambda s: s, state)
        else:
            state = do_rem(state)
    state["completed"] = (state["phase"] == DONE).astype(jnp.float32)
    state["total_time"] = jnp.where(state["phase"] == DONE,
                                    state["total_time"], state["t"])
    return state


@partial(jax.jit, static_argnames=("P", "R", "chunk", "rem", "impl",
                                   "early_exit", "struct_key", "kind",
                                   "rkind", "hist_channels", "scen",
                                   "n_seg", "n_rseg"))
def _run_chunked(pv: jnp.ndarray, key: jax.Array, P: int, R: int,
                 chunk: int, n_chunks, rem: int, impl: Optional[str],
                 early_exit: bool, struct_key, kind: str, rkind: str,
                 hist_channels: tuple, scen,
                 init_state: Dict[str, jnp.ndarray],
                 n_seg: int = 0, n_rseg: int = 0):
    """Single-device jit entry over :func:`_chunk_loop` (see there).

    ``struct_key`` is unused in the body — it is a static argument
    precisely so the legacy ``padded=False`` path compiles one program
    per structure.
    """
    return _chunk_loop(pv, key, P, R, chunk, n_chunks, rem, impl,
                       early_exit, kind, rkind, hist_channels, scen,
                       init_state, n_seg, n_rseg)


@partial(jax.jit, static_argnames=("mesh", "P", "R", "chunk", "rem",
                                   "impl", "early_exit", "struct_key",
                                   "kind", "rkind", "hist_channels",
                                   "scen", "n_seg", "n_rseg"))
def _run_chunked_sharded(pv: jnp.ndarray, keys: jax.Array, P: int, R: int,
                         chunk: int, n_chunks, rem: int,
                         impl: Optional[str], early_exit: bool, struct_key,
                         kind: str, rkind: str, hist_channels: tuple, scen,
                         init_state: Dict[str, jnp.ndarray],
                         n_seg: int = 0, n_rseg: int = 0, *, mesh):
    """Replica-sharded twin of :func:`_run_chunked` via ``shard_map``.

    Reshapes every batched state leaf ``(P*R, ...) -> (P, R, ...)``,
    shards the replica axis over the 1-D device mesh
    (:func:`repro.parallel.sharding.replica_mesh`), and runs
    :func:`_chunk_loop` independently per shard with that shard's folded
    key (``keys`` is the :func:`repro.parallel.sharding.shard_keys`
    stack, one row per device).  There are no collectives inside the
    body — shards early-exit independently — and the ``out_specs``
    concatenation IS the cross-device merge: every output lane
    (metric scalars, histogram accumulators, run-record ring buffers)
    is per-replica, so reassembling the replica axis recovers the exact
    flat ``(P*R, ...)`` layout.  Unbatched leaves (``hist_edges``) ride
    along replicated.

    With a 1-device mesh ``keys[0]`` is the unsplit base key and the
    body sees exactly the arguments :func:`_run_chunked` would, so the
    output is bit-identical to the unsharded engine (pinned by
    tests/test_replica_sharding.py).
    """
    n_shards = mesh.shape[rsharding.REPLICA_AXIS]
    R_loc = R // n_shards
    unbatched = {k: init_state[k] for k in _UNBATCHED_STATE
                 if k in init_state}
    state = {k: v.reshape((P, R) + v.shape[1:])
             for k, v in init_state.items() if k not in unbatched}
    rspec = PartitionSpec(None, rsharding.REPLICA_AXIS)
    # simulate_ctmc passes one shared (n_cols,) parameter vector
    # (replicated); the sweep path passes per-row (P*R, n_cols) columns
    # (sharded like the state)
    pv_batched = pv.ndim == 2
    pv2 = pv.reshape((P, R, pv.shape[-1])) if pv_batched else pv
    pv_spec = rspec if pv_batched else PartitionSpec()
    out_specs = {k: rspec for k in list(state) + ["completed"]}

    def body(keys_s, pv_s, n_chunks_s, unbatched_s, state_s):
        flat = {k: v.reshape((P * R_loc,) + v.shape[2:])
                for k, v in state_s.items()}
        flat.update(unbatched_s)
        pv_flat = (pv_s.reshape(P * R_loc, pv_s.shape[-1])
                   if pv_batched else pv_s)
        out = _chunk_loop(pv_flat,
                          keys_s[0], P, R_loc, chunk, n_chunks_s, rem,
                          impl, early_exit, kind, rkind, hist_channels,
                          scen, flat, n_seg, n_rseg)
        for k in unbatched_s:
            out.pop(k)
        return {k: v.reshape((P, R_loc) + v.shape[1:])
                for k, v in out.items()}

    out = shard_map(
        body, mesh=mesh,
        in_specs=(PartitionSpec(rsharding.REPLICA_AXIS), pv_spec,
                  PartitionSpec(),
                  {k: PartitionSpec() for k in unbatched},
                  rsharding.replica_state_specs(state)),
        out_specs=out_specs, check_rep=False,
    )(keys, pv2, n_chunks, unbatched, state)
    out = {k: v.reshape((P * R,) + v.shape[2:]) for k, v in out.items()}
    out.update(unbatched)
    return out


def compile_cache_size() -> Optional[int]:
    """Compiled-program cache entries of the chunked-scan driver.

    One entry per distinct static signature = one XLA compilation; the
    structural-sweep smoke (scripts/ci.sh) and benchmarks diff this
    around a sweep to assert the padded path's one-compile invariant.
    Relies on jax's private ``PjitFunction._cache_size``; returns None
    when a jax upgrade removes that internal — callers must treat None
    as "cannot measure", not as a regression.
    """
    fn = getattr(_run_chunked, "_cache_size", None)
    return fn() if callable(fn) else None


def shard_compile_cache_size() -> Optional[int]:
    """Compiled-program cache entries of the *sharded* chunked driver.

    The sharded weak-scaling benchmark diffs this around repeated sweeps
    to assert the sharded path keeps the one-compile invariant (the mesh
    object is part of the static signature, so re-running at the same
    device count reuses one program).  Same None-means-unmeasurable
    contract as :func:`compile_cache_size`.
    """
    fn = getattr(_run_chunked_sharded, "_cache_size", None)
    return fn() if callable(fn) else None


def _resolve_shards(shards, pts) -> int:
    """Effective shard count: the explicit argument, else the (single)
    ``Params.engine_shards`` value of the batch — a mixed grid raises
    (sharding is batch-level state; silently de-sharding part of a grid
    is exactly the failure mode docs/scaling.md promises never happens).
    """
    if shards is not None:
        return shards
    vals = {p.engine_shards for p in pts}
    if len(vals) > 1:
        raise ValueError(
            f"all points of a batched CTMC sweep must agree on "
            f"Params.engine_shards (got {sorted(vals)}); the batch axis "
            f"shards as one unit — split the grid or pass shards= "
            f"explicitly")
    return vals.pop()


def _shard_mesh(n_shards: int, R: int):
    """Validated replica mesh for ``n_shards`` shards over R replicas.

    Raises — never silently de-shards — when the shard count does not
    divide the replica count or exceeds the visible devices.
    """
    if R % n_shards:
        raise ValueError(
            f"engine_shards={n_shards} does not divide the replica "
            f"count {R}: the batch axis shards by whole replica "
            f"columns.  Choose a divisor; bucketed sweeps round R up to "
            f"a power of two, so any power-of-two shard count <= R "
            f"divides it (docs/scaling.md)")
    return rsharding.replica_mesh(n_shards)


def _unsupported_error(params: Params) -> ValueError:
    reasons = unsupported_reasons(params) \
        or ["unknown reason — please report"]
    return ValueError(
        "these Params are outside the CTMC envelope: "
        + "; ".join(reasons)
        + "; use core.simulation.simulate (or engine='auto') instead")


#: non-_METRICS outputs worth returning: completion flag + the exact
#: run-duration records (ring buffer, attempt count, in-flight interval)
#: + the per-domain shock counts of scenario runs (absent otherwise)
_EXTRA_OUTPUTS = ("completed", "run_durations", "n_runs", "cur_run",
                  "domain_shocks")


def _extract(state, sl=slice(None), channels=()) -> Dict[str, np.ndarray]:
    out = {k: np.asarray(v[sl]) for k, v in state.items()
           if k in _METRICS + _EXTRA_OUTPUTS}
    if "hist" in state and channels:
        # the in-scan accumulator carries exactly the selected channels,
        # in HIST_CHANNELS order
        hist = np.asarray(state["hist"][sl], np.float64)
        for ci, ch in enumerate(channels):
            out[f"hist_{ch}"] = hist[:, ci]
        out["hist_edges"] = np.asarray(state["hist_edges"], np.float64)
    return out


def _hist_channels(pts) -> tuple:
    return _selected_channels(pts[0].histogram)


def simulate_ctmc(params: Params, n_replicas: int = 1024, seed: int = 0,
                  max_steps: Optional[int] = None,
                  impl: Optional[str] = None,
                  chunk_steps: Optional[int] = None,
                  early_exit: bool = True,
                  max_runs: Optional[int] = None,
                  shards: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Vectorized replication study. Returns {metric: np.ndarray (R,)}.

    jit-compiled once per (pool-structure, R, step-budget); parameter
    values are traced inputs, so repeated calls over rates/times/
    probabilities reuse the compiled program.  The scan runs in
    ``chunk_steps``-sized pieces and stops at the first chunk boundary
    where every replica is DONE; ``early_exit=False`` forces the full
    ``max_steps`` budget (bit-identical results — finished replicas are
    inert — which tests/test_backend.py asserts).

    ``max_runs`` (default ``params.max_run_records``) sizes the exact
    per-run duration ring buffer returned as ``run_durations`` (R,
    max_runs) alongside ``n_runs`` and ``cur_run``.  ``max_runs=0``
    compiles the buffer out of the scan entirely for callers that only
    need scalar metrics: ``mean_run_duration`` stays exact via the
    interval-sum identity over ``n_runs``/``cur_run``, but pooled
    run-duration percentiles degrade to pooling per-replica means.

    ``shards`` (default ``params.engine_shards``; 0 = unsharded) splits
    the replica axis over that many local devices via shard_map —
    exact-in-law with per-shard folded keys, bit-identical to the
    unsharded run at ``shards=1``, loud errors (never a silent de-shard)
    on indivisible replica counts or missing devices.  ``impl`` (default
    ``params.event_race_impl``) selects the event-race kernel backend.
    See docs/scaling.md for both knobs.
    """
    if not supports(params):
        raise _unsupported_error(params)
    params.validate()
    impl = params.event_race_impl if impl is None else impl
    shards = _resolve_shards(shards, [params])
    max_steps = max_steps or default_max_steps(params)
    chunk = min(chunk_steps or DEFAULT_CHUNK_STEPS, max_steps)
    init_state = _initial_state(params, n_replicas, max_runs)
    channels = _hist_channels([params])
    args = (1, n_replicas, chunk, jnp.int32(max_steps // chunk),
            max_steps % chunk, impl, early_exit,
            _struct_key(params), hazards.hazard_kind(params),
            hazards.repair_kind(params), channels,
            faultdomains.scenario_key(params), init_state,
            hazards.hazard_segment_count(params),
            hazards.repair_segment_count(params))
    pv, key = _params_vector(params), jax.random.PRNGKey(seed)
    if shards:
        out = _run_chunked_sharded(pv, rsharding.shard_keys(key, shards),
                                   *args, mesh=_shard_mesh(shards,
                                                           n_replicas))
    else:
        out = _run_chunked(pv, key, *args)
    return _extract(out, channels=channels)


def simulate_ctmc_sweep(params_list, n_replicas: int = 1024, seed: int = 0,
                        max_steps: Optional[int] = None,
                        impl: Optional[str] = None,
                        chunk_steps: Optional[int] = None,
                        early_exit: bool = True,
                        padded: bool = True,
                        bucketed: bool = True,
                        max_runs: Optional[int] = None,
                        shards: Optional[int] = None):
    """Batched sweep: one compiled program for the whole grid.

    ``params_list`` is a sequence of :class:`Params` (the sweep grid, any
    order).  With ``padded=True`` (default) the entire grid — even when
    points differ *structurally* (job_size, pool sizes, warm_standbys,
    systematic fraction, job_length) — is stacked into one (P, 16)
    parameter array plus per-point padded initial states, expanded to one
    row per replica, and the whole (P * R,) batch runs through the same
    chunked scan as :func:`simulate_ctmc` in a single XLA compilation —
    the ``event_race`` kernel sees a single flat batch axis, so Pallas
    block sizes stay aligned.  The step budget is the max over points;
    replicas of cheaper points finish early and sit inert, so the shared
    head-room costs only chunks the early-exit check cannot skip.

    ``padded=False`` restores the legacy grouping — one compiled program
    per :func:`_struct_key` — for A/B benchmarking; per-point results are
    bit-identical to the padded path whenever both step budgets suffice
    (common random numbers are drawn per replica column either way).

    ``bucketed=True`` (the default; only active on the padded path)
    additionally buckets the *shape* signature: P and R round up to
    powers of two with inert phase-DONE padding rows, and the chunk
    count is traced, so repeated sweeps of any size inside one bucket
    reuse a single XLA program.  A *derived* default budget rounds up to
    a whole number of chunks (remainder statically 0); an explicit
    ``max_steps`` is honored exactly.  Real rows are bit-identical to
    ``bucketed=False`` for any explicit ``max_steps``, and under the
    default budget whenever every replica finishes (early exit skips
    the rounded-up head-room); padding rows never reach the caller.

    Uniforms are shared across points (the batched analogue of the event
    engine's same-seed-per-replication policy), giving common random
    numbers across the grid.

    The hazard family (exponential / weibull / bathtub — see
    :mod:`repro.core.hazards`) is a static compile switch, so a grid
    mixing families runs one batch per family; hazard *parameters*
    (rates, ``k``, taus) are traced and share programs freely.

    ``shards`` (default: the grid's shared ``Params.engine_shards``
    value; a mixed grid raises) splits the replica axis of every batch
    over that many local devices — see :func:`simulate_ctmc` and
    docs/scaling.md.  The shard count must divide the *run* replica
    count (after pow2 bucketing), checked loudly.  ``impl`` defaults to
    each point's ``Params.event_race_impl``; since the kernel backend is
    a static compile switch, a grid mixing backends splits into one
    batch per backend.

    Returns a list of ``{metric: np.ndarray (R,)}`` dicts in input order.
    """
    params_list = list(params_list)
    for p in params_list:
        if not supports(p):
            raise _unsupported_error(p)
        p.validate()
    if not params_list:
        return []
    shards = _resolve_shards(shards, params_list)
    if len({p.histogram for p in params_list}) > 1:
        # the batch shares one in-scan accumulator layout (bin edges +
        # channel set are part of the compiled state), so a mixed-spec
        # grid cannot be honored point by point — reject it instead of
        # silently applying the first point's spec to every point
        raise ValueError(
            "all points of a batched CTMC sweep must share the same "
            "Params.histogram spec (the in-scan accumulator layout is "
            "per-batch); split the grid or unify the spec")

    groups: Dict[tuple, list] = {}
    for i, p in enumerate(params_list):
        # the hazard and repair families are static compile switches
        # (they change the step program and the uniform-stream width),
        # so a grid mixing families splits into one batch per
        # (failure, repair, age-dtype) combination; within a
        # combination, structure padding keeps the whole sub-grid one
        # compilation (struct_key None -> one jit cache entry).  Hazard
        # AND repair *parameters* (k, taus, rates, repair scales/means)
        # stay traced, so they never split a group — a repair-rate grid
        # compiles exactly once.
        kind = hazards.hazard_kind(p)
        rkind = hazards.repair_kind(p)
        # the scenario key (domain count + campaign codes) sizes the race
        # and the trailing parameter columns, so it splits groups the
        # same way the hazard family does; shock *rates* and campaign
        # *times/fractions* stay traced — a shock-rate grid over one
        # topology compiles exactly once.  Likewise the empirical
        # family's segment *counts* (they size the column blocks) are
        # part of the key while edge positions and rates stay traced —
        # a grid of hazards fitted from different log slices is one
        # program as long as the fits share a bin count.
        gkey = (kind, rkind, p.age_dtype, faultdomains.scenario_key(p),
                hazards.hazard_segment_count(p),
                hazards.repair_segment_count(p),
                None if padded else _struct_key(p),
                # the event-race kernel backend is a static compile
                # switch; an explicit impl= argument overrides every
                # point's Params knob (one group), otherwise points
                # split by their requested backend
                impl if impl is not None else p.event_race_impl)
        groups.setdefault(gkey, []).append(i)
    mr = _max_runs_for(params_list) if max_runs is None else max_runs

    bucket = padded and bucketed
    channels = _hist_channels(params_list)
    results: list = [None] * len(params_list)
    for (kind, rkind, _adt, scen, n_seg, n_rseg, skey, impl_eff), idxs in \
            groups.items():
        pts = [params_list[i] for i in idxs]
        P, R = len(pts), n_replicas
        steps = max_steps or max(default_max_steps(p) for p in pts)
        chunk = min(chunk_steps or DEFAULT_CHUNK_STEPS, steps)
        P_run, R_run = (_next_pow2(P), _next_pow2(R)) if bucket else (P, R)
        if bucket and max_steps is None:
            # derived default budgets round up to whole chunks (rem
            # statically 0 -> every such sweep shares one program); an
            # *explicit* max_steps is still honored exactly — its
            # remainder stays a static part of the signature, so pass a
            # chunk multiple (or omit max_steps) for maximal sharing
            steps = -(-steps // chunk) * chunk
        pv = jnp.stack([_params_vector(p) for p in pts])        # (P, n_cols)
        if P_run != P:
            # padding rows are inert (phase DONE); replicating the last
            # real row keeps every hazard column benign (a zero
            # bathtub tau would evaluate g(t) to NaN — masked out, but
            # edge-padding avoids NaNs entering the race at all)
            pv = jnp.pad(pv, ((0, P_run - P), (0, 0)), mode="edge")
        pv_flat = jnp.repeat(pv, R_run, axis=0)       # (P_run*R_run, n_cols)
        init_state = _initial_state_batch(pts, R, mr, rkind,
                                          _repair_slots_for(pts, rkind),
                                          scen)
        if (P_run, R_run) != (P, R):
            init_state = _bucket_pad_state(init_state, P, R, P_run, R_run)
        key = jax.random.PRNGKey(seed)
        run_args = (P_run, R_run, chunk, jnp.int32(steps // chunk),
                    steps % chunk, impl_eff, early_exit, skey, kind,
                    rkind, channels, scen, init_state, n_seg, n_rseg)
        if shards:
            out = _run_chunked_sharded(
                pv_flat, rsharding.shard_keys(key, shards), *run_args,
                mesh=_shard_mesh(shards, R_run))
        else:
            out = _run_chunked(pv_flat, key, *run_args)
        for j, i in enumerate(idxs):
            rows = (slice(j * R_run, j * R_run + R) if R_run == R
                    else np.arange(R) + j * R_run)
            results[i] = _extract(out, rows, channels)
    return results
