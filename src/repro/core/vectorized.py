"""Vectorized JAX CTMC engine: thousands of AIReSim replicas per device.

TPU adaptation of the paper's DES (DESIGN.md §2.2): under the paper's
default exponential assumption the cluster is a continuous-time Markov
chain over server *compartments* — servers are exchangeable within
(origin x health) classes, so counts are sufficient state.  Each step
races the exponential clock families against the deterministic timers
(recovery / host-selection / completion) with the kernels.ops.event_race
Pallas kernel, then applies the winning transition with masked updates.
``lax.scan`` over events x vectorization over replicas turns a whole
replication study into a single XLA program; parameter sweeps stack one
level higher (sweeps run one compiled program per point with cached jit).

Compartment classes: c = 2*origin + bad, i.e.
  0: working-origin good   1: working-origin bad
  2: spare-origin good     3: spare-origin bad

Event families (K_exp = 16): random failure x4 classes, systematic
failure x4, auto-repair completion x4, manual completion x4.
Deterministic (K_det = 2): job completion, recovery/host-selection timer.

Known approximations vs the event-driven oracle (validated statistically
in tests/test_vectorized.py):
  * class-proportional sampling everywhere (exact under exchangeability);
  * misdiagnosis picks the wrong server proportionally over ALL running
    servers (the oracle excludes the failed one: O(1/4096) difference);
  * the initial bad-server split across pools uses its expectation.

Out of scope (routed to core.simulation): retirement, bad-set
regeneration, non-exponential distributions, failing standbys.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .params import Params

COMPUTE, OVERHEAD, STALL, DONE = 0, 1, 2, 3
K_EXP = 16

_METRICS = ("total_time", "n_failures", "n_random_failures",
            "n_systematic_failures", "n_preemptions", "n_auto_repairs",
            "n_manual_repairs", "n_host_selections", "n_standby_swaps",
            "n_undiagnosed", "n_misdiagnosed", "stall_time",
            "recovery_overhead", "lost_work", "useful_work")


def supports(params: Params) -> bool:
    """Can the CTMC engine simulate these params exactly?"""
    return (params.failure_distribution.lower() == "exponential"
            and params.repair_distribution.lower() == "exponential"
            and params.retirement_threshold == 0
            and params.bad_set_regeneration_period == 0
            and params.checkpoint_interval == 0
            and not params.standbys_can_fail)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def _initial_counts(p: Params):
    total = p.working_pool_size + p.spare_pool_size
    n_bad = int(round(p.systematic_failure_fraction * total))
    bad_w = round(n_bad * p.working_pool_size / total)
    bad_s = n_bad - bad_w

    def split(n_take, pool_good, pool_bad):
        frac_bad = pool_bad / max(pool_good + pool_bad, 1)
        take_bad = int(round(n_take * frac_bad))
        return n_take - take_bad, take_bad

    w_good, w_bad = p.working_pool_size - bad_w, bad_w
    run_g, run_b = split(p.job_size, w_good, w_bad)
    w_good -= run_g
    w_bad -= run_b
    n_sb = min(p.warm_standbys, w_good + w_bad)
    sb_g, sb_b = split(n_sb, w_good, w_bad)
    w_good -= sb_g
    w_bad -= sb_b
    return {
        "run": [run_g, run_b, 0, 0],
        "sb": [sb_g, sb_b, 0, 0],
        "fw": [w_good, w_bad, 0, 0],
        "fs": [0, 0, p.spare_pool_size - bad_s, bad_s],
    }


def _initial_state(p: Params, R: int) -> Dict[str, jnp.ndarray]:
    counts = _initial_counts(p)

    def tile(vals):
        return jnp.tile(jnp.asarray(vals, jnp.float32)[None, :], (R, 1))

    state = {k: tile(v) for k, v in counts.items()}
    state["auto"] = tile([0, 0, 0, 0])
    state["man"] = tile([0, 0, 0, 0])
    state["t"] = jnp.full((R,), p.host_selection_time, jnp.float32)
    state["work_left"] = jnp.full((R,), p.job_length, jnp.float32)
    state["timer"] = jnp.full((R,), jnp.inf, jnp.float32)
    state["stall_start"] = jnp.zeros((R,), jnp.float32)
    state["phase"] = jnp.full((R,), COMPUTE, jnp.int32)
    for m in _METRICS:
        state[m] = jnp.zeros((R,), jnp.float32)
    return state


def _pick_class(counts: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Categorical over 4 classes proportional to counts. (R,4),(R,)->(R,)"""
    total = jnp.maximum(counts.sum(-1), 1e-30)
    cdf = jnp.cumsum(counts, axis=-1) / total[:, None]
    return jnp.minimum(jnp.sum((u[:, None] >= cdf).astype(jnp.int32), -1), 3)


def _onehot(c: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.one_hot(c, 4, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# one transition
# ---------------------------------------------------------------------------

def _step(s: Dict[str, jnp.ndarray], key_t: jax.Array, pv: jnp.ndarray,
          impl: Optional[str]) -> Dict[str, jnp.ndarray]:
    (r_rand, r_sys, recovery, host_sel, waiting, auto_t, man_t,
     auto_fail, man_fail, p_auto, dp, du, ckpt, preempt_cost,
     warm_standbys) = [pv[i] for i in range(15)]
    R = s["t"].shape[0]

    u = jax.random.uniform(key_t, (R, 8), minval=1e-12, maxval=1.0)
    u_time, u_pick, u_diag, u_wrong, u_cls, u_esc, u_succ, u_pool = (
        u[:, 0], u[:, 1], u[:, 2], u[:, 3], u[:, 4], u[:, 5], u[:, 6],
        u[:, 7])

    computing = s["phase"] == COMPUTE
    in_overhead = s["phase"] == OVERHEAD
    stalled = s["phase"] == STALL
    active = s["phase"] != DONE

    # ---- rates (R, 16) ------------------------------------------------
    run = s["run"]
    bad_mask = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    fail_rand = run * r_rand * computing[:, None]
    fail_sys = run * bad_mask[None, :] * r_sys * computing[:, None]
    auto_rate = s["auto"] / jnp.maximum(auto_t, 1e-9)
    man_rate = s["man"] / jnp.maximum(man_t, 1e-9)
    rates = jnp.concatenate([fail_rand, fail_sys, auto_rate, man_rate],
                            axis=-1) * active[:, None]

    residuals = jnp.stack([
        jnp.where(computing, s["work_left"], jnp.inf),
        jnp.where(in_overhead, s["timer"], jnp.inf),
    ], axis=-1)

    dt, ev = ops.event_race(rates, residuals, u_time, u_pick, impl=impl)
    dt = jnp.where(active & jnp.isfinite(dt), dt, 0.0)

    cls = (ev % 4).astype(jnp.int32)
    is_fail = active & (ev < 8)
    is_sys = active & (ev >= 4) & (ev < 8)
    is_auto = active & (ev >= 8) & (ev < 12)
    is_man = active & (ev >= 12) & (ev < 16)
    is_complete = active & (ev == K_EXP)
    is_timer = active & (ev == K_EXP + 1)

    ns = dict(s)
    ns["t"] = s["t"] + dt

    # ---- progress accounting -------------------------------------------
    # work accrues during every COMPUTE interval regardless of which event
    # ends it (failures, repair completions, job completion); only
    # failures roll back to the last checkpoint (extension knob).
    progress = jnp.where(computing, dt, 0.0)
    lost = jnp.where(is_fail & (ckpt > 0),
                     jnp.mod(progress, jnp.maximum(ckpt, 1e-9)), 0.0)
    banked = progress - lost
    ns["work_left"] = s["work_left"] - banked
    ns["useful_work"] = s["useful_work"] + banked
    ns["lost_work"] = s["lost_work"] + lost

    # ---- completion / timer ----------------------------------------------
    # deterministic timers advance with the clock even when a concurrent
    # (repair) event ends the step first
    timer_dec = jnp.where(in_overhead, s["timer"] - dt, s["timer"])
    ns["phase"] = jnp.where(is_complete, DONE, s["phase"])
    ns["phase"] = jnp.where(is_timer, COMPUTE, ns["phase"])
    ns["timer"] = jnp.where(is_timer, jnp.inf, timer_dec)
    ns["total_time"] = jnp.where(is_complete, ns["t"], s["total_time"])

    # ---- failure handling ---------------------------------------------------
    f = is_fail.astype(jnp.float32)
    ns["n_failures"] = s["n_failures"] + f
    ns["n_systematic_failures"] = s["n_systematic_failures"] \
        + is_sys.astype(jnp.float32)
    ns["n_random_failures"] = s["n_random_failures"] \
        + (is_fail & ~is_sys).astype(jnp.float32)

    diagnosed = is_fail & (u_diag < dp)
    wrong = diagnosed & (u_wrong < du)
    ns["n_undiagnosed"] = s["n_undiagnosed"] \
        + (is_fail & ~diagnosed).astype(jnp.float32)
    ns["n_misdiagnosed"] = s["n_misdiagnosed"] + wrong.astype(jnp.float32)
    removed_cls = jnp.where(wrong, _pick_class(run, u_cls), cls)
    rm1h = _onehot(removed_cls) * diagnosed[:, None]
    ns["run"] = ns["run"] - rm1h
    ns["auto"] = ns["auto"] + rm1h

    # replacement waterfall (only when a server was removed)
    sb_tot = s["sb"].sum(-1)
    fw_tot = s["fw"].sum(-1)
    fs_tot = s["fs"].sum(-1)
    use_sb = diagnosed & (sb_tot > 0)
    use_fw = diagnosed & ~use_sb & (fw_tot > 0)
    use_fs = diagnosed & ~use_sb & ~use_fw & (fs_tot > 0)
    goes_stall = diagnosed & ~use_sb & ~use_fw & ~use_fs

    sb_cls = _pick_class(s["sb"], u_cls)
    fw_cls = _pick_class(s["fw"], u_pool)
    fs_cls = _pick_class(s["fs"], u_pool)
    ns["sb"] = ns["sb"] - _onehot(sb_cls) * use_sb[:, None]
    ns["fw"] = ns["fw"] - _onehot(fw_cls) * use_fw[:, None]
    ns["fs"] = ns["fs"] - _onehot(fs_cls) * use_fs[:, None]
    ns["run"] = (ns["run"] + _onehot(sb_cls) * use_sb[:, None]
                 + _onehot(fw_cls) * use_fw[:, None]
                 + _onehot(fs_cls) * use_fs[:, None])
    ns["n_standby_swaps"] = s["n_standby_swaps"] + use_sb.astype(jnp.float32)
    ns["n_host_selections"] = s["n_host_selections"] \
        + (use_fw | use_fs).astype(jnp.float32)
    ns["n_preemptions"] = s["n_preemptions"] + use_fs.astype(jnp.float32)

    fail_timer = (recovery
                  + jnp.where(use_fw | use_fs, host_sel, 0.0)
                  + jnp.where(use_fs, waiting + preempt_cost, 0.0))
    resolves = is_fail & ~goes_stall
    ns["timer"] = jnp.where(resolves, fail_timer, ns["timer"])
    ns["phase"] = jnp.where(resolves, OVERHEAD, ns["phase"])
    ns["phase"] = jnp.where(goes_stall, STALL, ns["phase"])
    ns["stall_start"] = jnp.where(goes_stall, ns["t"], s["stall_start"])
    ns["recovery_overhead"] = s["recovery_overhead"] \
        + jnp.where(resolves, recovery, 0.0)

    # ---- repair completions ----------------------------------------------
    rep1h = _onehot(cls)
    ns["auto"] = ns["auto"] - rep1h * is_auto[:, None]
    ns["n_auto_repairs"] = s["n_auto_repairs"] + is_auto.astype(jnp.float32)
    escalate = is_auto & (u_esc >= p_auto)
    ns["man"] = ns["man"] + rep1h * escalate[:, None]
    ns["man"] = ns["man"] - rep1h * is_man[:, None]
    ns["n_manual_repairs"] = s["n_manual_repairs"] + is_man.astype(jnp.float32)

    finishes = (is_auto & ~escalate) | is_man
    fail_prob = jnp.where(is_man, man_fail, auto_fail)
    healed = finishes & (u_succ >= fail_prob)
    out_cls = jnp.where(healed, cls - (cls % 2), cls)  # bad -> good
    out1h = _onehot(out_cls)

    # returning server: stalled job > standby refill > origin pool
    to_stalled = finishes & stalled
    to_sb = finishes & ~to_stalled & (ns["sb"].sum(-1) < warm_standbys)
    to_pool = finishes & ~to_stalled & ~to_sb
    spare_origin = out_cls >= 2
    ns["run"] = ns["run"] + out1h * to_stalled[:, None]
    ns["sb"] = ns["sb"] + out1h * to_sb[:, None]
    ns["fw"] = ns["fw"] + out1h * (to_pool & ~spare_origin)[:, None]
    ns["fs"] = ns["fs"] + out1h * (to_pool & spare_origin)[:, None]
    ns["phase"] = jnp.where(to_stalled, OVERHEAD, ns["phase"])
    ns["timer"] = jnp.where(to_stalled, recovery, ns["timer"])
    ns["stall_time"] = s["stall_time"] \
        + jnp.where(to_stalled, ns["t"] - s["stall_start"], 0.0)
    ns["recovery_overhead"] = ns["recovery_overhead"] \
        + jnp.where(to_stalled, recovery, 0.0)
    return ns


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _params_vector(p: Params) -> jnp.ndarray:
    return jnp.asarray([
        p.random_failure_rate, p.systematic_failure_rate, p.recovery_time,
        p.host_selection_time, p.waiting_time, p.auto_repair_time,
        p.manual_repair_time, p.auto_repair_failure_probability,
        p.manual_repair_failure_probability, p.automated_repair_probability,
        p.diagnosis_probability, p.diagnosis_uncertainty,
        p.checkpoint_interval, p.preemption_cost, float(p.warm_standbys),
    ], jnp.float32)


def default_max_steps(p: Params, safety: float = 2.0) -> int:
    """Expected events (failures x ~3 repair/replace hops) + head-room."""
    lam = p.expected_failures_per_minute()
    horizon = p.job_length * (1.0 + lam * (p.recovery_time + 2.0))
    return max(128, int(lam * horizon * 3.2 * safety))


@partial(jax.jit, static_argnames=("R", "max_steps", "impl", "struct_key"))
def _run_compiled(pv: jnp.ndarray, key: jax.Array, R: int, max_steps: int,
                  impl: Optional[str], struct_key,
                  init_state: Dict[str, jnp.ndarray]):
    def body(carry, key_t):
        return _step(carry, key_t, pv, impl), None

    keys = jax.random.split(key, max_steps)
    state, _ = jax.lax.scan(body, init_state, keys)
    state["completed"] = (state["phase"] == DONE).astype(jnp.float32)
    state["total_time"] = jnp.where(state["phase"] == DONE,
                                    state["total_time"], state["t"])
    return state


def simulate_ctmc(params: Params, n_replicas: int = 1024, seed: int = 0,
                  max_steps: Optional[int] = None,
                  impl: Optional[str] = None) -> Dict[str, np.ndarray]:
    """Vectorized replication study. Returns {metric: np.ndarray (R,)}.

    jit-compiled once per (pool-structure, R, max_steps); parameter values
    are traced inputs, so sweeps over rates/times/probabilities reuse the
    compiled program.
    """
    if not supports(params):
        raise ValueError(
            "CTMC engine supports the default exponential AIReSim model "
            "(no retirement / regeneration / non-exponential "
            "distributions); use core.simulation.simulate instead")
    params.validate()
    max_steps = max_steps or default_max_steps(params)
    struct_key = (params.job_size, params.working_pool_size,
                  params.spare_pool_size, params.warm_standbys,
                  round(params.systematic_failure_fraction, 6),
                  round(params.job_length, 3),
                  round(params.host_selection_time, 3))
    init_state = _initial_state(params, n_replicas)
    out = _run_compiled(_params_vector(params), jax.random.PRNGKey(seed),
                        n_replicas, max_steps, impl, struct_key, init_state)
    return {k: np.asarray(v) for k, v in out.items()
            if k in _METRICS + ("completed",)}
