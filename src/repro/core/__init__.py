"""AIReSim core: discrete event simulation of AI-cluster reliability.

The paper's primary contribution (Pattabiraman/Patel/Lin, CS.DC 2026)
implemented as a composable library:

  * :mod:`engine`        — generator-coroutine DES engine (SimPy-equivalent)
  * :mod:`params`        — the Params data class (all §III-B inputs)
  * :mod:`server`        — fleet, per-server state, analytical failure sampler
  * :mod:`coordinator`   — job execution loop / failure broadcast
  * :mod:`scheduler`     — host selection, warm standbys, stall handling
  * :mod:`repair`        — diagnosis -> auto -> manual repair -> retire/return
  * :mod:`pool`          — working / spare pool bookkeeping
  * :mod:`metrics`       — RunResult + cross-replication statistics
  * :mod:`sweeps`        — OneWaySweep / TwoWaySweep experiment harness
  * :mod:`analytical`    — closed-form cross-checks + Young/Daly cadence
  * :mod:`optimize`      — goodput-maximizing knob search (golden-section
    over checkpoint_interval, coordinate descent over structural knobs)
  * :mod:`vectorized`    — JAX CTMC engine for massive parameter sweeps
  * :mod:`hazards`       — non-exponential hazard math for the fast path
  * :mod:`empirical`     — trace-driven piecewise-constant hazard fitting
  * :mod:`faultdomains`  — correlated failure domains + injection campaigns
  * :mod:`histograms`    — streaming distribution telemetry (both engines)
  * :mod:`backend`       — engine dispatch (auto | event | ctmc)

The docs suite (docs/architecture.md, docs/engines.md,
docs/distributions.md) maps these layers and their parity guarantees.
"""

from . import bathtub as _bathtub  # noqa: F401  (registers "bathtub" dist)
from .analytical import (CheckpointPlan, cluster_failure_rate,
                         expected_failures, expected_total_time,
                         plan_checkpoints, repair_shop_occupancy,
                         spare_capacity_bound, young_daly_interval)
from .bathtub import Bathtub
from .multijob import (JobSpec, MultiJobResult, MultiJobSimulation,
                       simulate_multijob)
from .trace import TraceEvent, Tracer
from .distributions import (Deterministic, Distribution, Exponential,
                            LogNormal, Weibull, make_distribution,
                            register_distribution)
from .backend import (MultiJobReplications, Replications, resolve_engine,
                      resolve_engine_multijob, run_multijob_batch,
                      run_replications, run_replications_batch,
                      run_replications_multijob)
from .empirical import (Empirical, PiecewiseFit, fit_piecewise_hazard,
                        from_log, from_mttf_table)
from .engine import Environment, Event, Interrupt, Process, Timeout
from .faultdomains import (Campaign, CampaignEvent, FaultTopology,
                           ShockInjector)
from .hazards import hazard_kind
from .histograms import (HIST_CHANNELS, Histogram, HistogramSpec,
                         percentiles_per_row)
from .optimize import (CheckpointOptResult, KnobOptResult,
                       optimize_checkpoint_interval, optimize_knobs)
from .metrics import (RunResult, Stat, aggregate, aggregate_arrays,
                      aggregate_multijob_arrays, histograms_from_arrays,
                      histograms_from_results, pool_histograms, summarize)
from .params import MINUTES_PER_DAY, PAPER_TABLE1_RANGES, Params, paper_table1_defaults
from .simulation import ClusterSimulation, simulate, simulate_one
from .sweeps import (MultiJobSweep, OneWaySweep, SweepResult, TwoWaySweep,
                     load_experiment)
from .vectorized_multijob import (simulate_multijob_ctmc,
                                  simulate_multijob_ctmc_sweep,
                                  supports_multijob)

__all__ = [
    "Bathtub", "Campaign", "CampaignEvent", "CheckpointOptResult",
    "CheckpointPlan",
    "ClusterSimulation", "Deterministic", "KnobOptResult",
    "Distribution", "Empirical", "Environment", "Event", "Exponential",
    "FaultTopology",
    "HIST_CHANNELS",
    "Histogram", "HistogramSpec", "Interrupt", "ShockInjector",
    "JobSpec", "LogNormal", "MINUTES_PER_DAY", "MultiJobReplications",
    "MultiJobResult",
    "MultiJobSimulation", "MultiJobSweep", "OneWaySweep",
    "PAPER_TABLE1_RANGES", "Params", "PiecewiseFit",
    "Process", "Replications", "RunResult", "Stat", "SweepResult", "Timeout",
    "TraceEvent", "Tracer", "TwoWaySweep", "Weibull", "aggregate",
    "aggregate_arrays", "aggregate_multijob_arrays", "cluster_failure_rate",
    "expected_failures",
    "expected_total_time", "fit_piecewise_hazard", "from_log",
    "from_mttf_table", "hazard_kind", "histograms_from_arrays",
    "histograms_from_results", "load_experiment", "make_distribution",
    "percentiles_per_row", "pool_histograms",
    "optimize_checkpoint_interval", "optimize_knobs",
    "paper_table1_defaults", "plan_checkpoints", "register_distribution",
    "repair_shop_occupancy", "resolve_engine", "resolve_engine_multijob",
    "run_multijob_batch", "run_replications",
    "run_replications_batch", "run_replications_multijob", "simulate",
    "simulate_multijob", "simulate_multijob_ctmc",
    "simulate_multijob_ctmc_sweep", "simulate_one",
    "spare_capacity_bound", "summarize", "supports_multijob",
    "young_daly_interval",
]
