"""Correlated failure domains and scripted fault-injection campaigns.

Every failure the base simulator produces is i.i.d. per server.  Real
clusters die by *domain*: a rack PDU trips and the whole rack goes dark,
a pod-level network event partitions dozens of hosts at once.  This
module adds both the stochastic and the scripted version of that story:

* :class:`FaultTopology` — assigns every server (workers and spares
  alike) to a rack, and racks to pods.  Each rack and each pod is a
  *fault domain* with its own exponential shock rate; a shock atomically
  fails every server in the domain — running, standby, free, spare, and
  in-repair alike.
* :class:`Campaign` / :class:`CampaignEvent` — a validated schedule of
  deterministic injections: ``kill domain d at t`` and ``maintenance
  window disabling the repair shop over [t0, t0+duration]``.  Both
  engines honor the schedule exactly; on the CTMC fast path the entries
  race as deterministic residuals the same way repair-slot completions
  do.
* :class:`ShockInjector` — the event-engine driver: merges the random
  per-domain shock processes and the campaign schedule into one ordered
  stream of injections for the coordinator to race against compute.

Semantics shared by both engines (see docs/scenarios.md):

* A shock/kill does **not** flip a server's health class — it models an
  environmental outage (power, network), not a latent hardware fault.
  Struck servers are sent through the normal repair pipeline and return
  with whatever class they had.
* Struck servers already in the repair shop are "re-broken": their
  current repair stage restarts.  Under exponential repairs this is
  exact-in-law a no-op (memorylessness) — the CTMC engine counts it
  without touching state; the event engine redraws the stage.
* Shock kills are not recorded as server failures (``n_failures`` and
  the retirement window see only organic failures); they are surfaced
  through ``n_domain_shocks`` / ``n_shock_killed`` /
  ``n_campaign_events`` and the per-domain ``domain_shocks`` counts.

Server→rack assignment is round-robin (``rack = sid % n_racks``), which
stripes both the worker and the spare pool across racks — the worst
case for correlated loss of a job plus its spares, and the natural
default when nothing is known about placement.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FaultTopology", "CampaignEvent", "Campaign", "ShockInjector",
    "Injection", "scenario_key", "scenario_columns", "scenario_budget",
    "KILL", "MAINT_START", "MAINT_END",
]

#: campaign schedule entry codes (static on the CTMC fast path)
KILL, MAINT_START, MAINT_END = 0, 1, 2


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultTopology:
    """Rack → pod fault-domain hierarchy with per-level shock rates.

    Domains are indexed ``0..n_racks-1`` (racks) followed by
    ``n_racks..n_racks+n_pods-1`` (pods).  ``racks_per_pod == 0``
    disables the pod level entirely.

    >>> t = FaultTopology(n_racks=4, racks_per_pod=2,
    ...                   rack_shock_rate=1e-4)
    >>> t.n_pods, t.n_domains
    (2, 6)
    >>> [t.rack_of(s) for s in range(6)]
    [0, 1, 2, 3, 0, 1]
    >>> t.domain_members(4, total=8)     # pod 0 = racks {0, 1}
    [0, 1, 4, 5]
    """

    n_racks: int
    racks_per_pod: int = 0
    rack_shock_rate: float = 0.0
    pod_shock_rate: float = 0.0

    def validate(self, total_servers: int) -> None:
        if self.n_racks < 1:
            raise ValueError(f"n_racks must be >= 1, got {self.n_racks}")
        if self.racks_per_pod < 0:
            raise ValueError("racks_per_pod must be >= 0")
        if self.rack_shock_rate < 0 or self.pod_shock_rate < 0:
            raise ValueError("shock rates must be >= 0")
        if self.pod_shock_rate > 0 and self.racks_per_pod == 0:
            raise ValueError(
                "pod_shock_rate > 0 requires racks_per_pod >= 1")
        if self.n_racks > total_servers:
            raise ValueError(
                f"n_racks={self.n_racks} exceeds the fleet size "
                f"{total_servers}: every rack must hold a server")

    @property
    def n_pods(self) -> int:
        if not self.racks_per_pod:
            return 0
        return math.ceil(self.n_racks / self.racks_per_pod)

    @property
    def n_domains(self) -> int:
        return self.n_racks + self.n_pods

    def rack_of(self, sid: int) -> int:
        return sid % self.n_racks

    def pod_of_rack(self, rack: int) -> int:
        return rack // self.racks_per_pod

    def domain_members(self, domain: int, total: int) -> List[int]:
        """Server ids (workers + spares) belonging to ``domain``."""
        if domain < self.n_racks:
            return [s for s in range(total) if s % self.n_racks == domain]
        pod = domain - self.n_racks
        return [s for s in range(total)
                if (s % self.n_racks) // self.racks_per_pod == pod]

    def domain_rates(self) -> np.ndarray:
        """Per-domain shock rates, racks first then pods — shape (D,)."""
        return np.concatenate([
            np.full(self.n_racks, self.rack_shock_rate, np.float64),
            np.full(self.n_pods, self.pod_shock_rate, np.float64)])

    def domain_fractions(self, total: int) -> np.ndarray:
        """Fraction of the fleet in each domain — shape (D,).

        The CTMC engine carries compartment *counts*, not identities, so
        a shock removes ``fraction * count`` servers from every pool
        (stochastically rounded).  With round-robin assignment the
        striping is uniform, so the per-domain fraction is the exact
        expectation of the event engine's member count in every pool.
        """
        sizes = np.array([len(self.domain_members(d, total))
                          for d in range(self.n_domains)], np.float64)
        return sizes / max(total, 1)


# ---------------------------------------------------------------------------
# campaigns
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CampaignEvent:
    """One scripted injection.

    ``kind="kill"``: fail every server in ``domain`` at ``time``.
    ``kind="maintenance"``: disable the repair shop over
    ``[time, time + duration]`` — in-flight repairs pause and resume
    with their remaining stage time.
    """

    time: float
    kind: str = "kill"
    domain: int = 0
    duration: float = 0.0

    def validate(self, topology: Optional[FaultTopology]) -> None:
        if self.kind not in ("kill", "maintenance"):
            raise ValueError(f"unknown campaign event kind {self.kind!r}")
        if self.time < 0:
            raise ValueError("campaign event time must be >= 0")
        if self.kind == "maintenance" and self.duration <= 0:
            raise ValueError("maintenance windows need duration > 0")
        if self.kind == "kill":
            if topology is None:
                raise ValueError(
                    "campaign kills require Params.fault_domains")
            if not 0 <= self.domain < topology.n_domains:
                raise ValueError(
                    f"kill domain {self.domain} out of range "
                    f"[0, {topology.n_domains})")


@dataclass(frozen=True)
class Campaign:
    """An ordered, validated schedule of :class:`CampaignEvent`.

    >>> c = Campaign(events=({"time": 10.0, "kind": "maintenance",
    ...                       "duration": 5.0},
    ...              CampaignEvent(time=2.0, kind="kill", domain=1)))
    >>> c.schedule()
    [(2.0, 0, 1), (10.0, 1, 0), (15.0, 2, 0)]
    """

    events: Tuple[CampaignEvent, ...] = field(default_factory=tuple)

    def __post_init__(self):
        norm = tuple(CampaignEvent(**e) if isinstance(e, dict) else e
                     for e in self.events)
        object.__setattr__(self, "events", norm)

    def validate(self, topology: Optional[FaultTopology]) -> None:
        for e in self.events:
            e.validate(topology)

    def schedule(self) -> List[Tuple[float, int, int]]:
        """Flatten to a time-sorted list of ``(time, code, domain)``.

        Maintenance windows become two entries (start/end).  The sort is
        stable, so simultaneous entries fire in declaration order on
        both engines.
        """
        flat: List[Tuple[float, int, int]] = []
        for e in self.events:
            if e.kind == "kill":
                flat.append((float(e.time), KILL, e.domain))
            else:
                flat.append((float(e.time), MAINT_START, 0))
                flat.append((float(e.time + e.duration), MAINT_END, 0))
        flat.sort(key=lambda x: x[0])
        return flat


# ---------------------------------------------------------------------------
# CTMC fast-path builders
# ---------------------------------------------------------------------------
# The scan treats the scenario as (static structure, traced numbers):
# the *shape* — number of domains D and the tuple of schedule codes — is
# a static compile key, while every rate, fraction, time, and target
# domain rides in trailing params-vector columns.  A shock-rate grid or
# a campaign-timing grid therefore shares one compiled program.

def scenario_key(p) -> Optional[Tuple[int, Tuple[int, ...]]]:
    """Static compile key ``(D, codes)`` — or None when no scenario."""
    if p.fault_domains is None and p.campaign is None:
        return None
    d = p.fault_domains.n_domains if p.fault_domains is not None else 0
    codes = tuple(code for _, code, _ in p.campaign.schedule()) \
        if p.campaign is not None else ()
    return (d, codes)


def scenario_columns(p) -> np.ndarray:
    """Traced trailing params-vector columns for the scenario.

    Layout: ``[rates (D), fractions (D), times (L), fracs (L),
    domains (L)]`` where L is the flattened schedule length.  Kill
    entries carry the struck domain's fleet fraction; maintenance
    entries carry zeros.
    """
    topo, camp = p.fault_domains, p.campaign
    total = p.working_pool_size + p.spare_pool_size
    if topo is not None:
        rates = topo.domain_rates()
        fracs = topo.domain_fractions(total)
    else:
        rates = fracs = np.zeros(0, np.float64)
    times: List[float] = []
    efracs: List[float] = []
    edoms: List[float] = []
    if camp is not None:
        for t, code, dom in camp.schedule():
            times.append(t)
            efracs.append(float(fracs[dom]) if code == KILL else 0.0)
            edoms.append(float(dom))
    return np.concatenate([rates, fracs,
                           np.asarray(times, np.float64),
                           np.asarray(efracs, np.float64),
                           np.asarray(edoms, np.float64)])


def scenario_budget(p, horizon: float) -> Tuple[float, float]:
    """``(extra_steps, extra_horizon)`` for the CTMC step budget.

    Each shock consumes one scan step plus the repair traffic of the
    block it kills (~4 steps per killed server: auto completion,
    escalation, manual completion, return/unstall).  Maintenance
    windows stretch the horizon by their duration (repairs pause) and
    campaign entries each take a step of their own.
    """
    topo, camp = p.fault_domains, p.campaign
    total = p.working_pool_size + p.spare_pool_size
    extra_steps = 0.0
    extra_horizon = 0.0
    if topo is not None:
        rates = topo.domain_rates()
        sizes = topo.domain_fractions(total) * total
        lam = float(rates.sum())
        if lam > 0:
            n_shocks = lam * horizon
            mean_kill = float((rates * sizes).sum()) / lam
            extra_steps += n_shocks * (2.0 + 4.0 * mean_kill)
            extra_horizon += n_shocks * (
                p.recovery_time + p.host_selection_time + p.waiting_time)
    if camp is not None:
        for _, code, dom in camp.schedule():
            extra_steps += 2.0
            if code == KILL and topo is not None:
                extra_steps += 4.0 * len(topo.domain_members(dom, total))
            elif code == MAINT_END:
                pass
        extra_horizon += sum(e.duration for e in camp.events
                             if e.kind == "maintenance")
    return extra_steps, extra_horizon


# ---------------------------------------------------------------------------
# event-engine injector
# ---------------------------------------------------------------------------

@dataclass
class Injection:
    """One injection popped from the merged stream."""

    time: float
    kind: str                      # "shock" | "kill" | "maint_start" | "maint_end"
    domain: int
    members: Sequence[int]         # struck server ids ([] for maintenance)


class ShockInjector:
    """Merged random-shock + campaign stream for the event engine.

    Per-domain shock arrivals are drawn lazily (one exponential gap per
    pop) from the simulation RNG; the campaign schedule is a pointer
    walk.  ``peek()`` returns the next injection time (inf when
    exhausted), ``pop()`` consumes it.  Ties between a shock and a
    campaign entry resolve campaign-first, matching the CTMC race where
    deterministic residual ties break on the first (campaign) column.
    """

    def __init__(self, topology: Optional[FaultTopology],
                 campaign: Optional[Campaign], total: int, rng) -> None:
        self.topology = topology
        self._rng = rng
        if topology is not None:
            self._rates = topology.domain_rates()
            self._members = [topology.domain_members(d, total)
                             for d in range(topology.n_domains)]
            self._next = np.array(
                [rng.exponential(1.0 / r) if r > 0 else math.inf
                 for r in self._rates])
        else:
            self._rates = np.zeros(0)
            self._members = []
            self._next = np.zeros(0)
        self._schedule = campaign.schedule() if campaign is not None else []
        self._ptr = 0

    def _next_campaign_time(self) -> float:
        if self._ptr >= len(self._schedule):
            return math.inf
        return self._schedule[self._ptr][0]

    def peek(self) -> float:
        t = self._next_campaign_time()
        if len(self._next):
            t = min(t, float(self._next.min()))
        return t

    def pop(self) -> Injection:
        t_camp = self._next_campaign_time()
        t_shock = float(self._next.min()) if len(self._next) else math.inf
        if t_camp <= t_shock:            # campaign wins ties (see class doc)
            t, code, dom = self._schedule[self._ptr]
            self._ptr += 1
            if code == KILL:
                return Injection(t, "kill", dom, self._members[dom])
            kind = "maint_start" if code == MAINT_START else "maint_end"
            return Injection(t, kind, 0, [])
        d = int(self._next.argmin())
        t = self._next[d]
        self._next[d] = t + self._rng.exponential(1.0 / self._rates[d])
        return Injection(float(t), "shock", d, self._members[d])
