"""Event-trace observability for simulation runs.

Production fleets debug reliability policies from event timelines; the
simulator gives the same artifact: an optional tracer records every
state-changing event (failures, repairs, swaps, preemptions, stalls)
with timestamps and server identities, exportable to CSV / a
chrome://tracing-compatible JSON timeline.

Usage:
    tracer = Tracer()
    sim = ClusterSimulation(params)
    tracer.attach(sim)
    sim.run()
    tracer.write_csv("results/trace.csv")
    tracer.summary()
"""

from __future__ import annotations

import csv
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass(frozen=True)
class TraceEvent:
    time: float
    kind: str               # failure | repair_start | repair_done | swap...
    server: int             # -1 = cluster-level
    detail: str = ""


@dataclass
class Tracer:
    events: List[TraceEvent] = field(default_factory=list)

    def record(self, time: float, kind: str, server: int = -1,
               detail: str = "") -> None:
        self.events.append(TraceEvent(time, kind, server, detail))

    # -- attachment (monkey-patch observation points; the simulator stays
    # dependency-free when no tracer is attached) --------------------------
    def attach(self, sim) -> None:
        coord = sim.coordinator
        shop = sim.repair_shop
        sched = sim.scheduler
        env = sim.env
        tracer = self

        orig_diag = coord._diagnose

        def diagnose(failed):
            target = orig_diag(failed)
            tracer.record(env.now, "failure", failed.sid,
                          "bad" if failed.is_bad else "good")
            if target is None:
                tracer.record(env.now, "undiagnosed", failed.sid)
            elif target is not failed:
                tracer.record(env.now, "misdiagnosed", target.sid,
                              f"actual={failed.sid}")
            return target

        coord._diagnose = diagnose

        orig_submit = shop.submit

        def submit(server):
            tracer.record(env.now, "repair_start", server.sid)
            return orig_submit(server)

        shop.submit = submit

        orig_return = shop.on_return

        def on_return(server):
            tracer.record(env.now, "repair_done", server.sid,
                          "healed" if not server.is_bad else "still-bad")
            return orig_return(server)

        shop.on_return = on_return

        orig_acquire = sched.acquire_replacement

        def acquire_replacement():
            t0 = env.now
            server = yield from orig_acquire()
            kind = "standby_swap" if env.now == t0 else "host_selection"
            tracer.record(env.now, kind, server.sid,
                          f"wait={env.now - t0:.1f}")
            return server

        sched.acquire_replacement = acquire_replacement

        # fault-domain injections (shock | kill | maint_start | maint_end)
        if getattr(sim, "injector", None) is not None:
            orig_apply = coord._apply_injection

            def apply_injection(inj):
                tracer.record(env.now, inj.kind, -1,
                              f"domain={inj.domain} "
                              f"members={len(inj.members)}")
                return orig_apply(inj)

            coord._apply_injection = apply_injection

    # -- outputs -------------------------------------------------------------
    def write_csv(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["time_min", "kind", "server", "detail"])
            for e in self.events:
                w.writerow([f"{e.time:.3f}", e.kind, e.server, e.detail])

    def write_chrome_trace(self, path: str) -> None:
        """chrome://tracing 'trace events' JSON (instant events)."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = [{
            "name": e.kind, "ph": "i", "ts": e.time * 60e6,  # min -> us
            "pid": 0, "tid": max(e.server, 0), "s": "g",
            "args": {"detail": e.detail, "server": e.server},
        } for e in self.events]
        with open(path, "w") as f:
            json.dump({"traceEvents": payload}, f)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def repeat_offenders(self, top: int = 5) -> List[tuple]:
        """Servers with the most failures — retirement-policy candidates."""
        per: Dict[int, int] = {}
        for e in self.events:
            if e.kind == "failure":
                per[e.server] = per.get(e.server, 0) + 1
        return sorted(per.items(), key=lambda kv: -kv[1])[:top]

    def summary(self) -> str:
        lines = [f"{len(self.events)} events"]
        for kind, n in sorted(self.counts().items()):
            lines.append(f"  {kind:16s} {n}")
        off = self.repeat_offenders()
        if off:
            lines.append("  repeat offenders: "
                         + ", ".join(f"s{sid}x{n}" for sid, n in off))
        return "\n".join(lines)
