"""Hazard models for the vectorized CTMC engine's non-exponential fast path.

The event engine samples non-exponential failures by drawing one fresh
time-to-failure per running server at every compute-phase start
(:class:`repro.core.server.FailureSampler` — the paper's "failure process
starts when a job is started on a server").  The minimum of ``n`` iid
draws from a distribution with per-server hazard ``h(t)`` is a single
first-passage time with hazard ``n * h(t)`` where ``t`` is the *phase
age* (time computed since the last restart) — so the whole fleet's
failure process collapses to one age-indexed intensity per health class.
That is the state the vectorized scan carries: one ``age`` scalar per
replica, advancing through COMPUTE intervals and resetting to zero
whenever the job (re)starts.

Two sampling mechanisms cover the supported families:

* **Weibull** — closed-form conditional inversion.  All clocks share the
  shape ``k``, so the combined cumulative hazard is ``H(t) = C * t**k``
  with ``C = sum_i lam_i**-k`` over every active clock, and the
  time-to-failure from age ``a`` conditional on survival is exactly

      s = (a**k + E / C) ** (1/k) - a,   E ~ Exp(1).

  No thinning is needed (and none would work: the ``k < 1``
  infant-mortality hazard diverges at age zero, so no finite majorant
  exists there).  The sampled ``s`` enters the event race as a
  deterministic residual; the failing class is then drawn categorically
  from the per-class hazard weights, which are age-independent because
  every clock shares the same ``t**(k-1)`` time profile.

* **Bathtub** (:mod:`repro.core.bathtub`) — piecewise-constant hazard
  majorization with Ogata-style thinning.  The bathtub hazard factors as
  ``rate * g(t)`` with the dimensionless shape ``g`` shared by the
  random and systematic clocks, and ``g`` is convex (decaying
  exponential + constant + hinge), so its supremum over any age window
  ``[a, a + W]`` is attained at an endpoint:
  ``g_bar = max(g(a), g(a + W))``.  Each scan step scales the
  exponential failure propensities by ``g_bar``, races them with a
  window-expiry timer ``W`` (a *phantom* event that merely re-anchors
  the majorant), and accepts a winning failure candidate with
  probability ``g(a + dt) / g_bar`` — rejected candidates are phantoms
  too.  Validity needs exactly ``g_bar >= g`` on ``[a, a + W]``, which
  the convexity argument gives for every parameterization.

Host-side helpers here build the per-point hazard parameter columns that
ride along the traced ``(P, 15 + N_HAZARD_COLS)`` parameter matrix, and
the JAX helpers evaluate ``g`` / the Weibull inversion inside the
compiled step.  ``hazard_kind`` is the single source of truth for which
families :func:`repro.core.vectorized.supports` accepts.
"""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .bathtub import Bathtub
from .distributions import Weibull, failure_distribution
from .params import Params

#: failure-distribution families the vectorized engine can run.  The
#: kind is a *static* compile-time switch: each family compiles its own
#: step program (exponential keeps the exact pre-existing one).
HAZARD_KINDS = ("exponential", "weibull", "bathtub")

#: hazard parameter columns appended to the 15 base parameter columns.
#: Interpretation depends on the (static) hazard kind:
#:   weibull : [C_rand, C_sys, k, 0, 0]        C = lam**-k per clock
#:   bathtub : [infant_factor, infant_tau, wear_start, wear_tau, window]
#:   exponential : all zeros (unused)
N_HAZARD_COLS = 5

#: fraction of the fastest bathtub time constant used as the thinning
#: window W: small enough that the endpoint majorant stays tight
#: (rejection fraction ~W/tau), large enough that window-expiry phantom
#: events are rare next to real cluster events.
BATHTUB_WINDOW_FRACTION = 0.25


def _build_distribution(params: Params, rate: float):
    """The event engine's own distribution object for this failure clock.

    Going through the registry factory keeps every kwarg default in ONE
    place (the :class:`Weibull` / :class:`Bathtub` dataclasses): if a
    default is ever retuned there, both engines move together instead of
    the fast path keeping a stale copy.  Returns None when construction
    fails — dispatch treats that as unsupported.
    """
    try:
        return failure_distribution(params.failure_distribution, rate,
                                    **params.distribution_kwargs)
    except (ValueError, TypeError):
        return None


def hazard_kind(params: Params) -> Optional[str]:
    """The vectorized engine's hazard family for these Params, or None.

    None means the failure distribution is outside the fast path
    (lognormal, deterministic, user-registered — including a
    re-registered "weibull"/"bathtub" name that no longer builds the
    expected class) and the event engine must run it.  Degenerate
    parameters (``k <= 0``, non-positive taus, ``infant_factor < 1``,
    which would break the ``g >= 1`` acceptance-probability bound) also
    return None rather than raising.
    """
    name = params.failure_distribution.lower()
    if name == "exponential":
        return "exponential"
    if name not in ("weibull", "bathtub"):
        return None
    dist = _build_distribution(params, params.random_failure_rate)
    if isinstance(dist, Weibull):
        return "weibull" if dist.k > 0 else None
    if isinstance(dist, Bathtub):
        ok = (dist.infant_factor >= 1.0 and dist.infant_tau > 0
              and dist.wear_tau > 0)
        return "bathtub" if ok else None
    return None


def _weibull_clock_coeff(w: Weibull) -> float:
    """``lam**-k`` for a mean-parameterized Weibull clock; 0 for a
    disabled clock (infinite mean, i.e. zero rate)."""
    if not math.isfinite(w.mean_value) or w.mean_value <= 0.0:
        return 0.0
    lam = w.mean_value / math.gamma(1.0 + 1.0 / w.k)
    return lam ** -w.k


def hazard_columns(params: Params) -> np.ndarray:
    """Per-point hazard parameter columns (traced inputs), host-side.

    Shape ``(N_HAZARD_COLS,)`` float32; see the column legend on
    :data:`N_HAZARD_COLS`.  Values are read off the same distribution
    objects the event engine samples from, never from re-stated kwarg
    defaults.
    """
    kind = hazard_kind(params)
    cols = np.zeros(N_HAZARD_COLS, np.float32)
    if kind == "weibull":
        w_rand = _build_distribution(params, params.random_failure_rate)
        w_sys = _build_distribution(params, params.systematic_failure_rate)
        cols[0] = _weibull_clock_coeff(w_rand)
        cols[1] = _weibull_clock_coeff(w_sys)
        cols[2] = w_rand.k
    elif kind == "bathtub":
        bt = _build_distribution(params, params.random_failure_rate)
        cols[0] = bt.infant_factor
        cols[1] = bt.infant_tau
        cols[2] = bt.wear_start
        cols[3] = bt.wear_tau
        cols[4] = BATHTUB_WINDOW_FRACTION * min(bt.infant_tau, bt.wear_tau)
    return cols


def effective_event_rate(params: Params) -> float:
    """Cluster failure-event rate estimate for step budgeting (host-side).

    Because every failure clock restarts at each compute-phase start,
    the phase age rarely leaves the early part of the hazard curve when
    phases are short — so the *age-zero-ish* hazard, not the long-run
    mean rate, governs how many events a job generates:

    * weibull — the exact mean phase length is
      ``Gamma(1 + 1/k) * C**(-1/k)`` (the min of the fleet's clocks is
      itself Weibull); the budget uses its reciprocal.
    * bathtub — the hazard at age zero is ``infant_factor`` times the
      flat rate; the mean-rate estimate scales accordingly (an upper
      bound, which is the safe direction for a step budget).
    * exponential — the paper's ``expected_failures_per_minute``.
    """
    kind = hazard_kind(params)
    lam = params.expected_failures_per_minute()
    if kind == "weibull":
        cols = hazard_columns(params)
        c_rand, c_sys, k = float(cols[0]), float(cols[1]), float(cols[2])
        n_bad = params.systematic_failure_fraction * params.job_size
        C = params.job_size * c_rand + n_bad * c_sys
        if C <= 0.0:
            return 0.0
        mean_phase = math.gamma(1.0 + 1.0 / k) * C ** (-1.0 / k)
        return 1.0 / max(mean_phase, 1e-12)
    if kind == "bathtub":
        return lam * float(hazard_columns(params)[0])   # g(0) ~ infant_factor
    return lam


def phantom_steps(params: Params) -> int:
    """Extra scan steps budgeted for thinning phantoms (host-side).

    Bathtub thinning fires a window-expiry phantom at most every ``W``
    compute minutes plus a rejected candidate per accepted one in the
    worst case; Weibull inversion is phantom-free.
    """
    if hazard_kind(params) != "bathtub":
        return 0
    cols = hazard_columns(params)
    window = float(cols[4])
    if window <= 0.0:
        return 0
    return int(params.job_length / window) + 1


# ---------------------------------------------------------------------------
# JAX-side hazard math (used inside the compiled scan step)
# ---------------------------------------------------------------------------

def bathtub_shape(t, infant_factor, infant_tau, wear_start, wear_tau):
    """Dimensionless bathtub hazard shape ``g(t) = h(t) / h_flat``.

    Mirrors :meth:`repro.core.bathtub.Bathtub.hazard` exactly:
    ``g(t) = 1 + (IF - 1) * exp(-t / tau_i) + relu(t - t_w) / tau_w``.
    Convex in ``t``, and ``g >= 1`` everywhere (``IF >= 1`` is enforced
    by :func:`hazard_kind`), so endpoint majorants and acceptance
    probabilities are both well-defined.
    """
    g = 1.0 + (infant_factor - 1.0) * jnp.exp(-t / infant_tau)
    return g + jnp.maximum(t - wear_start, 0.0) / wear_tau


def weibull_conditional_ttf(age, C, k, exp_draw):
    """Exact time-to-first-failure from phase age ``age``.

    ``C`` is the summed ``lam**-k`` over all active clocks (zero when no
    clock can fire), ``k`` the shared shape, ``exp_draw`` an Exp(1)
    variate.  Returns +inf where ``C <= 0``.  Solves
    ``C * ((age + s)**k - age**k) = E`` for ``s``.
    """
    safe_c = jnp.maximum(C, 1e-30)
    target = jnp.power(age, k) + exp_draw / safe_c
    s = jnp.power(target, 1.0 / k) - age
    return jnp.where(C > 0.0, jnp.maximum(s, 0.0), jnp.inf)
