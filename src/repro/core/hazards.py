"""Hazard samplers for the vectorized CTMC engine's non-exponential paths.

The event engine samples non-exponential failures by drawing one fresh
time-to-failure per running server at every compute-phase start
(:class:`repro.core.server.FailureSampler` — the paper's "failure process
starts when a job is started on a server").  The minimum of ``n`` iid
draws from a distribution with per-server hazard ``h(t)`` is a single
first-passage time with hazard ``n * h(t)`` where ``t`` is the *phase
age* (time computed since the last restart) — so the whole fleet's
failure process collapses to one age-indexed intensity per health class.
That is the state the vectorized scan carries: one ``age`` scalar per
replica, advancing through COMPUTE intervals and resetting to zero
whenever the job (re)starts.

Repairs are different in two ways: their clocks do **not** reset with
the job (a repair in flight keeps its progress across restarts), and
servers enter the shop at different times, so there is no shared age.
The scan therefore carries a second lane of per-replica repair *slots*
(:mod:`repro.core.vectorized`), each holding one in-repair server's
class, stage, and remaining duration — sampled **at entry** by exact
inverse-CDF ("conditional inversion from age zero") through the same
family machinery the failure race uses.

All of that machinery lives behind one interface:

:class:`HazardSampler` — per-family sampling primitives consumed by both
the failure race and the repair race:

* ``conditional_residual`` — exact closed-form time-to-event from a
  given age, conditional on survival (inversion families: Weibull).
* ``majorant`` / ``hazard`` — a provably valid hazard bound over an age
  window plus the exact hazard for the Ogata-thinning accept step
  (thinning families: bathtub via convex-endpoint bound, lognormal via
  a numerically located hazard-mode bound).
* ``quantile`` — exact inverse-CDF duration sampling for the repair
  slots (Weibull / lognormal / deterministic).

``FAILURE_SAMPLERS`` / ``REPAIR_SAMPLERS`` register which families each
race accepts; :func:`hazard_kind` / :func:`repair_kind` are the single
sources of truth :func:`repro.core.vectorized.supports` dispatches on.

Sampling mechanisms per failure family:

* **Weibull** — closed-form conditional inversion.  All clocks share the
  shape ``k``, so the combined cumulative hazard is ``H(t) = C * t**k``
  with ``C = sum_i lam_i**-k`` over every active clock, and the
  time-to-failure from age ``a`` conditional on survival is exactly

      s = (a**k + E / C) ** (1/k) - a,   E ~ Exp(1).

  No thinning is needed (and none would work: the ``k < 1``
  infant-mortality hazard diverges at age zero, so no finite majorant
  exists there).  The sampled ``s`` enters the event race as a
  deterministic residual; the failing class is then drawn categorically
  from the per-class hazard weights, which are age-independent because
  every clock shares the same ``t**(k-1)`` time profile.

* **Bathtub** (:mod:`repro.core.bathtub`) — piecewise-constant hazard
  majorization with Ogata-style thinning.  The bathtub hazard factors as
  ``rate * g(t)`` with the dimensionless shape ``g`` shared by the
  random and systematic clocks, and ``g`` is convex (decaying
  exponential + constant + hinge), so its supremum over any age window
  ``[a, a + W]`` is attained at an endpoint:
  ``g_bar = max(g(a), g(a + W))``.  Each scan step scales the
  exponential failure propensities by ``g_bar``, races them with a
  window-expiry timer ``W`` (a *phantom* event that merely re-anchors
  the majorant), and accepts a winning failure candidate with
  probability ``g(a + dt) / g_bar`` — rejected candidates are phantoms
  too.  Validity needs exactly ``g_bar >= g`` on ``[a, a + W]``, which
  the convexity argument gives for every parameterization.

* **Empirical** (:mod:`repro.core.empirical`) — piecewise-constant
  hazards fit from measured failure logs, thinned with an *exact*
  majorant.  The hazard is constant inside each segment, so over the
  window ending at the next segment edge the supremum is the current
  rate itself: candidates are accepted with probability ~1 and the only
  phantoms are the segment-boundary re-anchors.  Random and systematic
  clocks carry independent ``(edges, rates)`` arrays (a registered
  distribution opting in via the ``hazard_segments()`` protocol may
  shape them arbitrarily per clock), padded to one shared segment count
  — the only *static* compile key; edges and rates are traced, so a
  grid over hazards fitted from different log slices is one XLA
  program.  A single-segment empirical hazard is memoryless and
  dispatches to the exact exponential program (bit-identical
  reduction).

* **Lognormal** — mode-bound majorization with Ogata thinning.  The
  lognormal hazard is neither monotone nor convex: it rises from zero
  to a single interior maximum and then decays, so the bathtub endpoint
  bound is invalid.  It *is* unimodal (Sweet 1990), so the supremum
  over ``[a, a + W]`` is the hazard at the mode clipped into the
  window: ``h_bar = h(clip(t_mode, a, a + W))``.  The mode location has
  no closed form; it is located **numerically** host-side, once per
  sigma (the lognormal is a scale family — ``t_mode = scale *
  mode_rel(sigma)``), and rides along as a traced parameter column.
  Random and systematic clocks have different scales, so each family
  carries its own majorant and acceptance ratio — thinning two
  independent inhomogeneous Poisson processes separately is exact.

Host-side helpers here build the per-point hazard parameter columns that
ride along the traced ``(P, 15 + N_HAZARD_COLS + N_REPAIR_COLS)``
parameter matrix, and the JAX helpers evaluate the hazards / inversions
/ quantiles inside the compiled step.
"""

from __future__ import annotations

import math
import warnings
from functools import lru_cache
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.scipy.special import log_ndtr, ndtri

from .bathtub import Bathtub
from .distributions import (Deterministic, LogNormal, Weibull,
                            failure_distribution)
from .empirical import Empirical, pad_segments, validate_segments
from .params import Params

#: failure-distribution families the vectorized engine can run.  The
#: kind is a *static* compile-time switch: each family compiles its own
#: step program (exponential keeps the exact pre-existing one).
HAZARD_KINDS = ("exponential", "weibull", "bathtub", "lognormal",
                "empirical")

#: repair-distribution families the vectorized engine can run.
#: Exponential keeps the original count-based repair compartments (the
#: memoryless case needs no per-server state); the others run the
#: repair-slot lane with durations sampled at entry by inverse CDF.
REPAIR_KINDS = ("exponential", "weibull", "lognormal", "deterministic",
                "empirical")

#: hazard parameter columns appended to the 15 base parameter columns.
#: Interpretation depends on the (static) hazard kind:
#:   weibull   : [C_rand, C_sys, k, 0, 0]        C = lam**-k per clock
#:   bathtub   : [infant_factor, infant_tau, wear_start, wear_tau, window]
#:   lognormal : [scale_rand, scale_sys, sigma, mode_rel, window]
#:   exponential : all zeros (unused)
#: The empirical family's block is segment-count-dependent instead —
#:   empirical : [rand_edges (m-1), rand_rates (m),
#:                sys_edges (m-1), sys_rates (m)]      (4m - 2 columns)
#: with m the static segment count; use :func:`hazard_col_count`.
N_HAZARD_COLS = 5

#: repair parameter columns appended after the hazard columns.
#: Interpretation depends on the (static) repair kind:
#:   weibull       : [lam_auto, lam_man, k]
#:   lognormal     : [scale_auto, scale_man, sigma]
#:   deterministic : [value_auto, value_man, 0]
#:   exponential   : all zeros (unused — legacy rate-race path)
#:   empirical     : [auto_edges (m-1), auto_rates (m),
#:                    man_edges (m-1), man_rates (m)]   (4m - 2 columns)
#: See :func:`repair_col_count` for the kind-dependent width.
N_REPAIR_COLS = 3


def hazard_col_count(kind: Optional[str], n_segments: int = 0) -> int:
    """Width of the hazard-column block for this (static) family.

    Closed-form families share the fixed :data:`N_HAZARD_COLS` layout;
    the empirical family's width grows with the static segment count.

    >>> hazard_col_count("weibull")
    5
    >>> hazard_col_count("empirical", 4)
    14
    """
    return 4 * n_segments - 2 if kind == "empirical" else N_HAZARD_COLS


def repair_col_count(kind: Optional[str], n_segments: int = 0) -> int:
    """Width of the repair-column block for this (static) family."""
    return 4 * n_segments - 2 if kind == "empirical" else N_REPAIR_COLS

#: fraction of the fastest bathtub time constant used as the thinning
#: window W: small enough that the endpoint majorant stays tight
#: (rejection fraction ~W/tau), large enough that window-expiry phantom
#: events are rare next to real cluster events.
BATHTUB_WINDOW_FRACTION = 0.25

#: lognormal thinning window, as a fraction of the earliest enabled
#: clock's hazard-mode time — the scale on which the hazard actually
#: varies.  Same tightness/phantom-rate trade as the bathtub window.
LOGNORMAL_WINDOW_FRACTION = 0.25

_LOG_SQRT_2PI = 0.5 * math.log(2.0 * math.pi)


def _build_distribution(params: Params, rate: float):
    """The event engine's own distribution object for this failure clock.

    Going through the registry factory keeps every kwarg default in ONE
    place (the :class:`Weibull` / :class:`Bathtub` / :class:`LogNormal`
    dataclasses): if a default is ever retuned there, both engines move
    together instead of the fast path keeping a stale copy.  Returns
    None when construction fails — dispatch treats that as unsupported.
    """
    try:
        return failure_distribution(params.failure_distribution, rate,
                                    **params.distribution_kwargs)
    except (ValueError, TypeError):
        return None


def _build_repair_distributions(params: Params):
    """(auto, manual) repair distributions, or (None, None) on failure."""
    from .repair import repair_distributions
    try:
        return repair_distributions(params)
    except (ValueError, TypeError):
        return None, None


@lru_cache(maxsize=1)
def _scipy_available() -> bool:
    """The lognormal fast path needs scipy host-side (mode location /
    peak hazard via ``scipy.special.log_ndtr``).  scipy ships with jax's
    own dependency set, but if it is ever absent the graceful-degrade
    convention applies: dispatch falls back to the event engine instead
    of committing to the fast path and crashing mid-run.  The fallback
    is loud — a one-time RuntimeWarning (the lru_cache makes it fire
    once) — because a mis-provisioned environment silently running the
    O(cluster)-per-restart event engine looks like a perf regression,
    not a packaging problem."""
    try:
        import scipy.special  # noqa: F401
        return True
    except ImportError:
        warnings.warn(
            "scipy is unavailable: lognormal failure hazards cannot run "
            "on the vectorized fast path, so engine='auto' will fall "
            "back to the much slower O(cluster)-per-restart event "
            "engine for them (install scipy to restore the CTMC path)",
            RuntimeWarning, stacklevel=2)
        return False


def _clock_segments(dist):
    """Classify one clock's distribution for the piecewise-constant path.

    Returns ``(edges, rates)`` float arrays for a fast-path-eligible
    clock, the string ``"off"`` for a clock that never fires (disabled
    — ``hazard_segments()`` returned None), or None when the
    distribution is ineligible (no ``hazard_segments()`` protocol, or
    segments that fail :func:`repro.core.empirical.validate_segments`).
    """
    probe = getattr(dist, "hazard_segments", None)
    if probe is None or not callable(probe):
        return None
    try:
        seg = probe()
    except Exception:  # graceful-degrade: user protocol code may raise
        return None
    if seg is None:
        return "off"
    try:
        edges, rates = seg
    except (TypeError, ValueError):
        return None
    if not validate_segments(edges, rates):
        return None
    return (np.asarray(edges, dtype=float), np.asarray(rates, dtype=float))


def _piecewise_pair_kind(d_rand, d_sys) -> Optional[str]:
    """Dispatch for the piecewise-constant path (a pair of clocks).

    Any registered distribution exposing the ``hazard_segments()``
    protocol qualifies — this absorbs the old "user-registered
    distributions are event-engine-only" carve-out.  A single-segment
    builtin :class:`Empirical` is memoryless with rate exactly
    ``1 / mean``, so it collapses to the exponential program
    (bit-identical reduction).
    """
    if d_rand is None or d_sys is None:
        return None
    s_rand = _clock_segments(d_rand)
    s_sys = _clock_segments(d_sys)
    if s_rand is None or s_sys is None:
        return None
    if (isinstance(d_rand, Empirical) and d_rand.n_segments == 1
            and isinstance(d_sys, Empirical) and d_sys.n_segments == 1):
        return "exponential"
    return "empirical"


def hazard_kind(params: Params) -> Optional[str]:
    """The vectorized engine's failure-hazard family, or None.

    None means the failure distribution is outside the fast path and
    the event engine must run it: deterministic failures, and
    registered distributions — including a re-registered builtin name
    that no longer builds the expected class — that do not opt in via
    the ``hazard_segments()`` piecewise-constant protocol.  Degenerate
    parameters (``k <= 0``, non-positive taus, ``infant_factor < 1``
    which would break the ``g >= 1`` acceptance-probability bound,
    ``sigma <= 0``, empty / duplicate / non-monotone empirical segment
    edges, defective zero-rate tails) also return None rather than
    raising.  A single-segment builtin empirical hazard is memoryless
    and returns "exponential" (bit-identical program reduction).
    """
    name = params.failure_distribution.lower()
    if name == "exponential":
        return "exponential"
    dist = _build_distribution(params, params.random_failure_rate)
    if name == "weibull" and isinstance(dist, Weibull):
        return "weibull" if dist.k > 0 else None
    if name == "bathtub" and isinstance(dist, Bathtub):
        ok = (dist.infant_factor >= 1.0 and dist.infant_tau > 0
              and dist.wear_tau > 0)
        return "bathtub" if ok else None
    if name == "lognormal" and isinstance(dist, LogNormal):
        return "lognormal" if dist.sigma > 0 and _scipy_available() else None
    # everything else — the builtin "empirical" family and any registered
    # distribution opting in via the hazard_segments() protocol — runs
    # the piecewise-constant program (None keeps it on the event engine)
    return _piecewise_pair_kind(
        dist, _build_distribution(params, params.systematic_failure_rate))


def repair_kind(params: Params) -> Optional[str]:
    """The vectorized engine's repair family for these Params, or None.

    Mirrors :func:`hazard_kind` for the repair side: None routes the
    point to the event engine (registered families without the
    ``hazard_segments()`` protocol, or degenerate parameters —
    ``k <= 0``, ``sigma <= 0``, invalid empirical segments).  The
    empirical pair here is (auto, manual) rather than (random,
    systematic); a single-segment builtin empirical repair collapses to
    the exponential repair program the same way.
    """
    name = params.repair_distribution.lower()
    if name == "exponential":
        return "exponential"
    auto, man = _build_repair_distributions(params)
    if name == "weibull" and isinstance(auto, Weibull):
        return "weibull" if auto.k > 0 else None
    if name == "lognormal" and isinstance(auto, LogNormal):
        return "lognormal" if auto.sigma > 0 else None
    if name == "deterministic" and isinstance(auto, Deterministic):
        return "deterministic"
    return _piecewise_pair_kind(auto, man)


def _padded_pair_count(d_a, d_b) -> int:
    """Shared segment count for a pair of piecewise-constant clocks.

    The max over both clocks' fitted counts, floored at 2 so the traced
    edge arrays are never zero-width (a genuinely single-segment builtin
    hazard never reaches here — it collapses to the exponential
    program in dispatch).
    """
    n = 1
    for d in (d_a, d_b):
        seg = _clock_segments(d)
        if isinstance(seg, tuple):
            n = max(n, len(seg[1]))
    return max(n, 2)


def hazard_segment_count(params: Params) -> int:
    """The empirical failure program's static segment count (else 0).

    This is the ONLY static compile key the empirical family adds: the
    edges and rates themselves are traced columns, so a sweep over
    hazards fitted from different log slices shares one program as long
    as the (padded) segment counts agree.
    """
    if hazard_kind(params) != "empirical":
        return 0
    return _padded_pair_count(
        _build_distribution(params, params.random_failure_rate),
        _build_distribution(params, params.systematic_failure_rate))


def repair_segment_count(params: Params) -> int:
    """The empirical repair program's static segment count (else 0)."""
    if repair_kind(params) != "empirical":
        return 0
    auto, man = _build_repair_distributions(params)
    return _padded_pair_count(auto, man)


def _pair_segment_columns(d_a, d_b, m: int) -> np.ndarray:
    """``[a_edges (m-1), a_rates (m), b_edges (m-1), b_rates (m)]``.

    Disabled clocks become all-zero rates over synthetic edges (zero
    hazard never fires); shorter fits pad by repeating the terminal
    rate, which leaves the hazard function unchanged.
    """
    blocks = []
    for d in (d_a, d_b):
        seg = _clock_segments(d)
        if isinstance(seg, tuple):
            e, r = pad_segments(seg[0], seg[1], m)
        else:
            e, r = np.arange(1.0, m), np.zeros(m)
        blocks.extend([e, r])
    return np.concatenate(blocks).astype(np.float32)


def _weibull_clock_coeff(w: Weibull) -> float:
    """``lam**-k`` for a mean-parameterized Weibull clock; 0 for a
    disabled clock (infinite mean, i.e. zero rate)."""
    lam = w.lam
    return 0.0 if lam <= 0.0 else lam ** -w.k


def _lognormal_log_hazard_host(logt: float, sigma: float) -> float:
    """Host-side unit-scale log hazard ``log h(e^logt)`` (scipy).

    The single host-side copy of the lognormal hazard formula; it must
    mirror :func:`lognormal_hazard` (the JAX twin evaluated inside the
    compiled step) term for term — mode location and step budgeting
    read THIS one, the thinning acceptance reads the JAX one, and a fix
    applied to only one of them silently desynchronizes the majorant
    from the acceptance ratio.
    """
    from scipy.special import log_ndtr as np_log_ndtr

    z = logt / sigma
    return -0.5 * z * z - _LOG_SQRT_2PI - np_log_ndtr(-z) \
        - math.log(sigma) - logt


@lru_cache(maxsize=64)
def _lognormal_mode_rel(sigma: float) -> float:
    """Hazard-mode time of a unit-scale lognormal, located numerically.

    The lognormal hazard ``h(t) = phi(z) / (sigma * t * Phi(-z))`` with
    ``z = ln(t) / sigma`` (scale 1) is unimodal (Sweet 1990): it rises
    from 0 to one interior maximum and decays.  There is no closed form
    for the argmax, so it is found by ternary search on ``log t`` —
    valid precisely because of unimodality.  The result scales to any
    clock as ``t_mode = scale * mode_rel(sigma)``; cached per sigma
    since a whole sweep typically shares one sigma.
    """
    lo, hi = -40.0 * sigma - 5.0, 40.0 * sigma + 5.0
    for _ in range(200):
        m1 = lo + (hi - lo) / 3.0
        m2 = hi - (hi - lo) / 3.0
        if _lognormal_log_hazard_host(m1, sigma) \
                < _lognormal_log_hazard_host(m2, sigma):
            lo = m1
        else:
            hi = m2
    return math.exp(0.5 * (lo + hi))


def _lognormal_peak_hazard(scale: float, sigma: float) -> float:
    """``max_t h(t)`` for a lognormal clock, host-side.

    Unit-scale peak rescaled by the clock's scale: the lognormal is a
    scale family, ``h_scale(t) = h_1(t / scale) / scale``.
    """
    if scale <= 0.0:
        return 0.0
    logt_mode = math.log(_lognormal_mode_rel(sigma))
    return math.exp(_lognormal_log_hazard_host(logt_mode, sigma)) / scale


def hazard_columns(params: Params) -> np.ndarray:
    """Per-point failure-hazard parameter columns (traced inputs).

    Shape ``(hazard_col_count(kind, n_segments),)`` float32 — the fixed
    :data:`N_HAZARD_COLS` layout for the closed-form families, the
    segment-count-dependent empirical layout otherwise.  Values are
    read off the same distribution objects the event engine samples
    from, never from re-stated kwarg defaults.
    """
    kind = hazard_kind(params)
    if kind == "empirical":
        return _pair_segment_columns(
            _build_distribution(params, params.random_failure_rate),
            _build_distribution(params, params.systematic_failure_rate),
            hazard_segment_count(params))
    cols = np.zeros(N_HAZARD_COLS, np.float32)
    if kind == "weibull":
        w_rand = _build_distribution(params, params.random_failure_rate)
        w_sys = _build_distribution(params, params.systematic_failure_rate)
        cols[0] = _weibull_clock_coeff(w_rand)
        cols[1] = _weibull_clock_coeff(w_sys)
        cols[2] = w_rand.k
    elif kind == "bathtub":
        bt = _build_distribution(params, params.random_failure_rate)
        cols[0] = bt.infant_factor
        cols[1] = bt.infant_tau
        cols[2] = bt.wear_start
        cols[3] = bt.wear_tau
        cols[4] = BATHTUB_WINDOW_FRACTION * min(bt.infant_tau, bt.wear_tau)
    elif kind == "lognormal":
        ln_rand = _build_distribution(params, params.random_failure_rate)
        ln_sys = _build_distribution(params, params.systematic_failure_rate)
        cols[0] = ln_rand.scale
        cols[1] = ln_sys.scale
        cols[2] = ln_rand.sigma
        cols[3] = _lognormal_mode_rel(ln_rand.sigma)
        scales = [s for s in (ln_rand.scale, ln_sys.scale) if s > 0.0]
        if scales:
            cols[4] = LOGNORMAL_WINDOW_FRACTION * cols[3] * min(scales)
    return cols


def repair_columns(params: Params) -> np.ndarray:
    """Per-point repair parameter columns (traced inputs), host-side.

    Shape ``(N_REPAIR_COLS,)`` float32; see :data:`N_REPAIR_COLS`.
    Read off the exact distribution objects the event engine's
    :class:`repro.core.repair.RepairShop` samples from
    (:func:`repro.core.repair.repair_distributions`) — the engines
    cannot drift apart on the mean parameterization.
    """
    kind = repair_kind(params)
    cols = np.zeros(N_REPAIR_COLS, np.float32)
    if kind in (None, "exponential"):
        return cols
    auto, man = _build_repair_distributions(params)
    if kind == "empirical":
        return _pair_segment_columns(auto, man, repair_segment_count(params))
    if kind == "weibull":
        cols[0], cols[1], cols[2] = auto.lam, man.lam, auto.k
    elif kind == "lognormal":
        cols[0], cols[1], cols[2] = auto.scale, man.scale, auto.sigma
    elif kind == "deterministic":
        cols[0], cols[1] = auto.value, man.value
    return cols


def effective_event_rate(params: Params) -> float:
    """Cluster failure-event rate estimate for step budgeting (host-side).

    Because every failure clock restarts at each compute-phase start,
    the phase age rarely leaves the early part of the hazard curve when
    phases are short — so the *age-zero-ish* hazard, not the long-run
    mean rate, governs how many events a job generates:

    * weibull — the exact mean phase length is
      ``Gamma(1 + 1/k) * C**(-1/k)`` (the min of the fleet's clocks is
      itself Weibull); the budget uses its reciprocal.
    * bathtub — the hazard at age zero is ``infant_factor`` times the
      flat rate; the mean-rate estimate scales accordingly (an upper
      bound, which is the safe direction for a step budget).
    * lognormal — thinning *candidates*, not just accepted failures,
      consume scan steps, and candidates arrive at up to the majorant
      rate; the peak hazard ``h(t_mode)`` bounds the majorant, so the
      budget uses the fleet-summed peak hazard (an upper bound again).
    * empirical — same majorant-rate argument with an exact bound: the
      majorant never exceeds the largest segment rate, so the budget
      uses the fleet-summed *peak segment rate* per clock.
    * exponential — the paper's ``expected_failures_per_minute``.
    """
    kind = hazard_kind(params)
    lam = params.expected_failures_per_minute()
    n_bad = params.systematic_failure_fraction * params.job_size
    if kind == "weibull":
        cols = hazard_columns(params)
        c_rand, c_sys, k = float(cols[0]), float(cols[1]), float(cols[2])
        C = params.job_size * c_rand + n_bad * c_sys
        if C <= 0.0:
            return 0.0
        mean_phase = math.gamma(1.0 + 1.0 / k) * C ** (-1.0 / k)
        return 1.0 / max(mean_phase, 1e-12)
    if kind == "bathtub":
        return lam * float(hazard_columns(params)[0])   # g(0) ~ infant_factor
    if kind == "lognormal":
        cols = hazard_columns(params)
        sigma = float(cols[2])
        h_rand = _lognormal_peak_hazard(float(cols[0]), sigma)
        h_sys = _lognormal_peak_hazard(float(cols[1]), sigma)
        return params.job_size * h_rand + n_bad * h_sys
    if kind == "empirical":
        cols = hazard_columns(params)
        m = hazard_segment_count(params)
        peak_rand = float(cols[m - 1:2 * m - 1].max())
        peak_sys = float(cols[3 * m - 2:].max())
        return params.job_size * peak_rand + n_bad * peak_sys
    return lam


def phantom_steps(params: Params) -> int:
    """Extra scan steps budgeted for thinning phantoms (host-side).

    The thinning families (bathtub, lognormal) fire a window-expiry
    phantom at most every ``W`` compute minutes; rejected candidates
    are already covered by :func:`effective_event_rate`'s majorant-rate
    estimate.  The empirical family's only phantoms are segment-edge
    re-anchors: each compute phase crosses each edge below its length
    at most once per clock, so the budget is (edges below the horizon)
    × (nominal phase count) — an over-count, which is the safe
    direction.  Weibull inversion is phantom-free.
    """
    kind = hazard_kind(params)
    if kind == "empirical":
        cols = hazard_columns(params)
        m = hazard_segment_count(params)
        edges = np.concatenate([cols[:m - 1], cols[2 * m - 1:3 * m - 3]])
        n_edges = int((edges < params.job_length).sum())
        phases = 1 + int(params.expected_failures_per_minute()
                         * params.job_length)
        return n_edges * phases
    if kind not in ("bathtub", "lognormal"):
        return 0
    cols = hazard_columns(params)
    window = float(cols[4])
    if window <= 0.0:
        return 0
    return int(params.job_length / window) + 1


def expected_repair_occupancy(params: Params) -> float:
    """Mean number of servers in the repair shop (Little's law).

    Entry rate = diagnosed failures; time in shop = automated stage plus
    the escalated manual stage.  Used to auto-size the vectorized
    engine's repair-slot lane (:func:`repro.core.vectorized` sizes the
    lane several standard deviations above this).

    The entry rate is an *accepted-failure* rate estimate, not the
    thinning candidate rate: for lognormal hazards
    :func:`effective_event_rate` deliberately over-budgets with the
    peak-hazard (majorant) rate because rejected candidates consume
    scan steps — but they never enter the shop, and sizing the slot
    lane off that bound doubles the lane's per-step cost for nothing.
    The nominal mean rate used instead is an estimate, NOT a bound:
    restart-reset phases whose length lands in the rising part of the
    hazard can realize an average rate moderately above 1/mean (~20%
    at sigma=1).  That gap is absorbed by the caller's sizing margin
    (2x the occupancy plus 8 sigma — see
    :func:`repro.core.vectorized._repair_slots_for`), and a genuinely
    undersized lane is surfaced, not silent (``n_repair_overflow`` +
    RuntimeWarning).  The empirical family budgets with its peak
    segment rate for the same reason and gets the same nominal-rate
    treatment here.  Weibull/bathtub keep the age-zero-ish estimate,
    which for them upper-bounds the accepted-failure rate.
    """
    if hazard_kind(params) in ("lognormal", "empirical"):
        rate = params.expected_failures_per_minute()
    else:
        rate = effective_event_rate(params)
    mean_shop = (params.auto_repair_time
                 + (1.0 - params.automated_repair_probability)
                 * params.manual_repair_time)
    return rate * params.diagnosis_probability * mean_shop


# ---------------------------------------------------------------------------
# JAX-side hazard math (used inside the compiled scan step)
# ---------------------------------------------------------------------------

def bathtub_shape(t, infant_factor, infant_tau, wear_start, wear_tau):
    """Dimensionless bathtub hazard shape ``g(t) = h(t) / h_flat``.

    Mirrors :meth:`repro.core.bathtub.Bathtub.hazard` exactly:
    ``g(t) = 1 + (IF - 1) * exp(-t / tau_i) + relu(t - t_w) / tau_w``.
    Convex in ``t``, and ``g >= 1`` everywhere (``IF >= 1`` is enforced
    by :func:`hazard_kind`), so endpoint majorants and acceptance
    probabilities are both well-defined.
    """
    g = 1.0 + (infant_factor - 1.0) * jnp.exp(-t / infant_tau)
    return g + jnp.maximum(t - wear_start, 0.0) / wear_tau


def weibull_conditional_ttf(age, C, k, exp_draw):
    """Exact time-to-first-failure from phase age ``age``.

    ``C`` is the summed ``lam**-k`` over all active clocks (zero when no
    clock can fire), ``k`` the shared shape, ``exp_draw`` an Exp(1)
    variate.  Returns +inf where ``C <= 0``.  Solves
    ``C * ((age + s)**k - age**k) = E`` for ``s``.

    Arithmetic runs in the dtype of ``age`` — the ``Params.age_dtype``
    carve-out promotes the age lane to float64 to kill the large-age
    cancellation of ``(a**k + E/C)**(1/k) - a`` (see docs) — and the
    result is cast back to float32 for the event race.
    """
    age = jnp.asarray(age)
    C = jnp.asarray(C, age.dtype)
    exp_draw = jnp.asarray(exp_draw, age.dtype)
    safe_c = jnp.maximum(C, 1e-30)
    target = jnp.power(age, k) + exp_draw / safe_c
    s = jnp.power(target, 1.0 / k) - age
    return jnp.where(C > 0.0, jnp.maximum(s, 0.0), jnp.inf).astype(
        jnp.float32)


def lognormal_hazard(t, scale, sigma):
    """Lognormal hazard ``h(t) = f(t) / S(t)`` (JAX, numerically stable).

    ``scale = exp(mu)``; a non-positive scale marks a disabled clock and
    yields 0.  Uses ``log_ndtr`` for the survival term so the deep right
    tail (large ``z``) stays finite instead of underflowing to 0/0.
    """
    safe_scale = jnp.maximum(scale, 1e-30)
    safe_t = jnp.maximum(t, 1e-30)
    z = (jnp.log(safe_t) - jnp.log(safe_scale)) / sigma
    log_h = -0.5 * z * z - _LOG_SQRT_2PI - log_ndtr(-z) \
        - jnp.log(sigma) - jnp.log(safe_t)
    return jnp.where(scale > 0.0, jnp.exp(log_h), 0.0)


def lognormal_window_majorant(age, window, scale, sigma, mode_rel):
    """``sup h`` over ``[age, age + window]`` via the clipped mode.

    Unimodality makes the supremum the hazard at the mode when the mode
    lies inside the window and at the nearer endpoint otherwise — i.e.
    ``h(clip(scale * mode_rel, age, age + window))``.  ``mode_rel`` is
    the numerically-located unit-scale mode (:func:`_lognormal_mode_rel`)
    riding along as a traced parameter column.
    """
    t_star = jnp.clip(scale * mode_rel, age, age + window)
    return lognormal_hazard(t_star, scale, sigma)


def _segment_take(values, idx):
    """``values[..., m]`` gathered at per-replica segment index ``idx``.

    Broadcasts a shared 1-D row across a batched index (the single-point
    path traces un-batched columns, the sweep path per-replica rows).
    """
    values = jnp.asarray(values)
    idx = jnp.asarray(idx)
    if values.ndim == idx.ndim + 1:
        return jnp.take_along_axis(values, idx[..., None], axis=-1)[..., 0]
    return values[idx]


def piecewise_hazard(t, edges, rates):
    """``h(t)`` for a piecewise-constant hazard (JAX, shape-polymorphic).

    ``edges`` are the ``m - 1`` interior breakpoints (first segment
    starts at 0, last extends to infinity), ``rates`` the ``m`` segment
    rates; either may be a shared row or per-replica.
    """
    t = jnp.asarray(t)
    idx = jnp.sum(t[..., None] >= edges, axis=-1)
    return _segment_take(rates, idx)


def piecewise_next_edge(t, edges):
    """Distance from ``t`` to the nearest edge strictly above it.

    +inf past the last edge.  This is the thinning window inside which
    the current segment rate IS the supremum — the empirical family's
    majorant is exact, so candidates are (up to float wobble at the
    boundary) always accepted.
    """
    t = jnp.asarray(t)
    gap = jnp.where(edges > t[..., None], edges - t[..., None], jnp.inf)
    return jnp.min(gap, axis=-1)


def piecewise_window_majorant(age, window, edges, rates):
    """``sup h`` over ``[age, age + window)`` — max intersecting rate.

    Exact for every window (each segment's supremum is its own rate);
    with ``window = piecewise_next_edge(age, edges)`` it reduces to the
    current rate.  The window end is exclusive so a window that lands
    exactly on the next edge does not drag in the next segment's rate.
    """
    age = jnp.asarray(age)
    e = jnp.asarray(edges)
    b = age + window
    lo = jnp.concatenate([jnp.zeros_like(e[..., :1]), e], axis=-1)
    hi = jnp.concatenate([e, jnp.full_like(e[..., :1], jnp.inf)], axis=-1)
    mask = (lo < b[..., None]) & (hi > age[..., None])
    return jnp.max(jnp.where(mask, rates, 0.0), axis=-1)


def piecewise_conditional_residual(age, edges, rates, exp_draw):
    """Exact time-to-event from ``age`` given survival (segment inversion).

    Solves ``H(age + s) - H(age) = E`` in closed form: locate the
    segment where the cumulative hazard crosses the target, then invert
    linearly inside it.  Returns +inf when the total hazard is
    exhausted first (a zero-rate tail — not fast-path eligible for
    fitted hazards, but the math stays well-defined for padding and
    disabled clocks).
    """
    age = jnp.asarray(age)
    e = jnp.asarray(edges)
    r = jnp.asarray(rates)
    zero = jnp.zeros_like(e[..., :1])
    lo = jnp.concatenate([zero, e], axis=-1)
    hi = jnp.concatenate([e, jnp.full_like(e[..., :1], jnp.inf)], axis=-1)
    width = hi - lo
    seg_h = jnp.where(r > 0.0, r * width, 0.0)       # keeps 0 * inf at 0
    cs = jnp.cumsum(seg_h, axis=-1)
    c_prev = jnp.concatenate([jnp.zeros_like(cs[..., :1]), cs[..., :-1]],
                             axis=-1)
    h_age = jnp.sum(
        jnp.broadcast_to(r, c_prev.shape)
        * jnp.clip(age[..., None] - lo, 0.0, width), axis=-1)
    target = h_age + exp_draw
    idx = jnp.sum(cs <= target[..., None], axis=-1)
    m = r.shape[-1]
    idx_c = jnp.clip(idx, 0, m - 1)
    r_j = _segment_take(jnp.broadcast_to(r, c_prev.shape), idx_c)
    lo_j = _segment_take(lo, idx_c)
    cp_j = _segment_take(c_prev, idx_c)
    t_star = lo_j + (target - cp_j) / jnp.maximum(r_j, 1e-30)
    s = jnp.maximum(t_star - age, 0.0)
    return jnp.where(idx >= m, jnp.inf, s)


# ---------------------------------------------------------------------------
# HazardSampler interface
# ---------------------------------------------------------------------------

class HazardSampler:
    """Family-specific sampling primitives for the compiled races.

    One instance per distribution family; stateless.  The failure race
    consumes ``conditional_residual`` (inversion families) or
    ``majorant`` + ``hazard`` (thinning families); the repair race
    consumes ``quantile``.  A family may implement any subset — the
    registries below declare which race accepts which family, and
    :func:`repro.core.vectorized.supports` dispatches on those.

    The repair-race method is genuinely polymorphic (one signature, the
    scan indexes ``REPAIR_SAMPLERS[rkind]`` dynamically).  The
    failure-race methods take a family-specific ``cols`` tuple —
    documented on each concrete sampler — because the families need
    different parameter sets and the scan's per-family branches are
    static compile switches anyway; a single positional convention
    would only relabel the parameters, not remove the branches.

    All methods take broadcastable JAX arrays; parameter columns arrive
    pre-sliced from the traced parameter matrix, so every method is
    shape-polymorphic over scalar-vs-per-replica parameters.
    """

    kind: str = "base"

    # -- inversion families (failure race) --------------------------------
    def conditional_residual(self, age, coeff, shape, exp_draw):
        """Exact time-to-event from ``age`` given survival (Exp(1) draw)."""
        raise NotImplementedError(self.kind)

    # -- thinning families (failure race) ---------------------------------
    def hazard(self, t, cols):
        """Exact hazard at ``t`` (the Ogata acceptance numerator)."""
        raise NotImplementedError(self.kind)

    def majorant(self, age, window, cols):
        """Valid upper bound of the hazard over ``[age, age+window]``."""
        raise NotImplementedError(self.kind)

    # -- repair race -------------------------------------------------------
    def quantile(self, u, scale, shape):
        """Exact inverse CDF — duration sampling at repair entry.

        ``scale`` is the per-stage scale column (0 marks a disabled
        stage => +inf, the event engine's infinite-mean convention);
        ``shape`` the family's shared shape column.
        """
        raise NotImplementedError(self.kind)


class WeibullSampler(HazardSampler):
    kind = "weibull"

    def conditional_residual(self, age, coeff, shape, exp_draw):
        return weibull_conditional_ttf(age, coeff, shape, exp_draw)

    def quantile(self, u, scale, shape):
        q = scale * jnp.power(-jnp.log1p(-u), 1.0 / shape)
        return jnp.where(scale > 0.0, q, jnp.inf)


class BathtubSampler(HazardSampler):
    kind = "bathtub"
    #: the bathtub hazard factors as rate * g(t): hazard/majorant return
    #: the dimensionless g and the race scales the exponential
    #: propensities by it.
    #: cols = (infant_factor, infant_tau, wear_start, wear_tau)

    def hazard(self, t, cols):
        infant_factor, infant_tau, wear_start, wear_tau = cols
        return bathtub_shape(t, infant_factor, infant_tau, wear_start,
                             wear_tau)

    def majorant(self, age, window, cols):
        # convex g => endpoint bound
        return jnp.maximum(self.hazard(age, cols),
                           self.hazard(age + window, cols))


class LognormalSampler(HazardSampler):
    kind = "lognormal"
    #: hazard cols = (scale, sigma); majorant cols = (scale, sigma,
    #: mode_rel) — the numerically pre-located unit-scale hazard mode

    def hazard(self, t, cols):
        scale, sigma = cols
        return lognormal_hazard(t, scale, sigma)

    def majorant(self, age, window, cols):
        scale, sigma, mode_rel = cols
        return lognormal_window_majorant(age, window, scale, sigma,
                                         mode_rel)

    def quantile(self, u, scale, shape):
        q = scale * jnp.exp(shape * ndtri(u))
        return jnp.where(scale > 0.0, q, jnp.inf)


class DeterministicSampler(HazardSampler):
    kind = "deterministic"

    def quantile(self, u, scale, shape):
        # a fixed duration: the inverse CDF is the constant itself
        # (value 0 is a *valid* instant repair here, mirroring the
        # event engine's Deterministic(0) => timeout(0))
        return scale * jnp.ones_like(u)


class PiecewiseConstantSampler(HazardSampler):
    kind = "empirical"
    #: failure-race cols = (edges, rates) arrays for ONE clock; the race
    #: thins the random and systematic clocks separately (exact, since
    #: thinning independent inhomogeneous Poisson processes is).  The
    #: repair race passes stage-selected (edges, rates) positionally
    #: through the ``quantile(u, scale, shape)`` slots.

    def hazard(self, t, cols):
        edges, rates = cols
        return piecewise_hazard(t, edges, rates)

    def majorant(self, age, window, cols):
        edges, rates = cols
        return piecewise_window_majorant(age, window, edges, rates)

    def conditional_residual(self, age, edges, rates, exp_draw):
        return piecewise_conditional_residual(age, edges, rates, exp_draw)

    def quantile(self, u, edges, rates):
        # closed form per segment: invert H(t) = -log1p(-u) from age 0
        return piecewise_conditional_residual(
            jnp.zeros_like(u), edges, rates, -jnp.log1p(-u))


#: failure families with fast-path sampling machinery (exponential is
#: the legacy rate-race program and needs none of it)
FAILURE_SAMPLERS = {
    "weibull": WeibullSampler(),
    "bathtub": BathtubSampler(),
    "lognormal": LognormalSampler(),
    "empirical": PiecewiseConstantSampler(),
}

#: repair families the slot lane can sample at entry
REPAIR_SAMPLERS = {
    "weibull": WeibullSampler(),
    "lognormal": LognormalSampler(),
    "deterministic": DeterministicSampler(),
    "empirical": PiecewiseConstantSampler(),
}
