"""Top-level ClusterSimulation: wires the five AIReSim modules together.

One ClusterSimulation = one replication: it builds the fleet, pools,
scheduler, repair shop, and coordinator on a fresh DES environment and
runs the job to completion, returning a :class:`RunResult`.

``simulate(params, n_replications)`` is the main entry point used by
sweeps, benchmarks, and tests.
"""

from __future__ import annotations

from typing import Generator, List, Optional

import numpy as np

from .coordinator import Coordinator
from .engine import Environment
from .metrics import RunResult
from .params import Params
from .pool import PoolManager
from .repair import RepairShop
from .scheduler import Scheduler
from .server import FailureSampler, Fleet


class ClusterSimulation:
    def __init__(self, params: Params, seed: Optional[int] = None):
        params.validate()
        self.params = params
        self.rng = np.random.default_rng(
            params.seed if seed is None else seed)
        self.env = Environment()
        self.metrics = RunResult()
        self.fleet = Fleet(params, self.rng)
        self.pools = PoolManager(params, self.fleet)
        self.scheduler = Scheduler(self.env, params, self.pools, self.metrics)
        self.repair_shop = RepairShop(
            self.env, params, self.rng, self.metrics,
            on_return=self.scheduler.on_server_return,
            on_retire=self.scheduler.on_server_retired)
        self.sampler = FailureSampler(params, self.rng)
        self.coordinator = Coordinator(
            self.env, params, self.rng, self.metrics, self.scheduler,
            self.repair_shop, self.sampler)

    # -- bad-set regeneration (assumption 1, case 2) -------------------------
    def _regeneration_process(self) -> Generator:
        period = self.params.bad_set_regeneration_period
        while True:
            yield self.env.timeout(period)
            self.fleet.regenerate_bad_set()
            self.coordinator.rebuild_running_partition()

    # -- run -----------------------------------------------------------------
    def run(self) -> RunResult:
        if self.params.bad_set_regeneration_period > 0:
            self.env.process(self._regeneration_process(), name="regen")
        job = self.env.process(self.coordinator.run_job(), name="job")
        self.env.run_until_process(job)
        self.metrics.total_time = self.env.now
        return self.metrics


def simulate(params: Params, n_replications: int = 1,
             base_seed: Optional[int] = None) -> List[RunResult]:
    """Run independent replications (distinct substreams of ``base_seed``)."""
    base = params.seed if base_seed is None else base_seed
    results = []
    for rep in range(n_replications):
        sim = ClusterSimulation(params, seed=base + 7919 * rep)
        results.append(sim.run())
    return results


def simulate_one(params: Params, seed: Optional[int] = None) -> RunResult:
    return ClusterSimulation(params, seed=seed).run()
