"""Top-level ClusterSimulation: wires the five AIReSim modules together.

One ClusterSimulation = one replication: it builds the fleet, pools,
scheduler, repair shop, and coordinator on a fresh DES environment and
runs the job to completion, returning a :class:`RunResult`.

``simulate(params, n_replications)`` is the main entry point used by
sweeps, benchmarks, and tests.
"""

from __future__ import annotations

from typing import Generator, List, Optional

import numpy as np

from .coordinator import Coordinator
from .engine import Environment
from .faultdomains import ShockInjector
from .metrics import RunResult
from .params import Params
from .pool import PoolManager
from .repair import RepairShop
from .scheduler import Scheduler
from .server import FailureSampler, Fleet


class ClusterSimulation:
    def __init__(self, params: Params, seed: Optional[int] = None):
        params.validate()
        self.params = params
        self.rng = np.random.default_rng(
            params.seed if seed is None else seed)
        self.env = Environment()
        self.metrics = RunResult()
        self.fleet = Fleet(params, self.rng)
        self.pools = PoolManager(params, self.fleet)
        self.scheduler = Scheduler(self.env, params, self.pools, self.metrics)
        self.repair_shop = RepairShop(
            self.env, params, self.rng, self.metrics,
            on_return=self.scheduler.on_server_return,
            on_retire=self.scheduler.on_server_retired)
        self.sampler = FailureSampler(params, self.rng)
        self.coordinator = Coordinator(
            self.env, params, self.rng, self.metrics, self.scheduler,
            self.repair_shop, self.sampler)
        # correlated failure domains / scripted campaigns (faultdomains):
        # one merged injection stream the coordinator races against
        # compute.  Zero shock rates and an empty campaign draw nothing
        # from the RNG, keeping plain runs bit-identical.
        self.injector = None
        if params.fault_domains is not None or params.campaign is not None:
            total = params.working_pool_size + params.spare_pool_size
            self.injector = ShockInjector(
                params.fault_domains, params.campaign, total, self.rng)
            self.coordinator.injector = self.injector
            # scenario return semantics: repaired servers backfill the
            # job's standbys regardless of membership (matches the CTMC
            # return lane, which carries no membership information)
            self.scheduler.standby_refill_any = True
            if params.fault_domains is not None:
                self.metrics.domain_shocks = (
                    [0] * params.fault_domains.n_domains)

    # -- bad-set regeneration (assumption 1, case 2) -------------------------
    def _regeneration_process(self) -> Generator:
        period = self.params.bad_set_regeneration_period
        while True:
            yield self.env.timeout(period)
            self.fleet.regenerate_bad_set()
            self.coordinator.rebuild_running_partition()

    # -- run -----------------------------------------------------------------
    def run(self) -> RunResult:
        if self.params.bad_set_regeneration_period > 0:
            self.env.process(self._regeneration_process(), name="regen")
        if self.injector is not None:
            # created before the job so a same-instant tie resolves
            # injection-first (the CTMC campaign-residual tie-break)
            self.env.process(self.coordinator.injection_loop(),
                             name="injector")
        job = self.env.process(self.coordinator.run_job(), name="job")
        self.coordinator._job_proc = job
        self.env.run_until_process(job)
        self.metrics.total_time = self.env.now
        return self.metrics


def simulate(params: Params, n_replications: int = 1,
             base_seed: Optional[int] = None) -> List[RunResult]:
    """Run independent replications (distinct substreams of ``base_seed``)."""
    base = params.seed if base_seed is None else base_seed
    results = []
    for rep in range(n_replications):
        sim = ClusterSimulation(params, seed=base + 7919 * rep)
        results.append(sim.run())
    return results


def simulate_one(params: Params, seed: Optional[int] = None) -> RunResult:
    return ClusterSimulation(params, seed=seed).run()
