"""Generator-coroutine discrete event simulation engine.

The paper implements AIReSim on SimPy; SimPy is not available in this
environment, so this module provides an API-compatible subset built from
scratch (Environment / Process / Timeout / Event / Interrupt / conditions).
It is deliberately small and allocation-light: the event heap stores
``(time, priority, eid, event)`` tuples and processes are plain generators.

Semantics mirror SimPy 4:
  * ``env.process(gen)`` turns a generator into a schedulable Process.
  * Processes ``yield`` events; they resume when the event triggers.
  * ``proc.interrupt(cause)`` throws :class:`Interrupt` into the generator
    at the current simulation time (deregistering the pending wait).
  * Events may ``succeed(value)`` or ``fail(exc)`` exactly once.
"""

from __future__ import annotations

import heapq
from typing import Any, Callable, Generator, Iterable, List, Optional

# Scheduling priorities (lower runs first at equal timestamps).
URGENT = 0
NORMAL = 1


class Interrupt(Exception):
    """Thrown into a process by :meth:`Process.interrupt`."""

    def __init__(self, cause: Any = None):
        super().__init__(cause)
        self.cause = cause


class StopSimulation(Exception):
    """Raised internally to end :meth:`Environment.run`."""


class Event:
    """A one-shot occurrence processes can wait on."""

    __slots__ = ("env", "callbacks", "_value", "_ok", "_scheduled", "_defused")

    def __init__(self, env: "Environment"):
        self.env = env
        self.callbacks: Optional[List[Callable[["Event"], None]]] = []
        self._value: Any = None
        self._ok: Optional[bool] = None  # None = untriggered
        self._scheduled = False
        self._defused = False

    # -- state ------------------------------------------------------------
    @property
    def triggered(self) -> bool:
        return self._ok is not None

    @property
    def processed(self) -> bool:
        return self.callbacks is None

    @property
    def ok(self) -> bool:
        return bool(self._ok)

    @property
    def value(self) -> Any:
        return self._value

    # -- triggering -------------------------------------------------------
    def succeed(self, value: Any = None) -> "Event":
        if self._ok is not None:
            raise RuntimeError(f"{self!r} already triggered")
        self._ok = True
        self._value = value
        self.env._schedule(self, NORMAL, 0.0)
        return self

    def fail(self, exc: BaseException) -> "Event":
        if self._ok is not None:
            raise RuntimeError(f"{self!r} already triggered")
        self._ok = False
        self._value = exc
        self.env._schedule(self, NORMAL, 0.0)
        return self

    def defuse(self) -> None:
        """Mark a failed event as handled so it doesn't crash the run."""
        self._defused = True


class Timeout(Event):
    """Event that triggers after a fixed simulated delay."""

    __slots__ = ("delay",)

    def __init__(self, env: "Environment", delay: float, value: Any = None):
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        super().__init__(env)
        self.delay = delay
        self._ok = True
        self._value = value
        env._schedule(self, NORMAL, delay)


class Initialize(Event):
    """Internal: schedules the first resumption of a new process."""

    __slots__ = ()

    def __init__(self, env: "Environment", process: "Process"):
        super().__init__(env)
        self.callbacks.append(process._resume)
        self._ok = True
        env._schedule(self, URGENT, 0.0)


class Process(Event):
    """Wraps a generator; itself an event that triggers on completion."""

    __slots__ = ("_generator", "_target", "name")

    def __init__(self, env: "Environment", generator: Generator, name: str = ""):
        super().__init__(env)
        self._generator = generator
        self._target: Optional[Event] = None
        self.name = name or getattr(generator, "__name__", "process")
        Initialize(env, self)

    @property
    def is_alive(self) -> bool:
        return self._ok is None

    def interrupt(self, cause: Any = None) -> None:
        if self._ok is not None:
            return  # already finished; interrupt is a no-op
        # Deregister from whatever it is waiting on.
        target = self._target
        if target is not None and target.callbacks is not None:
            try:
                target.callbacks.remove(self._resume)
            except ValueError:
                pass
        self._target = None
        # Resume immediately (urgent) with an Interrupt.
        evt = Event(self.env)
        evt._ok = False
        evt._value = Interrupt(cause)
        evt._defused = True
        evt.callbacks.append(self._resume)
        self.env._schedule(evt, URGENT, 0.0)

    # -- driving ----------------------------------------------------------
    def _resume(self, event: Event) -> None:
        self.env._active_proc = self
        try:
            if event._ok:
                next_evt = self._generator.send(event._value)
            else:
                # event carries an exception (failed event or interrupt)
                next_evt = self._generator.throw(event._value)
        except StopIteration as stop:
            self._ok = True
            self._value = stop.value
            self.env._schedule(self, NORMAL, 0.0)
            self.env._active_proc = None
            return
        except BaseException as exc:  # propagate through the process event
            self._ok = False
            self._value = exc
            self._defused = False
            self.env._schedule(self, NORMAL, 0.0)
            self.env._active_proc = None
            return
        self.env._active_proc = None
        if not isinstance(next_evt, Event):
            raise RuntimeError(
                f"process {self.name} yielded non-event {next_evt!r}")
        if next_evt.callbacks is None:
            # already processed -> resume immediately via a relay event
            evt = Event(self.env)
            evt._ok = next_evt._ok
            evt._value = next_evt._value
            evt._defused = True
            evt.callbacks.append(self._resume)
            self.env._schedule(evt, URGENT, 0.0)
            self._target = evt
        else:
            next_evt.callbacks.append(self._resume)
            if next_evt._ok is False:
                next_evt._defused = True  # waiting on it handles failure
            self._target = next_evt


class Condition(Event):
    """Triggers when ``check(count_done, total)`` is satisfied."""

    __slots__ = ("_events", "_check", "_done")

    def __init__(self, env: "Environment", events: Iterable[Event],
                 check: Callable[[int, int], bool]):
        super().__init__(env)
        self._events = list(events)
        self._check = check
        self._done = 0
        if not self._events:
            self.succeed({})
            return
        for evt in self._events:
            if evt.callbacks is None:
                self._on_event(evt)
            else:
                evt.callbacks.append(self._on_event)

    def _on_event(self, evt: Event) -> None:
        if self._ok is not None:
            return
        if not evt._ok:
            evt.defuse()
            self.fail(evt._value)
            return
        self._done += 1
        if self._check(self._done, len(self._events)):
            self.succeed({e: e._value for e in self._events if e.processed})


class Environment:
    """Owner of the clock and the event heap."""

    def __init__(self, initial_time: float = 0.0):
        self._now = float(initial_time)
        self._heap: List[tuple] = []
        self._eid = 0
        self._active_proc: Optional[Process] = None
        self.event_count = 0  # processed events; used by perf benchmarks

    @property
    def now(self) -> float:
        return self._now

    @property
    def active_process(self) -> Optional[Process]:
        return self._active_proc

    # -- factories ---------------------------------------------------------
    def event(self) -> Event:
        return Event(self)

    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def process(self, generator: Generator, name: str = "") -> Process:
        return Process(self, generator, name)

    def any_of(self, events: Iterable[Event]) -> Condition:
        return Condition(self, events, lambda done, total: done >= 1)

    def all_of(self, events: Iterable[Event]) -> Condition:
        return Condition(self, events, lambda done, total: done == total)

    # -- scheduling ----------------------------------------------------------
    def _schedule(self, event: Event, priority: int, delay: float) -> None:
        if event._scheduled:
            return
        event._scheduled = True
        self._eid += 1
        heapq.heappush(self._heap, (self._now + delay, priority, self._eid, event))

    def step(self) -> None:
        when, _prio, _eid, event = heapq.heappop(self._heap)
        self._now = when
        callbacks, event.callbacks = event.callbacks, None
        self.event_count += 1
        for cb in callbacks:
            cb(event)
        if event._ok is False and not event._defused:
            raise event._value  # unhandled failure

    def run(self, until: Optional[float] = None) -> Any:
        """Run until the heap drains or simulated time reaches ``until``."""
        if until is not None:
            def _stop(_evt: Event) -> None:
                raise StopSimulation()
            stopper = Event(self)
            stopper._ok = True
            stopper.callbacks.append(_stop)
            self._schedule(stopper, URGENT, max(0.0, until - self._now))
        try:
            while self._heap:
                self.step()
        except StopSimulation:
            self._now = until
        return self._now

    def run_until_process(self, proc: Process) -> Any:
        """Run until ``proc`` completes; returns its value (raises its error)."""
        while self._heap and proc._ok is None:
            self.step()
        if proc._ok is None:
            raise RuntimeError(f"deadlock: {proc.name} never completed "
                               f"(heap drained at t={self._now})")
        if not proc._ok:
            raise proc._value
        return proc._value
