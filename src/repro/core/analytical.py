"""Closed-form reliability cross-checks (the paper's §I 'analytical methods').

The paper positions DES against Markov/analytical models; we keep a small
analytical layer for three purposes:

1. *Validation*: under simplifying assumptions (no pool exhaustion, no
   stalls) the expected training time has a renewal-reward closed form the
   simulator must approach — used by tests.
2. *Checkpoint cadence* (Young/Daly): the training substrate picks its
   checkpoint interval from the same failure rates the DES sweeps, closing
   the sim-to-system loop.
3. *Napkin math for sweeps*: expected failures, repair-shop occupancy
   (M/G/infinity), and spare-capacity sizing bounds used to sanity-check
   sweep outputs before trusting them.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .params import Params


def cluster_failure_rate(params: Params) -> float:
    """Mean failure rate (per minute) of the executing fleet at t=0."""
    return params.expected_failures_per_minute()


def expected_total_time(params: Params) -> float:
    """Renewal-reward estimate of E[total training time].

    Model: failures arrive at rate L while computing; each failure costs
    ``recovery_time`` (ignores host-selection, preemption, stalls, and the
    depletion of bad servers via repair — an *optimistic lower bound* that
    tightens as pools stay unexhausted; tests assert the simulator is
    slower than this bound minus CI but in its vicinity for the default
    over-provisioned configuration).

        E[T] ~= job_length * (1 + L * recovery_overhead_per_failure)

    With checkpoint rollback (``checkpoint_interval`` = tau > 0) each
    failure additionally re-computes the work lost since the last
    durable checkpoint, and every tau of banked compute pays one
    ``checkpoint_cost`` write.  For exponential inter-failure times with
    the failure clock restarting at every restart, banking one tau
    segment is a geometric renewal: an attempt succeeds with
    p = e^(-L*tau) and costs E[min(X, tau)] = (1 - e^(-L*tau))/L of
    compute, so

        E[compute] = job_length * (e^(L*tau) - 1) / (L * tau)

    exactly (equivalently job_length + n_fail * E[loss] with the
    truncated-exponential mean E[loss] = 1/L - tau/(e^(L*tau) - 1) ->
    tau/2 as L*tau -> 0, the Young/Daly regime).  Writes number
    ~job_length/tau.  The bound stays optimistic (no stalls, pools,
    host-selection) exactly as in the rollback-free case.
    """
    lam = cluster_failure_rate(params)
    per_failure = params.recovery_time
    tau = params.checkpoint_interval
    if lam <= 0 or tau <= 0:
        return params.job_length * (1.0 + lam * per_failure)
    x = lam * tau
    # truncated-exponential mean, numerically stable for small x via
    # expm1 (naive 1 - e^-x cancels below x ~ 1e-8)
    e_loss = 1.0 / lam - tau * math.exp(-x) / (-math.expm1(-x))
    # mean compute minutes per banked minute: a segment reaches the next
    # write with prob e^-x, and every attempt costs an expected
    # min(X, tau) = (1 - e^-x)/L minutes of compute
    compute = params.job_length * (-math.expm1(-x) / lam) / (
        tau * math.exp(-x))
    n_fail = lam * compute
    writes = params.job_length / tau
    return (compute + writes * params.checkpoint_cost
            + n_fail * per_failure)


def expected_failures(params: Params) -> float:
    """E[#failures] over the job under the optimistic model above."""
    return cluster_failure_rate(params) * params.job_length


def repair_shop_occupancy(params: Params) -> float:
    """M/G/infinity steady-state mean servers simultaneously in repair.

    Little's law: N = lambda * E[repair duration], with the repair duration
    mixing automated and escalated-manual paths.
    """
    lam = cluster_failure_rate(params) * params.diagnosis_probability
    p_auto = params.automated_repair_probability
    mean_repair = (params.auto_repair_time
                   + (1.0 - p_auto) * params.manual_repair_time)
    return lam * mean_repair


def spare_capacity_bound(params: Params, quantile_z: float = 2.33) -> float:
    """Poisson upper bound (z~2.33 -> ~99%) on servers out for repair.

    A working-pool headroom above this bound makes stalls rare — the
    analytical counterpart of the paper's capacity-planning case study.
    """
    occ = repair_shop_occupancy(params)
    return occ + quantile_z * math.sqrt(max(occ, 1e-12))


# ---------------------------------------------------------------------------
# Young/Daly checkpoint cadence — used by train/loop.py
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CheckpointPlan:
    interval_minutes: float       # optimal checkpoint interval
    mtbf_minutes: float           # cluster-level MTBF feeding the formula
    checkpoint_cost_minutes: float
    expected_overhead_fraction: float


def young_daly_interval(checkpoint_cost_minutes: float,
                        mtbf_minutes: float) -> float:
    """First-order optimum tau = sqrt(2 * C * MTBF) (Young 1974 / Daly 2006)."""
    if mtbf_minutes <= 0 or math.isinf(mtbf_minutes):
        return math.inf
    return math.sqrt(2.0 * checkpoint_cost_minutes * mtbf_minutes)


def plan_checkpoints(params: Params,
                     checkpoint_cost_minutes: float) -> CheckpointPlan:
    lam = cluster_failure_rate(params)
    mtbf = math.inf if lam <= 0 else 1.0 / lam
    tau = young_daly_interval(checkpoint_cost_minutes, mtbf)
    if math.isinf(tau):
        overhead = 0.0
    else:
        # overhead ~ C/tau (write cost) + tau/(2*MTBF) (expected rollback)
        overhead = checkpoint_cost_minutes / tau + tau / (2.0 * mtbf)
    return CheckpointPlan(interval_minutes=tau, mtbf_minutes=mtbf,
                          checkpoint_cost_minutes=checkpoint_cost_minutes,
                          expected_overhead_fraction=overhead)
