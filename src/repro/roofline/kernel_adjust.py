"""Analytic TPU-kernelized roofline: what the Pallas kernels change.

The dry-run compiles the *reference* (pure-jnp) attention and selective
scan — XLA materializes score matrices and per-step scan tensors in HBM,
which dominates the measured memory term.  On TPU the Pallas kernels
(kernels/flash_attention.py, kernels/mamba_scan.py) keep those internals
in VMEM; this module computes the memory/compute terms with the kernel's
true HBM traffic substituted, giving the optimized §Perf numbers that the
interpret-mode-validated kernels justify.

All formulas are per-device per step, documented inline.  The collective
term is unchanged by kernelization (taken from the measured baseline).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

from .analysis import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline, model_flops


def _layer_counts(cfg: ModelConfig):
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    n_ssm = cfg.n_layers - n_attn
    n_cross = sum(1 for i in range(cfg.n_layers)
                  if cfg.layer_has_cross_attn(i))
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
    return n_attn, n_ssm, n_cross, n_moe


def kernelized_memory_bytes(cfg: ModelConfig, shape: ShapeSpec,
                            n_chips: int, train: bool) -> float:
    """Per-device HBM bytes with Pallas-kernel attention/scan traffic.

    Accounting (bf16 activations/params, fp32 optimizer):
      * params: read once fwd (+ once bwd re-gather under FSDP) and the
        optimizer update reads/writes p/m/v — training charges
        params*(2 reads + grad write + 3*opt rw) ~ params_bytes * 8;
        inference charges one read of active params.
      * per layer, the residual stream + mixer/MLP activations stream
        through HBM a small constant number of times: c_act ~ 12 tensors
        of (B, S, D) bf16 fwd (+~2x bwd with remat recompute).
      * flash attention: reads q,k,v + writes o once — no (S,S) traffic.
      * mamba kernel: streams x, dt, z, B, C, y once; h stays in VMEM.
      * MoE: dispatch buffer (E*C_local..) read/write ~3x per matmul set.
    """
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    n_attn, n_ssm, n_cross, n_moe = _layer_counts(cfg)
    bpe = 2.0  # bf16

    # tokens resident on this device (batch and sequence sharded: batch
    # over fsdp(16 or 32), seq over tp for boundary storage; streamed
    # activations are per-device work tokens)
    tok_dev = B * S / n_chips if shape.kind == "train" else B * S / n_chips
    if shape.kind == "decode":
        tok_dev = B * 1.0 / min(B, n_chips)

    act_stream = 12.0 * tok_dev * D * bpe          # per dense layer fwd
    if train:
        act_stream *= 3.0                          # bwd + remat recompute

    # attention kernel traffic: q,k,v,o once (+dq,dk,dv,do bwd)
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, max(cfg.n_kv_heads, 1)
    attn_io = tok_dev * hd * (2 * Hq + 2 * Hkv) * bpe
    if shape.kind == "decode":
        # decode reads the KV cache once per step
        attn_io = (B * S * Hkv * hd * 2 * bpe) / n_chips + tok_dev * Hq * hd * bpe
    if train:
        attn_io *= 3.0

    # mamba kernel traffic: x, dt, z, y (di) + B, C (N) streams
    di, N = cfg.d_inner, max(cfg.ssm_state, 1)
    ssm_io = tok_dev * (4 * di + 2 * N) * bpe
    if train:
        ssm_io *= 3.0

    # MoE buffer traffic: top_k token copies in/out of the expert buffers
    moe_io = 0.0
    if cfg.n_experts:
        moe_io = 6.0 * tok_dev * cfg.top_k * D * bpe * cfg.capacity_factor
        if train:
            moe_io *= 3.0

    layer_bytes = (n_attn * (act_stream + attn_io)
                   + n_ssm * (act_stream * 0.8 + ssm_io)
                   + n_cross * attn_io
                   + n_moe * moe_io)

    # parameter traffic
    p_active = cfg.active_param_count()
    p_total = cfg.param_count()
    if train:
        param_bytes = (p_total * bpe * 2          # fwd + bwd weight reads
                       + p_total * bpe            # grad write
                       + p_total * 3 * 4          # adam p/m/v read+write fp32-ish
                       ) / n_chips
    else:
        param_bytes = p_active * bpe / n_chips

    # logits/CE traffic (vocab-sharded)
    head_bytes = tok_dev * (cfg.vocab_size / max(n_chips ** 0.5, 1)) * bpe \
        if shape.kind == "train" else 0.0

    return layer_bytes + param_bytes + head_bytes


def kernelized_roofline(base: Roofline, cfg: ModelConfig, shape: ShapeSpec,
                        ) -> Dict[str, float]:
    """The §Perf 'kernelized' variant of a measured baseline cell."""
    train = shape.kind == "train"
    mem_bytes = kernelized_memory_bytes(cfg, shape, base.n_chips, train)
    # compute term: the model math + flash recompute factor (~1.15 for
    # remat of dots under the 'nothing' policy is already inside
    # hlo_flops; kernelization does not change required FLOPs, it removes
    # masked/wasted score work -> use model flops + 20% engineering slack)
    mf_dev = model_flops(cfg, shape) / base.n_chips
    compute_s = 1.2 * mf_dev / PEAK_FLOPS if train else mf_dev / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = base.collective_s  # unchanged by kernelization
    bound = max(compute_s, memory_s, collective_s)
    useful_s = (model_flops(cfg, shape) / base.n_chips) / PEAK_FLOPS
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}.items(), key=lambda kv: kv[1])[0],
        "step_time_bound_s": bound,
        "roofline_fraction": useful_s / bound if bound else 0.0,
        "memory_bytes_per_dev": mem_bytes,
    }
