"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOPs)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link (3 links/chip; we count the per-link figure, i.e. the
bottleneck link of a ring collective).

Sources:
  * ``compiled.cost_analysis()`` for HLO FLOPs / bytes.  XLA:CPU's cost
    model does NOT multiply while-loop bodies by their trip counts, so we
    also parse the optimized HLO: collective/FLOP-bearing ops inside a
    while body whose condition bounds the induction variable by a constant
    are scaled by that constant (scan-over-superblocks, CE chunks, ...).
  * collective bytes from the optimized HLO text — result-shape bytes of
    every all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute, x2 for ring all-reduce, scaled by while trip
    counts.
  * MODEL_FLOPS analytically (6*N_active*tokens for training), giving the
    useful-compute ratio that catches remat/dispatch waste.
"""

from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---- TPU v5e constants ------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_PER_CHIP = 16e9          # v5e HBM capacity

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128,512]{2,1,0}' -> byte size (0 for tuples/tokens)."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: int, repeats: int = 1) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) \
            + nbytes * repeats
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + repeats


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None and stripped:
            comps[current].append(stripped)
    return comps


def _result_shapes(line: str) -> List[str]:
    """Shape strings of an instruction's result (tuple-aware)."""
    m = re.match(r"\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.*?)\s*[\w\-]+\(", line)
    if not m:
        return []
    return re.findall(r"[a-z0-9]+\[[\d,]*\]", m.group(1))


def _shape_table(comps: Dict[str, List[str]]) -> Dict[str, List[str]]:
    """instruction name -> result shape strings (across all computations)."""
    table: Dict[str, List[str]] = {}
    for lines in comps.values():
        for line in lines:
            nm = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=", line)
            if nm:
                table[nm.group(1)] = _result_shapes(line)
    return table


def _while_trip_counts(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """while body computation name -> static trip count (best effort).

    jax scans lower to while loops whose condition compares the induction
    variable with a constant; we extract that constant from the condition
    computation.
    """
    trip: Dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line or line.startswith("while("):
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if not (mb and mc):
                    continue
                body, cond = mb.group(1), mc.group(1)
                count = None
                for cl in comps.get(cond, []):
                    m = re.search(r"constant\((\d+)\)", cl)
                    if m:
                        c = int(m.group(1))
                        if count is None or c > count:
                            count = c
                if count:
                    trip[body] = count
    return trip


def _callers_of(comps: Dict[str, List[str]]) -> Dict[str, List[str]]:
    """computation -> computations it invokes via calls/fusion/while."""
    out: Dict[str, List[str]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(
                    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)", line):
                callee = m.group(1)
                if callee in comps:
                    out[name].append(callee)
    return out


def _multipliers(comps: Dict[str, List[str]],
                 trip: Dict[str, int]) -> Dict[str, int]:
    """Effective execution multiplier of each computation (nested whiles)."""
    callers = _callers_of(comps)
    mult: Dict[str, int] = {}
    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))

    def visit(name: str, factor: int, seen) -> None:
        if name in seen:
            return
        seen = seen | {name}
        mult[name] = max(mult.get(name, 0), factor)
        for callee in callers.get(name, []):
            f = factor * trip.get(callee, 1)
            visit(callee, f, seen)

    if entry is not None:
        visit(entry, 1, frozenset())
    # unreachable comps default to 1x
    for name in comps:
        mult.setdefault(name, 1)
    return mult


_COLLECTIVE_RE = re.compile(
    r"=\s*[^=]*?\s(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective link bytes, scaling by while trip counts.

    Convention (bytes crossing the bottleneck link per device, ring
    algorithms over a group of size g):
      all-gather:         result_bytes * (g-1)/g
      reduce-scatter:     result_bytes * (g-1)        (operand = result*g)
      all-reduce:         2 * result_bytes * (g-1)/g
      all-to-all:         result_bytes * (g-1)/g
      collective-permute: result_bytes
    """
    comps = _split_computations(hlo_text)
    trips = _while_trip_counts(comps)
    mults = _multipliers(comps, trips)
    stats = CollectiveStats()
    for name, lines in comps.items():
        factor = mults.get(name, 1)
        for line in lines:
            m = _COLLECTIVE_RE.search(line)
            if not m:
                continue
            kind = m.group(1)
            nbytes = sum(_shape_bytes(s) for s in _result_shapes(line))
            g = 2
            gm = _GROUP_RE.search(line)
            if gm:
                g = max(int(gm.group(2)), 1)
            if kind == "all-gather":
                eff = nbytes * (g - 1) / g
            elif kind == "reduce-scatter":
                eff = nbytes * (g - 1)
            elif kind == "all-reduce":
                eff = 2 * nbytes * (g - 1) / g
            elif kind == "all-to-all":
                eff = nbytes * (g - 1) / g
            else:  # collective-permute
                eff = nbytes
            stats.add(kind, int(eff), factor)
    return stats


_DOT_RE = re.compile(r"=\s*[^=]*?\sdot\(([^)]*)\)")
_LHS_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]+)\}")


def hlo_flops_and_bytes(hlo_text: str,
                        cost_analysis: Optional[Dict[str, float]] = None,
                        ) -> Tuple[float, float]:
    """Per-device (FLOPs, HBM bytes), while-loop trip counts applied.

    XLA:CPU cost analysis reports while bodies once; we parse every dot op,
    look up operand shapes, compute 2*|out|*k FLOPs, and scale by the
    enclosing while trip counts.  HBM bytes are cost_analysis['bytes
    accessed'] rescaled by the same loop factor (flops_scaled /
    flops_unscaled) — loop bodies dominate traffic in scanned models.
    """
    comps = _split_computations(hlo_text)
    trips = _while_trip_counts(comps)
    mults = _multipliers(comps, trips)
    shapes = _shape_table(comps)

    flops_scaled = 0.0
    flops_raw = 0.0
    for name, lines in comps.items():
        factor = mults.get(name, 1)
        for line in lines:
            m = _DOT_RE.search(line)
            if not m:
                continue
            out_shapes = _result_shapes(line)
            if not out_shapes:
                continue
            dt = re.match(r"([a-z0-9]+)\[", out_shapes[0]).group(1)
            out_elems = _shape_bytes(out_shapes[0]) / max(
                _DTYPE_BYTES.get(dt, 4), 1)
            operands = re.findall(r"%([\w.\-]+)", m.group(1))
            k = 1.0
            if operands:
                lhs_shapes = shapes.get(operands[0], [])
                if lhs_shapes:
                    dims = [int(x) for x in re.match(
                        r"[a-z0-9]+\[([\d,]*)\]", lhs_shapes[0]
                    ).group(1).split(",") if x]
                    cm = _LHS_DIMS_RE.search(line)
                    if cm and dims:
                        for ci in cm.group(1).split(","):
                            ci = int(ci)
                            if ci < len(dims):
                                k *= dims[ci]
            f = 2.0 * out_elems * k
            flops_raw += f
            flops_scaled += f * factor

    # ---- HBM bytes at fusion boundaries -------------------------------
    # Count result + operand bytes of every top-level instruction (entry +
    # while bodies), scaled by trip counts.  Computations referenced via
    # calls=/to_apply= are fusion internals — their traffic happens in
    # registers/VMEM, not HBM, so they are excluded (matching the
    # semantics of XLA's "bytes accessed").
    fusion_bodies = set()
    for lines in comps.values():
        for line in lines:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", line):
                fusion_bodies.add(m.group(1))

    bytes_accessed = 0.0
    for name, lines in comps.items():
        if name in fusion_bodies:
            continue
        factor = mults.get(name, 1)
        for line in lines:
            op = re.match(r"\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*[^=]*?"
                          r"\s([\w\-]+)\(", line)
            if not op:
                continue
            opname = op.group(1)
            if opname in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast", "while"):
                continue
            paren = line[line.index("(") + 1:] if "(" in line else ""
            operand_bytes = []
            for om in re.finditer(r"%([\w.\-]+)", paren):
                for s in shapes.get(om.group(1), []):
                    operand_bytes.append(_shape_bytes(s))
            result = sum(_shape_bytes(s) for s in _result_shapes(line))
            if opname == "dynamic-slice":
                # in-place view: only the slice moves
                traffic = 2 * result
            elif opname == "dynamic-update-slice" or "dynamic-update-slice" in line:
                # in-place: read update + write slice, not the buffer
                upd = min(operand_bytes) if operand_bytes else 0
                traffic = 2 * upd
            else:
                traffic = result + sum(operand_bytes)
            bytes_accessed += traffic * factor

    if cost_analysis:
        flops_scaled = max(flops_scaled,
                           float(cost_analysis.get("flops", 0.0)))
        bytes_accessed = max(bytes_accessed,
                             float(cost_analysis.get("bytes accessed", 0.0)))
    return flops_scaled, bytes_accessed


# ---------------------------------------------------------------------------
# analytic model FLOPs / bytes (the denominator of the useful-compute ratio)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (6*N*D train, 2*N*D inference)."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * n_active * tokens
        attn = 0.0
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_kind(i) == "attn")
        # causal attention: fwd 2*2*S^2/2*H*hd per example; train = 3x fwd
        attn = 3.0 * 2.0 * B * S * S * cfg.n_heads * cfg.head_dim * n_attn
        return base + attn
    if shape.kind == "prefill":
        tokens = B * S
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_kind(i) == "attn")
        attn = 2.0 * B * S * S * cfg.n_heads * cfg.head_dim * n_attn
        return 2.0 * n_active * tokens + attn
    # decode: one token per request
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    attn = 4.0 * B * S * cfg.n_heads * cfg.head_dim * n_attn
    return 2.0 * n_active * B + attn


def model_bytes(cfg, shape) -> float:
    """Analytic minimum HBM traffic (params/caches read once)."""
    p_bytes = cfg.active_param_count() * 2.0   # bf16
    if shape.kind == "train":
        return 3.0 * cfg.param_count() * 2.0   # params+grads+opt touched
    if shape.kind == "prefill":
        return p_bytes
    # decode: read params + full KV cache
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    kv = 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim * 2.0 * n_attn
    return p_bytes + kv


# ---------------------------------------------------------------------------
# the three-term roofline
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    """All hlo_* / coll_* fields are PER-DEVICE per step; model_flops_ is
    the cluster-wide analytic total."""
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops_: float
    per_device_hbm: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    fits_hbm: bool = True
    collectives: Dict[str, int] = field(default_factory=dict)

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.coll_bytes / LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.hlo_flops * self.n_chips
        self.useful_ratio = (self.model_flops_ / total_hlo
                             if total_hlo else 0.0)
        self.fits_hbm = self.per_device_hbm <= HBM_PER_CHIP
        return self

    @property
    def step_time_bound_s(self) -> float:
        """Lower bound on step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful (model) compute time / achievable step-time bound."""
        useful_s = self.model_flops_ / (self.n_chips * PEAK_FLOPS)
        bound = self.step_time_bound_s
        return useful_s / bound if bound > 0 else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["step_time_bound_s"] = self.step_time_bound_s
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze(arch: str, shape_name: str, mesh_name: str, n_chips: int,
            cfg, shape, hlo_text: str, cost: Optional[Dict[str, float]],
            per_device_bytes: float) -> Roofline:
    coll = collective_bytes(hlo_text)
    flops, hbytes = hlo_flops_and_bytes(hlo_text, cost)  # per-device
    mf = model_flops(cfg, shape)                         # cluster total
    # floors: HLO cannot beat the analytic model math / min traffic
    flops = max(flops, mf / n_chips)
    hbytes = max(hbytes, model_bytes(cfg, shape) / n_chips)
    r = Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                 n_chips=n_chips, hlo_flops=flops, hlo_bytes=hbytes,
                 coll_bytes=float(coll.total_bytes), model_flops_=mf,
                 per_device_hbm=per_device_bytes,
                 collectives=dict(coll.bytes_by_kind))
    return r.finalize()
