"""What-if scenario analysis (paper §II-C / §III).

The questions the paper poses verbatim:
  * "how much does availability improve if we reduce the recovery time
    after a failure by 50%?"
  * "when the same server fails repeatedly, after how many failures
    should we remove it from the cluster for ever?"
  * "what if failure rates increase and whether current policies will
    still be effective?"

    PYTHONPATH=src python examples/whatif_scenarios.py [--fast]
"""

import argparse

import numpy as np

from repro.core import MINUTES_PER_DAY, Params, simulate
from repro.core.vectorized import simulate_ctmc, supports

parser = argparse.ArgumentParser()
parser.add_argument("--fast", action="store_true")
args = parser.parse_args()
N = 96 if args.fast else 384

BASE = Params(job_size=1024, working_pool_size=1056, spare_pool_size=128,
              warm_standbys=16, job_length=16 * MINUTES_PER_DAY,
              random_failure_rate=0.02 / MINUTES_PER_DAY,
              systematic_failure_rate=0.10 / MINUTES_PER_DAY)


def run(p: Params, label: str) -> float:
    if supports(p):
        out = simulate_ctmc(p, n_replicas=N, seed=0)
        hours = out["total_time"].mean() / 60
        util = out["useful_work"].mean() / out["total_time"].mean()
    else:  # retirement etc. -> event-driven engine
        res = simulate(p, max(N // 24, 8))
        hours = np.mean([r.total_time for r in res]) / 60
        util = np.mean([r.effective_utilization for r in res])
    print(f"  {label:44s} {hours:9.1f} h   utilization {util * 100:6.2f}%")
    return hours


print("=== baseline ===")
base_h = run(BASE, "as configured")

print("\n=== what if recovery got 50% faster? (paper's example) ===")
fast_h = run(BASE.replace(recovery_time=BASE.recovery_time / 2),
             "recovery 20 -> 10 min")
print(f"  -> saves {base_h - fast_h:.1f} h "
      f"({(base_h - fast_h) / base_h * 100:.1f}%)")

print("\n=== what if failure rates double / quadruple? ===")
for mult in (2, 4):
    run(BASE.replace(
        random_failure_rate=BASE.random_failure_rate * mult,
        systematic_failure_rate=BASE.systematic_failure_rate * mult),
        f"{mult}x failure rates")

print("\n=== retirement policy: remove after K failures in 7 days ===")
for k in (0, 2, 3, 5):
    label = "no retirement" if k == 0 else f"retire after {k} failures"
    run(BASE.replace(retirement_threshold=k,
                     auto_repair_failure_probability=0.9,
                     manual_repair_failure_probability=0.6), label)
print("  (with poor repair efficacy, early retirement removes chronic "
      "offenders\n   before they burn more recovery cycles)")

print("\n=== distribution sensitivity (beyond-Markov, event engine) ===")
for dist in ("exponential", "weibull", "lognormal"):
    p = BASE.replace(failure_distribution=dist, job_length=4 * MINUTES_PER_DAY)
    res = simulate(p, 12)
    print(f"  {dist:14s} mean total "
          f"{np.mean([r.total_time for r in res]) / 60:8.1f} h   "
          f"p99 {np.percentile([r.total_time for r in res], 99) / 60:8.1f} h")
