"""What-if scenario analysis (paper §II-C / §III).

The questions the paper poses verbatim:
  * "how much does availability improve if we reduce the recovery time
    after a failure by 50%?"
  * "when the same server fails repeatedly, after how many failures
    should we remove it from the cluster for ever?"
  * "what if failure rates increase and whether current policies will
    still be effective?"

    PYTHONPATH=src python examples/whatif_scenarios.py [--fast]
"""

import argparse

import numpy as np

from repro.core import (MINUTES_PER_DAY, Params, resolve_engine,
                        run_replications, simulate)
from repro.core.vectorized import supports

parser = argparse.ArgumentParser()
parser.add_argument("--fast", action="store_true")
parser.add_argument("--engine", choices=("auto", "event", "ctmc"),
                    default="auto")
args = parser.parse_args()
N = 96 if args.fast else 384

BASE = Params(job_size=1024, working_pool_size=1056, spare_pool_size=128,
              warm_standbys=16, job_length=16 * MINUTES_PER_DAY,
              random_failure_rate=0.02 / MINUTES_PER_DAY,
              systematic_failure_rate=0.10 / MINUTES_PER_DAY)


def run(p: Params, label: str) -> float:
    # a forced --engine ctmc would raise on retirement scenarios; let
    # those degrade to auto (-> event) instead of crashing the tour
    eng = "auto" if (args.engine == "ctmc" and not supports(p)) \
        else args.engine
    # replica budget follows the engine that will actually run: the
    # vectorized path gets the full count, the sequential one a slice
    n = N if resolve_engine(p, eng) == "ctmc" else max(N // 24, 8)
    rep = run_replications(p, n, engine=eng)
    hours = rep.stats["total_time"].mean / 60
    util = 1.0 - rep.stats["overhead_fraction"].mean
    print(f"  {label:44s} {hours:9.1f} h   utilization {util * 100:6.2f}%"
          f"   [{rep.engine}]")
    return hours


print("=== baseline ===")
base_h = run(BASE, "as configured")

print("\n=== what if recovery got 50% faster? (paper's example) ===")
fast_h = run(BASE.replace(recovery_time=BASE.recovery_time / 2),
             "recovery 20 -> 10 min")
print(f"  -> saves {base_h - fast_h:.1f} h "
      f"({(base_h - fast_h) / base_h * 100:.1f}%)")

print("\n=== what if failure rates double / quadruple? ===")
for mult in (2, 4):
    run(BASE.replace(
        random_failure_rate=BASE.random_failure_rate * mult,
        systematic_failure_rate=BASE.systematic_failure_rate * mult),
        f"{mult}x failure rates")

print("\n=== retirement policy: remove after K failures in 7 days ===")
for k in (0, 2, 3, 5):
    label = "no retirement" if k == 0 else f"retire after {k} failures"
    run(BASE.replace(retirement_threshold=k,
                     auto_repair_failure_probability=0.9,
                     manual_repair_failure_probability=0.6), label)
print("  (with poor repair efficacy, early retirement removes chronic "
      "offenders\n   before they burn more recovery cycles)")

print("\n=== distribution sensitivity (age-dependent hazards) ===")
# weibull/bathtub now ride the vectorized fast path via engine="auto"
# (docs/distributions.md); lognormal still falls back to the event
# engine, so its replication count is kept small
for dist, kwargs in (("exponential", {}),
                     ("weibull", {"k": 1.5}),
                     ("bathtub", {"infant_factor": 5.0}),
                     ("lognormal", {"sigma": 1.0})):
    p = BASE.replace(failure_distribution=dist, distribution_kwargs=kwargs,
                     job_length=4 * MINUTES_PER_DAY)
    chosen = resolve_engine(p, "auto")
    rep = run_replications(p, N if chosen == "ctmc" else 12, engine="auto")
    st = rep.stats["total_time"]
    print(f"  {dist:14s} mean total {st.mean / 60:8.1f} h   "
          f"p99 {st.percentiles[99] / 60:8.1f} h   [{rep.engine}]")
