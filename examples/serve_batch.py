"""Serving example: batched prefill + decode with KV/SSM caches.

Demonstrates the inference path the decode_* dry-run shapes exercise:
prefill a batch of prompts, then decode tokens autoregressively against
the cache — including a hybrid (attention + mamba) architecture whose
cache carries both KV blocks and SSM states.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model

BATCH, PROMPT_LEN, GEN_TOKENS, S_MAX = 4, 24, 12, 64

for arch in ("qwen2.5-3b", "jamba-1.5-large-398b"):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (BATCH, PROMPT_LEN)), jnp.int32)

    # ---- prefill: one pass over the prompts, caches filled --------------
    cache = bundle.make_cache(BATCH, S_MAX)
    batch = {"tokens": prompts}
    t0 = time.perf_counter()
    logits, cache = bundle.prefill(params, batch, cache)
    prefill_ms = (time.perf_counter() - t0) * 1e3

    # ---- decode loop ------------------------------------------------------
    decode = jax.jit(lambda p, t, c, pos: bundle.decode(p, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for step in range(GEN_TOKENS - 1):
        pos = jnp.int32(PROMPT_LEN + step)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    decode_ms = (time.perf_counter() - t0) * 1e3 / (GEN_TOKENS - 1)

    out = jnp.concatenate(generated, axis=1)
    print(f"{arch}: prefill {BATCH}x{PROMPT_LEN} tokens in {prefill_ms:.1f} ms; "
          f"decode {decode_ms:.1f} ms/token (smoke config, CPU)")
    print(f"  generated token ids (request 0): {np.asarray(out[0])}")
    assert out.shape == (BATCH, GEN_TOKENS)
    assert bool(jnp.isfinite(logits).all())
print("serving OK")
