"""The paper's capacity-planning case study (§IV), end to end.

Question: how many servers beyond the 4096-server job minimum should the
working pool hold?  Too few -> preemptions and stalls; too many -> wasted
energy and capacity.

Runs a OneWaySweep over working-pool sizes through the engine-dispatch
layer (``engine="ctmc"`` -> the vectorized batched path) at the exact
Table-I parameters, cross-checks the analytic spare-capacity bound, and
prints a recommendation.  Pool size is a *structural* knob: thanks to
structure padding the whole grid still runs as one compiled XLA program,
and the exact per-run records give the mean time between restarts (the
ETTF-style metric operators tune on) per pool size.

``--hazard bathtub`` (the default) additionally re-runs the sweep under
an age-dependent bathtub failure process on ``engine="auto"`` — which
now takes the vectorized fast path too (docs/distributions.md), so the
what-if that used to crawl through the event engine is another single
compiled grid.  Infant mortality raises the effective failure rate
(restart-reset clocks live near the left edge of the hazard curve), so
the capacity answer genuinely shifts — that comparison is the point.

``--repairs lognormal`` (the default) adds a repair-policy what-if on
the fast path as well: heavy-tailed (lognormal, sigma=1.2) repair times
at the same means, swept over ``auto_repair_time`` — the ETTR
percentile table that used to require the event engine, now one
compiled grid through the repair-slot lane.

    PYTHONPATH=src python examples/capacity_planning.py [--fast]
"""

import argparse

from repro.core import (MINUTES_PER_DAY, OneWaySweep, Params,
                        repair_shop_occupancy, spare_capacity_bound)

parser = argparse.ArgumentParser()
parser.add_argument("--fast", action="store_true", help="fewer replicas")
parser.add_argument("--job-days", type=float, default=32.0)
parser.add_argument("--engine", choices=("auto", "event", "ctmc"),
                    default="ctmc")
parser.add_argument("--hazard", choices=("exponential", "bathtub"),
                    default="bathtub",
                    help="hazard family for the what-if section")
parser.add_argument("--repairs", choices=("exponential", "lognormal"),
                    default="lognormal",
                    help="repair family for the repair-policy what-if")
parser.add_argument("--shock", choices=("off", "on"), default="on",
                    help="correlated-failure what-if: rack-shock-rate "
                         "sweep under a 40-rack topology")
parser.add_argument("--jobs", choices=("off", "on"), default="on",
                    help="multi-job what-if: spare-pool x repair-server "
                         "grid with three mixed-size jobs sharing one "
                         "pool and one repair shop")
parser.add_argument("--tune", choices=("off", "on"), default="on",
                    help="checkpoint what-if: goodput-optimal checkpoint "
                         "interval via golden-section on the fast path, "
                         "cross-checked against Young/Daly")
args = parser.parse_args()

N_REP = 64 if args.fast else 256
POOLS = [4112, 4128, 4160, 4192, 4256]

base = Params(job_length=args.job_days * MINUTES_PER_DAY)

print(f"analytic repair-shop occupancy : "
      f"{repair_shop_occupancy(base):6.1f} servers (Little's law)")
print(f"analytic 99% spare bound       : "
      f"{spare_capacity_bound(base):6.1f} servers above the job\n")

sweep = OneWaySweep("capacity", "working_pool_size", POOLS,
                    n_replications=N_REP, base_params=base,
                    engine=args.engine)
rows = []
for point in sweep.run().points:
    pool = point.values["working_pool_size"]
    ettf, ettr = point.stats["run_duration_dist"], point.stats["recovery_dist"]
    rows.append({
        "pool": pool,
        "extra": pool - base.job_size - base.warm_standbys,
        "hours": point.stats["total_time"].mean / 60,
        "ci": point.stats["total_time"].ci95_halfwidth(N_REP) / 60,
        "stall_h": point.stats["stall_time"].mean / 60,
        "preempt": point.stats["n_preemptions"].mean,
        # exact pooled run durations (time between restarts), not the
        # old total_time/(n_failures+1) approximation
        "ettf_h": point.stats["run_duration_pooled"].mean / 60,
        # streaming-histogram percentiles: the distribution tails that
        # drive checkpoint cadence and spare capacity (exact to one bin
        # width, unbounded run count — no ring-buffer truncation)
        "ettf_p50": ettf.percentiles[50] / 60,
        "ettf_p99": ettf.percentiles[99] / 60,
        "ettr_p50": ettr.percentiles[50],
        "ettr_p99": ettr.percentiles[99],
    })

print(f"{'pool':>6} {'extra':>6} {'train hours':>14} {'stall h':>9} "
      f"{'preempts':>9} {'ettf h':>8}")
for r in rows:
    print(f"{r['pool']:>6} {r['extra']:>6} {r['hours']:>9.1f} +-{r['ci']:<4.1f}"
          f" {r['stall_h']:>9.2f} {r['preempt']:>9.2f} {r['ettf_h']:>8.2f}")

print("\ndistribution percentiles (streaming histograms; h = hours, "
      "min = minutes):")
print(f"{'pool':>6} {'ettf p50 h':>11} {'ettf p99 h':>11} "
      f"{'ettr p50 min':>13} {'ettr p99 min':>13}")
for r in rows:
    print(f"{r['pool']:>6} {r['ettf_p50']:>11.2f} {r['ettf_p99']:>11.2f} "
          f"{r['ettr_p50']:>13.1f} {r['ettr_p99']:>13.1f}")

# recommendation: the smallest pool within 0.5% of the best time
best = min(r["hours"] for r in rows)
for r in rows:
    if r["hours"] <= best * 1.005:
        print(f"\nRECOMMENDATION: working pool {r['pool']} "
              f"(+{r['pool'] - 4096} over the job size) — larger pools buy "
              f"<0.5% — matching the paper's finding that ~+32 extra "
              f"servers over job+standbys suffice at these rates.")
        break

# ---------------------------------------------------------------------------
# what-if: age-dependent (bathtub) failures, engine="auto" fast path
# ---------------------------------------------------------------------------
if args.hazard == "bathtub":
    bathtub = base.replace(
        job_length=min(args.job_days, 8.0) * MINUTES_PER_DAY,
        failure_distribution="bathtub",
        distribution_kwargs={"infant_factor": 5.0,
                             "infant_tau": 7 * MINUTES_PER_DAY})
    n_rep_bt = max(N_REP // 4, 32)
    print(f"\n=== what-if: bathtub hazard (infant x5, tau 7d), "
          f"engine=auto, {n_rep_bt} reps ===")
    bt_rows = []
    for point in OneWaySweep("capacity-bathtub", "working_pool_size", POOLS,
                             n_replications=n_rep_bt, base_params=bathtub,
                             engine="auto").run().points:
        ettr = point.stats["recovery_dist"]
        bt_rows.append({
            "pool": point.values["working_pool_size"],
            "engine": point.engine,     # "ctmc": the fast path took it
            "hours": point.stats["total_time"].mean / 60,
            "fails": point.stats["n_failures"].mean,
            "stall_h": point.stats["stall_time"].mean / 60,
            "ettr_p99": ettr.percentiles[99],
            # cross-replica spread of each replica's own p99 ETTR — the
            # run-to-run variability a pooled histogram cannot show
            "ettr_p99_iqr": point.stats["recovery_p99_replica"].iqr,
        })
    print(f"{'pool':>6} {'engine':>7} {'train h':>9} {'fails':>8} "
          f"{'stall h':>8} {'ettr p99':>9} {'p99 iqr':>8}")
    for r in bt_rows:
        print(f"{r['pool']:>6} {r['engine']:>7} {r['hours']:>9.1f} "
              f"{r['fails']:>8.1f} {r['stall_h']:>8.2f} "
              f"{r['ettr_p99']:>9.1f} {r['ettr_p99_iqr']:>8.2f}")
    assert all(r["engine"] == "ctmc" for r in bt_rows), \
        "bathtub grid should ride the vectorized fast path via auto"
    print("\nInfant mortality multiplies the effective failure rate "
          "(restart-reset clocks stay near age zero), so spare capacity "
          "that was comfortable under the exponential model tightens — "
          "compare the stall columns above.")

# ---------------------------------------------------------------------------
# what-if: heavy-tailed repairs (repair-policy grid on the fast path)
# ---------------------------------------------------------------------------
if args.repairs == "lognormal":
    heavy = base.replace(
        job_length=min(args.job_days, 8.0) * MINUTES_PER_DAY,
        repair_distribution="lognormal",
        distribution_kwargs={"sigma": 1.2})
    n_rep_rp = max(N_REP // 4, 32)
    auto_times = [60.0, 120.0, 240.0]
    print(f"\n=== what-if: lognormal repairs (sigma 1.2, same means), "
          f"auto_repair_time sweep, engine=auto, {n_rep_rp} reps ===")
    rp_rows = []
    for point in OneWaySweep("repair-policy", "auto_repair_time", auto_times,
                             n_replications=n_rep_rp, base_params=heavy,
                             engine="auto").run().points:
        ettr = point.stats["recovery_dist"]
        rp_rows.append({
            "auto_min": point.values["auto_repair_time"],
            "engine": point.engine,     # "ctmc": the repair-slot lane
            "hours": point.stats["total_time"].mean / 60,
            "stall_h": point.stats["stall_time"].mean / 60,
            # ETTR distribution tails under heavy-tailed repair times —
            # the table that used to require the event engine
            "ettr_p50": ettr.percentiles[50],
            "ettr_p99": ettr.percentiles[99],
        })
    print(f"{'auto min':>9} {'engine':>7} {'train h':>9} {'stall h':>8} "
          f"{'ettr p50':>9} {'ettr p99':>9}")
    for r in rp_rows:
        print(f"{r['auto_min']:>9.0f} {r['engine']:>7} {r['hours']:>9.1f} "
              f"{r['stall_h']:>8.2f} {r['ettr_p50']:>9.1f} "
              f"{r['ettr_p99']:>9.1f}")
    assert all(r["engine"] == "ctmc" for r in rp_rows), \
        "repair-policy grid should ride the repair-slot lane via auto"
    print("\nHeavy-tailed repairs at the same mean stretch the ETTR tail "
          "(compare p99 against the mean-matched exponential model) — "
          "the spare-capacity margin has to cover the tail, not the "
          "mean, which is exactly what the percentile columns price in.")

# ---------------------------------------------------------------------------
# what-if: correlated failure domains (docs/scenarios.md)
# ---------------------------------------------------------------------------
if args.shock == "on":
    from repro.core import FaultTopology

    # 4360-server fleet / 40 racks = 109 per rack, exact striping; the
    # shock rates are traced, so the whole grid is one compiled program
    shocked = base.replace(
        job_length=min(args.job_days, 8.0) * MINUTES_PER_DAY,
        fault_domains=FaultTopology(n_racks=40, racks_per_pod=8))
    n_rep_sh = max(N_REP // 4, 32)
    rates = [0.0, 2e-6, 5e-6, 1e-5]
    print(f"\n=== what-if: correlated rack outages (40 racks, whole-rack "
          f"shocks), rack_shock_rate sweep, engine=auto, {n_rep_sh} reps "
          f"===")
    sh_rows = []
    for point in OneWaySweep("capacity-shock", "rack_shock_rate", rates,
                             n_replications=n_rep_sh, base_params=shocked,
                             engine="auto").run().points:
        sh_rows.append({
            "rate": point.values["rack_shock_rate"],
            "engine": point.engine,     # "ctmc": scenario fast path
            "hours": point.stats["total_time"].mean / 60,
            "shocks": point.stats["n_domain_shocks"].mean,
            "killed": point.stats["n_shock_killed"].mean,
            "stall_h": point.stats["stall_time"].mean / 60,
            "preempt": point.stats["n_preemptions"].mean,
        })
    print(f"{'rate/min':>9} {'engine':>7} {'train h':>9} {'shocks':>7} "
          f"{'killed':>7} {'stall h':>8} {'preempts':>9}")
    for r in sh_rows:
        print(f"{r['rate']:>9.0e} {r['engine']:>7} {r['hours']:>9.1f} "
              f"{r['shocks']:>7.2f} {r['killed']:>7.1f} "
              f"{r['stall_h']:>8.2f} {r['preempt']:>9.2f}")
    assert all(r["engine"] == "ctmc" for r in sh_rows), \
        "shock grid should ride the scenario fast path via auto"
    base_h = sh_rows[0]["hours"]
    worst = sh_rows[-1]
    print(f"\nA whole-rack outage kills 109 servers at once — the job, "
          f"its standbys, and its spares lose their rack stripe "
          f"together.  At {worst['rate']:.0e}/min per rack the shocks "
          f"cost {worst['hours'] - base_h:+.1f} train hours vs the "
          f"uncorrelated baseline; spare capacity sized for i.i.d. "
          f"failures underestimates the burst draw (compare the "
          f"preemption column).  Scripted campaigns (exact kill times, "
          f"maintenance windows) cover the deterministic side — see "
          f"docs/scenarios.md.")

# ---------------------------------------------------------------------------
# what-if: multi-job shared-pool contention (docs/multijob.md)
# ---------------------------------------------------------------------------
if args.jobs == "on":
    from repro.core import JobSpec, MultiJobSweep
    from repro.core import vectorized_multijob

    # three mixed-size jobs on one 200-server pool: how many spares and
    # repair servers does the *fleet* need?  Job count is the only
    # static compile key, so the whole 3x2 grid (mixed sizes included)
    # is one compiled XLA program.
    mj_cluster = Params(
        working_pool_size=200, spare_pool_size=12, job_size=64,
        job_length=720.0, random_failure_rate=0.004,
        systematic_failure_rate=0.01, auto_repair_time=180.0,
        manual_repair_time=480.0, repair_servers=4, histogram=None)
    mj_jobs = [JobSpec(64, 720.0, warm_standbys=2),
               JobSpec(32, 1000.0, warm_standbys=1),
               JobSpec(16, 860.0, warm_standbys=1)]
    n_rep_mj = max(N_REP // 4, 32)
    print(f"\n=== what-if: 3 mixed-size jobs (64/32/16) on one shared "
          f"pool, spare x repair-server grid, engine=auto, {n_rep_mj} "
          f"reps ===")
    compiles_before = vectorized_multijob.compile_cache_size()
    mj = MultiJobSweep("fleet-capacity", mj_jobs, "spare_pool_size",
                       [8, 10, 12], parameter_b="repair_servers",
                       values_b=[3, 4], n_replications=n_rep_mj,
                       base_params=mj_cluster, engine="auto").run()
    compiles_after = vectorized_multijob.compile_cache_size()
    compiles = (None if compiles_before is None or compiles_after is None
                else compiles_after - compiles_before)
    print(f"{'spares':>7} {'shop':>5} {'engine':>7} {'makespan h':>11} "
          f"{'stalls':>7} {'queued':>7} {'job0 h':>7} {'job2 h':>7}")
    for p in mj.points:
        print(f"{p.values['spare_pool_size']:>7} "
              f"{p.values['repair_servers']:>5} {p.engine:>7} "
              f"{p.stats['makespan'].mean / 60:>11.1f} "
              f"{p.stats['stall_handoffs'].mean:>7.1f} "
              f"{p.stats['n_shop_queued'].mean:>7.1f} "
              f"{p.stats['job0_total_time'].mean / 60:>7.1f} "
              f"{p.stats['job2_total_time'].mean / 60:>7.1f}")
    assert all(p.engine == "ctmc" for p in mj.points), \
        "multi-job grid should ride the compartment engine via auto"
    assert compiles in (None, 0, 1), \
        f"mixed-size capacity grid should be ONE program, got {compiles}"
    print("\nThe fleet view prices what single-job sweeps cannot: spares "
          "and repair servers are shared, so the small job's stalls are "
          "set by the big job's failure traffic.  Watch the queued "
          "column — a shop one server short backs up every job at once "
          "(hand-offs go FIFO to the longest-stalled job; see "
          "docs/multijob.md).")

# ---------------------------------------------------------------------------
# what-if: goodput-optimal checkpoint cadence (docs/optimization.md)
# ---------------------------------------------------------------------------
if args.tune == "on":
    from repro.core import cluster_failure_rate, young_daly_interval
    from repro.core.optimize import optimize_checkpoint_interval

    # a 10-minute checkpoint write at paper scale: every interval
    # candidate is a traced column, so the whole search (coarse grid +
    # every golden-section iteration) reuses ONE compiled XLA program
    # a one-minute write: at this fleet's ~20-min MTBF a long write
    # would drown the job in overhead — the knob only has an interior
    # optimum when C << MTBF, the regime the +-4x bracket stays inside
    tuned = base.replace(
        job_length=min(args.job_days, 8.0) * MINUTES_PER_DAY,
        checkpoint_cost=1.0)
    n_rep_ck = max(N_REP // 4, 32)
    mtbf = 1.0 / cluster_failure_rate(tuned)
    yd = young_daly_interval(tuned.checkpoint_cost, mtbf)
    print(f"\n=== what-if: checkpoint cadence (write cost "
          f"{tuned.checkpoint_cost:.0f} min, fleet MTBF {mtbf:.0f} min), "
          f"golden-section on goodput, {n_rep_ck} reps ===")
    res = optimize_checkpoint_interval(tuned, n_replicas=n_rep_ck,
                                       bounds=(yd / 4.0, yd * 4.0),
                                       n_grid=8, refine_iters=6)
    print(f"{'interval min':>13} {'goodput':>9}")
    for iv, g in zip(res.grid, res.grid_objective):
        mark = " <- grid argmax" if g == max(res.grid_objective) else ""
        print(f"{iv:>13.1f} {g:>9.4f}{mark}")
    print(f"\nYoung/Daly sqrt(2*C*MTBF)      : {res.young_daly:8.1f} min")
    print(f"simulated goodput optimum      : {res.interval:8.1f} min "
          f"(goodput {res.objective:.4f}, {res.n_evals} candidates, "
          f"{len(res.history)} refinement iterations)")
    print("\nThe first-order Young/Daly cadence and the simulated optimum "
          "agree to about a grid notch here — the analytical cross-check "
          "that pins the optimizer (tests/test_checkpoint_opt.py).  The "
          "simulated curve additionally prices what the formula ignores: "
          "stalls, pool depletion, and host-selection overhead all load "
          "the denominator of goodput = useful work / wall clock.")
