"""The paper's capacity-planning case study (§IV), end to end.

Question: how many servers beyond the 4096-server job minimum should the
working pool hold?  Too few -> preemptions and stalls; too many -> wasted
energy and capacity.

Uses the vectorized CTMC engine to sweep working-pool sizes at the exact
Table-I parameters, cross-checks the analytic spare-capacity bound, and
prints a recommendation.

    PYTHONPATH=src python examples/capacity_planning.py [--fast]
"""

import argparse

import numpy as np

from repro.core import (MINUTES_PER_DAY, Params, repair_shop_occupancy,
                        spare_capacity_bound)
from repro.core.vectorized import simulate_ctmc

parser = argparse.ArgumentParser()
parser.add_argument("--fast", action="store_true", help="fewer replicas")
parser.add_argument("--job-days", type=float, default=32.0)
args = parser.parse_args()

N_REP = 64 if args.fast else 256
POOLS = [4112, 4128, 4160, 4192, 4256]

base = Params(job_length=args.job_days * MINUTES_PER_DAY)

print(f"analytic repair-shop occupancy : "
      f"{repair_shop_occupancy(base):6.1f} servers (Little's law)")
print(f"analytic 99% spare bound       : "
      f"{spare_capacity_bound(base):6.1f} servers above the job\n")

rows = []
for pool in POOLS:
    p = base.replace(working_pool_size=pool)
    out = simulate_ctmc(p, n_replicas=N_REP, seed=0)
    t = out["total_time"]
    rows.append({
        "pool": pool,
        "extra": pool - p.job_size - p.warm_standbys,
        "hours": t.mean() / 60,
        "ci": 1.96 * t.std() / np.sqrt(N_REP) / 60,
        "stall_h": out["stall_time"].mean() / 60,
        "preempt": out["n_preemptions"].mean(),
    })

print(f"{'pool':>6} {'extra':>6} {'train hours':>14} {'stall h':>9} "
      f"{'preempts':>9}")
for r in rows:
    print(f"{r['pool']:>6} {r['extra']:>6} {r['hours']:>9.1f} +-{r['ci']:<4.1f}"
          f" {r['stall_h']:>9.2f} {r['preempt']:>9.2f}")

# recommendation: the smallest pool within 0.5% of the best time
best = min(r["hours"] for r in rows)
for r in rows:
    if r["hours"] <= best * 1.005:
        print(f"\nRECOMMENDATION: working pool {r['pool']} "
              f"(+{r['pool'] - 4096} over the job size) — larger pools buy "
              f"<0.5% — matching the paper's finding that ~+32 extra "
              f"servers over job+standbys suffice at these rates.")
        break
