"""End-to-end driver: train a language model under injected failures.

This closes the paper's loop in one script:
  1. pick a cluster reliability configuration (AIReSim Params);
  2. derive the checkpoint cadence from Young/Daly on those failure rates;
  3. train a real model with the fault-tolerant loop — failures are
     injected from the SAME exponential model, recovery restores the
     latest checkpoint and reseeks the data pipeline;
  4. compare the measured overhead fraction against what the AIReSim
     simulator predicts for this configuration.

Default preset is laptop-sized so the demo finishes on one CPU core;
``--preset 100m`` is the full-size variant for real hardware
(d_model=768, 12 layers, ~100M params, a few hundred steps).

    PYTHONPATH=src python examples/train_with_failures.py [--steps 60]
"""

import argparse

import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.core import MINUTES_PER_DAY, Params, simulate
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train.loop import TrainLoopConfig, train
from repro.train.optimizer import OptimizerConfig

PRESETS = {
    "tiny": (ModelConfig(name="tiny-lm", family="dense", n_layers=4,
                         d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                         vocab_size=2048, dtype="float32"),
             ShapeSpec("tiny", 64, 4, "train")),
    "100m": (ModelConfig(name="lm-100m", family="dense", n_layers=12,
                         d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                         vocab_size=32768, dtype="float32"),
             ShapeSpec("train", 512, 8, "train")),
}

parser = argparse.ArgumentParser()
parser.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
parser.add_argument("--steps", type=int, default=60)
parser.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
args = parser.parse_args()

cfg, shape = PRESETS[args.preset]
print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params), "
      f"{args.steps} steps of batch {shape.global_batch} x {shape.seq_len}")

# a cluster where failures are frequent enough to see during the demo:
# ~1 failure per 15 simulated step-minutes
cluster = Params(job_size=64, working_pool_size=72, spare_pool_size=8,
                 warm_standbys=4,
                 random_failure_rate=1.0 / MINUTES_PER_DAY,
                 systematic_failure_rate=5.0 / MINUTES_PER_DAY,
                 job_length=args.steps * 1.0)

bundle = build_model(cfg)
mesh = make_host_mesh()
out = train(
    bundle, mesh, shape,
    TrainLoopConfig(total_steps=args.steps, log_every=max(args.steps // 6, 1),
                    checkpoint_dir=args.ckpt_dir,
                    checkpoint_cost_minutes=0.5, step_minutes=1.0,
                    inject_failures=True, cluster=cluster, seed=0),
    OptimizerConfig(learning_rate=3e-3, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps, min_lr_fraction=0.5),
)

print("\n--- training history ---")
for h in out["history"]:
    print(f"  step {h['step']:4d}  loss {h['loss']:7.4f}  "
          f"lr {h['lr']:.2e}  {h['step_time_s'] * 1e3:7.1f} ms/step")
print(f"\ncheckpoint cadence (Young/Daly): every "
      f"{out['checkpoint_cadence']} steps")
print(f"recovery events: {out['recovery']}")

# synthetic tokens are uniform -> the achievable floor is ln(vocab); check
# the model moved toward it despite the failures
first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
floor = float(np.log(cfg.vocab_size))
assert last < first - 0.01, (
    f"training did not reduce the loss ({first:.3f} -> {last:.3f}; "
    f"uniform-token floor is {floor:.3f})")
print(f"loss: {first:.3f} -> {last:.3f} (floor ~{floor:.2f}) OK despite "
      f"{out['recovery']['n_failures']} failure(s)")

# --- what does AIReSim predict for this cluster? -------------------------
# the injector draws from the same exponential model the simulator sweeps,
# so the FAILURE COUNT over the job is directly comparable
pred = simulate(cluster, n_replications=10)
sim_failures = float(np.mean([r.n_failures for r in pred]))
print(f"\nAIReSim-predicted failures over the job: {sim_failures:5.1f}")
print(f"failures injected into this training run: "
      f"{out['recovery']['n_failures']:5d}")
print(f"AIReSim-predicted overhead fraction (incl. 20-min recoveries): "
      f"{np.mean([r.overhead_fraction for r in pred]):.3f} — the capacity "
      f"planner's input for this cluster")
