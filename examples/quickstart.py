"""Quickstart: simulate a cluster, read the outputs, run a sweep.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (MINUTES_PER_DAY, OneWaySweep, Params, aggregate,
                        simulate)

# ---------------------------------------------------------------------------
# 1. one configuration, a few replications
# ---------------------------------------------------------------------------
params = Params(
    job_size=1024,                    # servers the job needs
    working_pool_size=1060,           # powered-on pool (36 spare-ish)
    spare_pool_size=64,               # preemptible pool
    warm_standbys=8,
    job_length=8 * MINUTES_PER_DAY,   # useful compute
    random_failure_rate=0.01 / MINUTES_PER_DAY,
    systematic_failure_rate=0.05 / MINUTES_PER_DAY,
    systematic_failure_fraction=0.15,
    recovery_time=20.0,               # minutes per restart
)

results = simulate(params, n_replications=5)
stats = aggregate(results)
print("=== single configuration (5 replications) ===")
print(f"total time      : {stats['total_time'].mean / 60:8.1f} h "
      f"(median {stats['total_time'].median / 60:.1f}, "
      f"p99 {stats['total_time'].percentiles[99] / 60:.1f})")
print(f"failures        : {stats['n_failures'].mean:8.1f} "
      f"(random {stats['n_random_failures'].mean:.1f} / "
      f"systematic {stats['n_systematic_failures'].mean:.1f})")
print(f"repairs         : auto {stats['n_auto_repairs'].mean:.1f}, "
      f"manual {stats['n_manual_repairs'].mean:.1f}")
print(f"preemptions     : {stats['n_preemptions'].mean:8.1f}")
print(f"overhead        : {stats['overhead_fraction'].mean * 100:8.2f} %")

# ---------------------------------------------------------------------------
# 2. a one-way sweep (the paper's §III-D API) under a bathtub hazard
# ---------------------------------------------------------------------------
# age-dependent failures (infant mortality + wear-out) are one Params
# switch, and engine="auto" still takes the vectorized fast path — the
# sweep compiles to a single XLA program (see docs/distributions.md)
bathtub = params.replace(
    failure_distribution="bathtub",
    distribution_kwargs={"infant_factor": 5.0, "infant_tau": 7 * MINUTES_PER_DAY})
sweep = OneWaySweep("Systematic Failure Fraction (bathtub hazard)",
                    "systematic_failure_fraction", [0.1, 0.15, 0.2, 0.3],
                    n_replications=3, base_params=bathtub, engine="auto")
result = sweep.run()
print("\n=== one-way sweep: systematic failure fraction, bathtub hazard ===")
for point, row in zip(result.points, result.to_rows()):
    print(f"  fraction={row['systematic_failure_fraction']:<5} "
          f"total={row['total_time'] / 60:7.1f} h  "
          f"failures={row['n_failures']:6.1f}  "
          f"(ci95 +-{row['total_time_ci95'] / 60:.1f} h)  "
          f"[engine={point.engine}]")
result.write_csv("results/quickstart_sweep.csv")
print("wrote results/quickstart_sweep.csv")
