"""Reproductions of the paper's evaluation (§IV, Table I, Fig. 2a/2b).

The paper's design: two-way sweeps of each Table-I knob against the
working-pool size {4112, 4128, 4160, 4192} for a 4096-server job with 16
warm standbys, measuring total training time (lower = better).

We run the exact Table-I values at the full 4096-server scale using the
vectorized CTMC engine (validated against the event-driven engine in
tests/test_vectorized.py) with the event engine cross-checking a subset.
Job length is 32 days (the paper's is illustrative — "e.g., 256 days" —
and enters total time linearly; noted in EXPERIMENTS.md).

Expected qualitative results (asserted in tests/test_paper_claims.py):
  * training time increases with recovery time (Fig 2a);
  * training time increases with spare-pool waiting time, most at the
    smallest working pool (Fig 2b);
  * +32 servers over minimum suffice — larger pools give ~no further gain;
  * the other knobs are ~flat in this over-provisioned regime.
"""

from __future__ import annotations

import csv
import os
from typing import Dict, List, Sequence

import numpy as np

from repro.core import MINUTES_PER_DAY, Params
from repro.core.params import PAPER_TABLE1_RANGES
from repro.core.vectorized import simulate_ctmc_sweep

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
POOL_SIZES = [4112, 4128, 4160, 4192]
JOB_DAYS = 32
N_REPLICAS = 256


def paper_params(**kw) -> Params:
    base = dict(job_length=JOB_DAYS * MINUTES_PER_DAY)
    base.update(kw)
    return Params(**base)


def _cell_stats(out: Dict[str, np.ndarray], n_replicas: int) -> Dict[str, float]:
    return {
        "total_time_hours": float(out["total_time"].mean()) / 60.0,
        "total_time_ci95_hours": float(
            1.96 * out["total_time"].std() / np.sqrt(n_replicas)) / 60.0,
        "n_failures": float(out["n_failures"].mean()),
        "n_preemptions": float(out["n_preemptions"].mean()),
        "stall_hours": float(out["stall_time"].mean()) / 60.0,
        "overhead_fraction": float(
            1.0 - out["useful_work"].mean() / out["total_time"].mean()),
    }


def two_way_sweep(param: str, values: Sequence[float],
                  pools: Sequence[int] = POOL_SIZES,
                  n_replicas: int = N_REPLICAS) -> List[Dict]:
    grid = []
    for v in values:
        for pool in pools:
            if param == "systematic_failure_rate_multiplier":
                p = paper_params(working_pool_size=pool)
                p = p.replace(systematic_failure_rate=v * p.random_failure_rate)
            else:
                p = paper_params(working_pool_size=pool, **{param: v})
            grid.append((v, pool, p))
    # one batched call: with structure padding the whole values x pools
    # cross grid — pool size is a structural knob — runs as a single
    # compiled program instead of one per pool structure
    outs = simulate_ctmc_sweep([p for _, _, p in grid], n_replicas=n_replicas,
                               seed=0)
    return [{param: v, "working_pool_size": pool, **_cell_stats(out, n_replicas)}
            for (v, pool, _), out in zip(grid, outs)]


def _write_csv(rows: List[Dict], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


def _plot(rows: List[Dict], param: str, path: str, title: str) -> None:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:
        return
    fig, ax = plt.subplots(figsize=(7, 4))
    pools = sorted({r["working_pool_size"] for r in rows})
    for pool in pools:
        sub = [r for r in rows if r["working_pool_size"] == pool]
        xs = [r[param] for r in sub]
        ys = [r["total_time_hours"] for r in sub]
        es = [r["total_time_ci95_hours"] for r in sub]
        ax.errorbar(xs, ys, yerr=es, marker="o", label=f"pool={pool}")
    ax.set_xlabel(param)
    ax.set_ylabel("total training time (hours)")
    ax.set_title(title)
    ax.legend()
    fig.tight_layout()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fig.savefig(path, dpi=110)
    plt.close(fig)


def fig2a(n_replicas: int = N_REPLICAS) -> List[Dict]:
    """Training time vs recovery time x pool size (paper Fig 2a)."""
    rows = two_way_sweep("recovery_time",
                         PAPER_TABLE1_RANGES["recovery_time"],
                         n_replicas=n_replicas)
    _write_csv(rows, f"{RESULTS_DIR}/fig2a_recovery_time.csv")
    _plot(rows, "recovery_time", f"{RESULTS_DIR}/fig2a_recovery_time.png",
          "Fig 2a: total training time vs recovery time")
    return rows


def fig2b(n_replicas: int = N_REPLICAS) -> List[Dict]:
    """Training time vs spare-pool waiting time x pool size (Fig 2b)."""
    rows = two_way_sweep("waiting_time",
                         PAPER_TABLE1_RANGES["waiting_time"],
                         n_replicas=n_replicas)
    _write_csv(rows, f"{RESULTS_DIR}/fig2b_waiting_time.csv")
    _plot(rows, "waiting_time", f"{RESULTS_DIR}/fig2b_waiting_time.png",
          "Fig 2b: total training time vs spare-pool waiting time")
    return rows


#: the "all other knobs" of Table I (the paper's flat-sensitivity finding)
SENSITIVITY_PARAMS = [
    "random_failure_rate", "systematic_failure_rate_multiplier",
    "systematic_failure_fraction", "warm_standbys", "host_selection_time",
    "automated_repair_probability", "auto_repair_failure_probability",
    "manual_repair_failure_probability", "auto_repair_time",
    "manual_repair_time", "spare_pool_size", "diagnosis_probability",
]


def sensitivity(n_replicas: int = 128,
                pools: Sequence[int] = (4112, 4160)) -> List[Dict]:
    """Table-I grid: every remaining knob x pool size; effect sizes."""
    all_rows: List[Dict] = []
    for param in SENSITIVITY_PARAMS:
        rows = two_way_sweep(param, PAPER_TABLE1_RANGES[param], pools,
                             n_replicas)
        for r in rows:
            r["parameter"] = param
            r["value"] = r.pop(param)
        all_rows.extend(rows)
    _write_csv(all_rows, f"{RESULTS_DIR}/table1_sensitivity.csv")
    return all_rows


def effect_sizes(rows: List[Dict]) -> Dict[str, float]:
    """Relative spread of training time per parameter (max-min)/min."""
    out: Dict[str, float] = {}
    for param in {r["parameter"] for r in rows}:
        ts = [r["total_time_hours"] for r in rows if r["parameter"] == param]
        out[param] = (max(ts) - min(ts)) / min(ts)
    return out
